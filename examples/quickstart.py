"""Quickstart: train a small model, checkpoint it, and serve from it.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]

Uses the reduced smollm-135m config so the whole thing runs on a laptop
CPU in about a minute.  See examples/train_e2e.py for the full-size run.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.configs.model_config import ShapeConfig, TrainConfig
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = reduced(ARCHS["smollm-135m"])
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")
    tcfg = TrainConfig(learning_rate=3e-3)

    ckpt_dir = tempfile.mkdtemp(prefix="xar_quickstart_")
    trainer = Trainer(cfg, shape, tcfg, ckpt_dir=ckpt_dir, ckpt_every=20,
                      total_steps=args.steps)
    log = trainer.run(steps=args.steps, log_every=20)
    print(f"\ntrained {args.steps} steps: loss "
          f"{log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")

    params, _ = trainer.final_state
    engine = ServeEngine(cfg, params=params)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (4, 16), 0,
                                 cfg.vocab_size, jnp.int32)
    res = engine.generate(prompts, max_new_tokens=8)
    print(f"generated {res.tokens.shape} tokens at "
          f"{res.tokens_per_second:.1f} tok/s")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
