"""The paper's multi-tenant evaluation in one script.

Replays the calibrated platform (Xeon + ThunderX + Alveo, Table 1
profiles) through the REAL Xar-Trek scheduler (Algorithms 1+2) across the
paper's scenarios, printing side-by-side numbers vs the no-migration
baselines:

    PYTHONPATH=src python examples/multi_tenant_sim.py
"""
import random

from repro.core.estimator import estimate_table
from repro.core.sim import AppProfile, MGB_MS, PAPER_APPS, PlatformSim
from repro.core.thresholds import ThresholdTable
import copy


def fresh_table() -> ThresholdTable:
    t = ThresholdTable()
    t.rows = {k: copy.deepcopy(v)
              for k, v in estimate_table(PAPER_APPS).rows.items()}
    return t


BG = AppProfile("mgb", MGB_MS, MGB_MS, MGB_MS, "KNL_MGB")
KERNELS = tuple(a.hw_kernel for a in PAPER_APPS.values())


def scenario(name: str, n_apps: int, n_bg: int) -> None:
    print(f"\n=== {name}: {n_apps} apps, {n_bg} background processes ===")
    results = {}
    for policy in ("always_host", "always_accel", "always_aux", "xartrek"):
        sim = PlatformSim(policy=policy, table=fresh_table(),
                          preconfigure=KERNELS)
        for _ in range(n_bg):
            sim.submit(BG, at=0.0, background=True)
        rng = random.Random(42)
        apps = list(PAPER_APPS.values())
        for _ in range(n_apps):
            sim.submit(rng.choice(apps), at=10.0)
        sim.run()
        results[policy] = sim.avg_execution_ms()
        dec = {k.value: v for k, v in sim.decisions.items() if v}
        print(f"  {policy:13s} avg={results[policy]:9.0f} ms  "
              f"decisions={dec}")
    x86, xar = results["always_host"], results["xartrek"]
    print(f"  -> Xar-Trek vs vanilla x86: "
          f"{100 * (x86 - xar) / x86:+.0f}% "
          f"(paper range at this load band: 88%..1%)")


def threshold_report() -> None:
    print("=== Threshold estimation (paper Table 2) ===")
    import math
    paper = {"cg_a": (31, 25), "facedet320": (16, 31), "facedet640": (0, 23),
             "digit500": (0, 18), "digit2000": (0, 17)}
    for row in estimate_table(PAPER_APPS).as_table2():
        name = row["Benchmark"]
        f = max(0, math.ceil(row["FPGA_THR"]))
        a = max(0, math.ceil(row["ARM_THR"]))
        print(f"  {name:12s} FPGA_THR={f:3d} (paper {paper[name][0]:3d})  "
              f"ARM_THR={a:3d} (paper {paper[name][1]:3d})")


def main() -> None:
    threshold_report()
    scenario("low load (Fig 3)", n_apps=5, n_bg=0)
    scenario("medium load (Fig 4)", n_apps=10, n_bg=50)
    scenario("high load (Fig 5)", n_apps=10, n_bg=114)
    scenario("FPGA-hostile mix (Fig 9)", n_apps=10, n_bg=110)


if __name__ == "__main__":
    main()
