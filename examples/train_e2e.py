"""End-to-end driver: train the FULL smollm-135m (135M params) for a few
hundred steps with fault-tolerant checkpointing and a mid-run simulated
node failure + automatic restart.

    PYTHONPATH=src python examples/train_e2e.py \\
        [--steps 300] [--seq 512] [--batch 4] [--fail-at 150] [--reduced]

On this CPU container a full-size step at seq 512 / batch 4 takes a few
seconds; pass --reduced for a quick functional pass.
"""
import argparse
import os
import time

from repro.configs import ARCHS, reduced as make_reduced
from repro.configs.model_config import ShapeConfig, TrainConfig
from repro.train.trainer import FailureInjector, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/xar_e2e_ckpt")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a failure at this step (0=off)")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = make_reduced(cfg)
    print(f"arch={cfg.name}: {cfg.param_count()/1e6:.0f}M params")

    shape = ShapeConfig("e2e", args.seq, args.batch, "train")
    tcfg = TrainConfig(learning_rate=args.lr)
    trainer = Trainer(cfg, shape, tcfg, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50, async_ckpt=True,
                      total_steps=args.steps)

    injector = (FailureInjector(fail_at_steps=(args.fail_at,))
                if args.fail_at else None)
    t0 = time.time()
    log = trainer.run(steps=args.steps, injector=injector, log_every=10)
    dt = time.time() - t0
    tokens = args.steps * args.seq * args.batch
    print(f"\ndone: loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f} "
          f"in {dt/60:.1f} min ({tokens/dt:.0f} tok/s)")
    print(f"checkpoints: {sorted(os.listdir(args.ckpt_dir))}")


if __name__ == "__main__":
    main()
