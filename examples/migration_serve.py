"""Serving with run-time execution migration (the Fig-6 scenario on real
JAX functions).

A reduced model serves batched generation while synthetic host load
ramps up.  The decode step is a MigratableFunction with HOST (plain jnp)
and ACCEL (Pallas-kernel attention for prefill / alternative compiled
step) variants; the Xar-Trek scheduler watches the load, pre-configures
the ACCEL variant asynchronously at startup, and migrates when the load
crosses the threshold.

    PYTHONPATH=src python examples/migration_serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry, MigratableFunction
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.models.model import build_model


def main() -> None:
    cfg = reduced(ARCHS["smollm-135m"])
    model = build_model(cfg, mesh=None)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B, S, NEW = 4, 32, 24
    cache0 = model.init_cache(B, S + NEW)

    def decode_step(params, cache, batch):          # HOST variant
        return model.decode(params, cache, batch)

    def decode_step_accel(params, cache, batch):    # ACCEL variant
        # same math; in production this is the Pallas-kernel build of the
        # step — here it doubles as the "hardware kernel" so the demo
        # exercises compile/migrate mechanics on CPU
        return model.decode(params, cache, batch)

    registry = FunctionRegistry()
    registry.register(MigratableFunction(
        "serve_decode", "serve-demo",
        {TargetKind.HOST: decode_step, TargetKind.ACCEL: decode_step_accel}))

    rt = XarTrekRuntime(registry=registry, min_reconfig_seconds=1.0)
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": prompts})
    cache = {k: jax.lax.dynamic_update_slice(
        cache0[k], cache[k].astype(cache0[k].dtype), (0,) * cache0[k].ndim)
        for k in cache0}

    example = (params, cache, {"tokens": jnp.zeros((B, 1), jnp.int32),
                               "index": jnp.int32(S)})
    # app launch: compile HOST now, pre-configure ACCEL in the background
    rt.prepare("serve_decode", *example,
               table_row={"fpga_thr": 2.5, "arm_thr": 1e9})

    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    phases = [("low load", 0), ("high load", 6)]
    for pi, (phase, synthetic_load) in enumerate(phases):
        if pi == 1:
            # lull between phases: the asynchronous "reconfiguration"
            # (ACCEL compile) completes while traffic is elsewhere —
            # the paper's latency-hiding behaviour
            deadline = time.time() + 10.0
            while (not rt.bank.is_resident("serve_decode")
                   and time.time() < deadline):
                time.sleep(0.05)
        # synthetic co-tenants on the host pool
        for _ in range(synthetic_load):
            rt.monitor.job_started(TargetKind.HOST)
        t0 = time.perf_counter()
        targets = []
        for i in range(NEW // 2):
            batch = {"tokens": tok, "index": jnp.int32(S + i)}
            logits, cache = rt.call("serve_decode", params, cache, batch)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)[:, 0]
            tok = tok[:, None]
            targets.append(rt.call_log[-1]["target"])
        dt = time.perf_counter() - t0
        for _ in range(synthetic_load):
            rt.monitor.job_finished(TargetKind.HOST)
        print(f"{phase:10s}: {B * NEW // 2 / dt:7.1f} tok/s  "
              f"targets={dict((t, targets.count(t)) for t in set(targets))}")
    print("summary:", rt.summary())


if __name__ == "__main__":
    main()
