"""Continuous-batching serving with run-time execution migration (the
Fig-6 scenario on real JAX functions), on the v2 serve API.

A reduced model serves a ragged Poisson arrival stream through the
``ContinuousBatchingEngine``; every prefill/decode step dispatches
through the Xar-Trek runtime.  The engine registers genuinely different
builds of its step functions — HOST on the XLA reference math, ACCEL on
the Pallas kernels (flash prefill, paged/flash decode) — so a migration
is a real kernel swap.  The scheduler watches the synthetic host load,
pre-configures the ACCEL variant asynchronously at startup, and
migrates decode steps when the load crosses the threshold.

Requests are v2 ``GenerationRequest``s: half the stream samples with
per-request seeds (temperature 0.8, top-k 40) through the IN-GRAPH
sampler — the decode step keeps one static compile signature for any
request mix, and a seeded request reproduces the same tokens no matter
which target serves each step.  Results come back as ``RequestOutput``
(finish reason + TTFT/TPOT metrics), and one request is consumed as a
live token stream via its ``RequestHandle``.

    PYTHONPATH=src python examples/migration_serve.py [--backend auto]

Placement is a pluggable ``SchedulingPolicy`` (core/policy):
``--backend`` picks one — ``host``/``accel`` are the ``PinHost`` /
``PinAccel`` static policies, ``auto`` (default) is ``XarTrekHeuristic``
(Algorithm 2) fed by REAL engine telemetry: the engine publishes a
``LoadSignals`` snapshot (queue depth, free KV, per-target decode ms)
every loop iteration, and the synthetic co-tenant counter is merged in
as one more signal source.
"""
import argparse
import threading
import time

import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.policy import PinAccel, PinHost, XarTrekHeuristic
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.serve import (ContinuousBatchingEngine, GenerationRequest,
                         SamplingParams)
from repro.serve.scheduler import poisson_arrivals


def make_stream(vocab: int, n: int, rate_per_s: float, seed: int = 0):
    """Mixed stream: even requests greedy, odd requests sampled with a
    per-request seed — all through ONE decode signature."""
    rng = np.random.RandomState(seed)
    return [GenerationRequest(
        rng.randint(0, vocab, size=int(rng.randint(6, 28))),
        max_new_tokens=int(rng.randint(4, 16)), arrival_s=t,
        sampling=(SamplingParams(temperature=0.8, top_k=40, seed=seed * 100 + i)
                  if i % 2 else SamplingParams()))
        for i, t in enumerate(poisson_arrivals(n, rate_per_s, seed))]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("host", "accel", "auto"),
                    default="auto",
                    help="pin every step to one build, or let the "
                         "scheduler migrate (auto)")
    args = ap.parse_args()

    cfg = reduced(ARCHS["smollm-135m"])
    policy = {"host": PinHost(), "accel": PinAccel(),
              "auto": XarTrekHeuristic()}[args.backend]
    rt = XarTrekRuntime(registry=FunctionRegistry(),
                        min_reconfig_seconds=1.0 if args.backend == "auto"
                        else 0.0)
    # auto keeps the paper's asynchronous FPGA pre-configuration (the
    # latency-hiding demo below); only accel-pinned runs compile the
    # ACCEL build eagerly (host-pinned never calls it — don't stall on it)
    engine = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=96,
                                      runtime=rt, seed=0, policy=policy,
                                      eager_accel=args.backend == "accel")
    # threshold row for the decode step: ACCEL profitable once the real
    # load (queued requests + synthetic co-tenants) crosses ~6
    row = rt.table.row("cb_decode")
    row.fpga_thr, row.arm_thr = 6.0, 1e9

    # --- streaming demo: consume one request token-by-token while the
    # engine loop drains in another thread
    handle = engine.submit(np.arange(1, 11, dtype=np.int32) % cfg.vocab_size,
                           max_new_tokens=8,
                           sampling=SamplingParams(temperature=0.8,
                                                   top_k=40, seed=1234))
    t = threading.Thread(target=engine.run)
    t.start()
    streamed = [tok for tok in handle]          # blocks per token
    t.join()
    out = handle.result()
    print(f"streamed  : {streamed} finish={out.finish_reason} "
          f"ttft={out.ttft_s * 1e3:.0f}ms tpot={out.tpot_s * 1e3:.1f}ms")

    phases = [("low load", 0), ("high load", 6)]
    for pi, (phase, synthetic_load) in enumerate(phases):
        if pi == 1 and args.backend == "auto":
            # lull between phases: the asynchronous "reconfiguration"
            # (ACCEL compile) completes while traffic is elsewhere —
            # the paper's latency-hiding behaviour
            deadline = time.time() + 10.0
            while (not rt.bank.is_resident("cb_decode")
                   and time.time() < deadline):
                time.sleep(0.05)
        for _ in range(synthetic_load):      # synthetic co-tenants
            rt.monitor.job_started(TargetKind.HOST)
        mark = len(rt.call_log)
        reqs = make_stream(cfg.vocab_size, n=12, rate_per_s=30.0, seed=pi)
        t0 = time.perf_counter()
        outs = engine.run(reqs)
        dt = time.perf_counter() - t0
        for _ in range(synthetic_load):
            rt.monitor.job_finished(TargetKind.HOST)
        tokens = sum(o.n_tokens for o in outs.values())
        ttft = sorted(o.ttft_s for o in outs.values())
        targets = [rec["target"] for rec in rt.call_log[mark:]]
        finish = {}
        for o in outs.values():
            finish[o.finish_reason] = finish.get(o.finish_reason, 0) + 1
        print(f"{phase:10s}: {tokens / dt:7.1f} tok/s  "
              f"ttft_p50={ttft[len(ttft) // 2] * 1e3:.0f}ms "
              f"finish={finish}  "
              f"targets={dict((t, targets.count(t)) for t in set(targets))}")
    print("summary:", rt.summary())


if __name__ == "__main__":
    main()
