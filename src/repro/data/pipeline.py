"""Deterministic synthetic data pipeline with sharded host loading.

Every value is a pure function of (seed, step, GLOBAL row index), so any
restart — including an *elastic* restart onto a different mesh, or a
different sharding layout entirely — replays the identical stream (the
property tests/test_train_checkpoint.py and the elastic-restore test pin
down).  Each host process materialises only its device shards
(``make_array_from_callback``), the standard multi-host JAX loading
pattern; on this single-process container that degenerates gracefully.

The token stream is a per-row Markov chain (token[t] = f(token[t-1]) 75%
of the time) so smoke-training shows a falling loss.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.model_config import ModelConfig, ShapeConfig


def _row_tokens(seed: int, step: int, row: int, shape: tuple,
                vocab: int) -> np.ndarray:
    """Tokens for one global batch row (any trailing dims)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, row]))
    base = rng.integers(0, vocab, size=shape, dtype=np.int64)
    mix = rng.random(shape) < 0.75
    out = base.copy()
    for t in range(1, shape[-1]):
        out[..., t] = np.where(mix[..., t],
                               (out[..., t - 1] * 31 + 7) % vocab,
                               base[..., t])
    return out.astype(np.int32)


def _row_floats(seed: int, step: int, row: int, shape: tuple) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, row, 77]))
    return (rng.standard_normal(shape) * 0.02).astype(np.float32)


@dataclasses.dataclass
class SyntheticPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    mesh: Optional[Mesh] = None
    batch_spec: Optional[dict] = None     # PartitionSpecs per field

    def _field_shape(self, name: str) -> tuple:
        B, S = self.shape.global_batch, self.shape.seq_len
        K = self.cfg.num_codebooks
        if name in ("tokens", "labels"):
            if self.cfg.family == "audio" and K > 1:
                return (B, K, S)
            return (B, S)
        if name == "patch_embeds":
            return (B, min(self.cfg.num_patches, S), self.cfg.d_model)
        raise KeyError(name)

    def fields(self) -> list[str]:
        out = ["tokens", "labels"]
        if self.cfg.family == "vlm":
            out.append("patch_embeds")
        return out

    def _make_field(self, name: str, step: int) -> jax.Array:
        shape = self._field_shape(name)
        vocab = self.cfg.vocab_size

        def region(index: tuple) -> np.ndarray:
            """Values for one shard region, by GLOBAL row coordinates.

            Only the leading (batch) dim may be sharded by the batch
            specs; trailing dims are generated whole per row and sliced,
            so every layout sees identical values.
            """
            row_lo = index[0].start or 0
            row_hi = index[0].stop or shape[0]
            rows = []
            for r in range(row_lo, row_hi):
                if name == "patch_embeds":
                    rows.append(_row_floats(self.seed, step, r, shape[1:]))
                else:
                    toks = _row_tokens(self.seed, step, r, shape[1:], vocab)
                    if name == "labels":
                        toks = np.roll(toks, -1, axis=-1)
                    rows.append(toks)
            block = np.stack(rows)
            trailing = tuple(s for s in index[1:])
            return block[(slice(None),) + trailing]

        if self.mesh is None:
            full = region(tuple(slice(0, s) for s in shape))
            return jnp.asarray(full)
        from repro.parallel.sharding import named
        spec = (self.batch_spec or {}).get(name)
        sharding = named(self.mesh, spec if spec is not None else P())
        return jax.make_array_from_callback(shape, sharding, region)

    def batch(self, step: int) -> dict:
        out = {name: self._make_field(name, step) for name in self.fields()}
        if "patch_embeds" in out:
            out["patch_embeds"] = out["patch_embeds"].astype(jnp.bfloat16)
        return out


def make_global_batch(cfg: ModelConfig, shape: ShapeConfig, step: int = 0,
                      seed: int = 0, mesh: Optional[Mesh] = None,
                      batch_spec: Optional[dict] = None) -> dict:
    return SyntheticPipeline(cfg, shape, seed=seed, mesh=mesh,
                             batch_spec=batch_spec).batch(step)
