from repro.data.pipeline import SyntheticPipeline, make_global_batch
