"""Serving launcher: batched generation with the ServeEngine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \\
      --reduced --batch 4 --prompt-len 32 --new-tokens 16
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, reduced
    from repro.parallel.compat import make_mesh
    from repro.serve.engine import ServeEngine

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        mesh = make_mesh(dims, axes)

    engine = ServeEngine(cfg, mesh=mesh, seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    if cfg.family == "audio" and cfg.num_codebooks > 1:
        prompts = jax.random.randint(
            key, (args.batch, cfg.num_codebooks, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)
    else:
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            key, (args.batch, min(cfg.num_patches, args.prompt_len),
                  cfg.d_model), jnp.bfloat16)
    res = engine.generate(prompts, max_new_tokens=args.new_tokens, **kw)
    print(f"generated {res.tokens.shape} tokens | "
          f"prefill {res.prefill_ms:.0f} ms | decode {res.decode_ms:.0f} ms "
          f"| {res.tokens_per_second:.1f} tok/s")


if __name__ == "__main__":
    main()
