"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``cost_analysis`` gives per-partition FLOPs/bytes;
collective bytes are parsed from the post-SPMD optimized HLO text
(``compiled.as_text()``), summing the operand bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op (per-partition buffers, consistent with the other
two terms).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # v5e: 4 usable ICI links per chip (2D torus ring x2)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.3 = f32[128,256]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-buffer bytes per collective kind from optimized HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "all-" not in line and "reduce-scatter" not in line \
                and "collective-permute" not in line:
            continue
        if "-done(" in line:        # async pair: count the -start only
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        out[kind] += numel * nbytes
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_compute_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/padding/redundancy waste."""
        if self.flops_per_chip <= 0:
            return 0.0
        return self.model_flops_per_chip / self.flops_per_chip

    @property
    def roofline_fraction(self) -> float:
        """Useful-model-compute time over the perfect-overlap step bound —
        the §Perf score: how close the cell is to pure model-FLOPs
        compute at peak."""
        bound = self.step_time_lower_bound
        if bound <= 0:
            return 0.0
        return (self.model_flops_per_chip / PEAK_FLOPS) / bound

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops_per_chip": self.model_flops_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_compute_ratio": self.useful_compute_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, chips: int) -> float:
    """Analytic MODEL_FLOPS per chip for one step of the cell.

    train: 6·N·D (fwd+bwd), D = global tokens; prefill: 2·N·D;
    decode: 2·N·B tokens (one per sequence).  N excludes embedding
    tables (standard convention) and uses active params for MoE.
    """
    n_active = cfg.active_param_count()
    embed = cfg.vocab_size * cfg.d_model
    n = max(n_active - embed, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence + attention over the cache
        total = 2.0 * n * shape.global_batch
        if cfg.num_kv_heads and cfg.family != "ssm":
            hd = cfg.resolved_head_dim
            layers_attn = (cfg.num_layers if cfg.family != "hybrid"
                           else cfg.num_layers // max(cfg.attn_every, 1))
            # q @ K^T + p @ V over the cache
            total += (2.0 * 2.0 * shape.global_batch * layers_attn
                      * cfg.num_heads * hd * shape.seq_len)
    return total / chips
