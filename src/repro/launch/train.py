"""Training launcher.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \\
      --reduced --steps 100 --seq 256 --batch 8 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b \\
      --mesh 2x2 --devices 4 --reduced --steps 20

``--devices N`` forces N host devices (CPU testing); the production
path runs under real TPU runtime device counts.  ``--xartrek`` routes
steps through the migration runtime with HOST/AUX variants.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="assigned shape name (default: custom --seq/--batch)")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 or 2x2x2 (pod)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (tests restart)")
    ap.add_argument("--xartrek", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    from repro.configs import get_arch, get_shape, reduced
    from repro.configs.model_config import ShapeConfig, TrainConfig
    from repro.parallel.compat import make_mesh
    from repro.train.trainer import FailureInjector, Trainer

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = (get_shape(args.shape) if args.shape
             else ShapeConfig("custom", args.seq, args.batch, "train"))

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        mesh = make_mesh(dims, axes)

    tcfg = TrainConfig(microbatches=args.microbatches,
                       learning_rate=args.lr, seed=args.seed)
    trainer = Trainer(cfg, shape, tcfg, mesh=mesh, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, async_ckpt=args.async_ckpt,
                      total_steps=args.steps, seed=args.seed)

    if args.xartrek:
        from repro.core.function import FunctionRegistry
        from repro.core.runtime import XarTrekRuntime
        registry = FunctionRegistry()
        trainer.register_migratable(registry, aux_step=trainer.step_fn)
        runtime = XarTrekRuntime(mesh=mesh, registry=registry)
        params, opt_state = trainer.init_or_restore()[:2]
        batch = trainer.pipeline.batch(0)
        runtime.prepare("train_step", params, opt_state, batch)
        trainer.runtime = runtime

    injector = (FailureInjector(tuple(args.fail_at))
                if args.fail_at else None)
    log = trainer.run(steps=args.steps, injector=injector)
    print(f"final loss: {log[-1]['loss']:.4f} after {log[-1]['step']} steps")


if __name__ == "__main__":
    main()
