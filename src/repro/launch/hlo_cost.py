"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies
ONCE, so any lax.scan-structured model (layer stacking, microbatch
accumulation, blockwise attention, SSD chunk scans) is undercounted by
the loop trip counts — empirically 12-240x for our cells.  This module
re-derives FLOPs / HBM bytes / collective bytes by walking the
*optimized* post-SPMD HLO text with loop multipliers taken from the
``known_trip_count`` backend configs that the XLA CPU/TPU pipelines
attach to rolled loops.

Cost conventions (per partition, matching roofline usage):
  * dot: 2 x numel(result) x prod(contracting dims)   [MXU FLOPs]
  * elementwise / reduce: numel(result)               [VPU FLOPs]
  * HBM bytes use a TPU-fusion traffic model (the CPU backend's fusion
    is far weaker than TPU's, so counting every op boundary would
    overcount by ~10x):
      - dot/convolution: operands + result (weights/activations move);
      - data movement (copy, slices, gather): moved bytes x2;
      - dynamic-update-slice: only the updated slice moves (in-place);
      - elementwise / non-dot fusions / converts / reduces: result bytes
        only — on TPU these fuse into their producers, and their inputs
        are dot outputs already counted;
  * conditional: branch costs weighted by ``cond_weights`` (the caller
    knows e.g. that a hybrid runs its shared-attention branch on 1/6 of
    layers) — default 1/n_branches each;
  * collectives: result bytes x trips, per kind, reported separately.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "transpose",  # layout ops: bytes counted when fused/copied
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes_numel(type_str: str) -> tuple[int, int]:
    """Total (bytes, numel) across all arrays in a (possibly tuple) type."""
    total_b = total_n = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        total_b += numel * _DTYPE_BYTES[dtype]
        total_n += numel
    return total_b, total_n


def _first_array(type_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.collective_bytes:
            self.collective_bytes[k] += other.collective_bytes[k]
        return self

    def scaled(self, factor: float) -> "Cost":
        return Cost(self.flops * factor, self.bytes * factor,
                    {k: v * factor
                     for k, v in self.collective_bytes.items()})


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # operand list + attributes (raw tail of the line)


class HloProgram:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: dict[tuple, Cost] = {}

    def _parse(self, text: str) -> None:
        current = None
        for raw in text.splitlines():
            # strip /*index=N*/-style comments: they contain '=' and break
            # the op regex on wide tuple types
            line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
            stripped = line.strip()
            header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{",
                              stripped)
            if header and not stripped.startswith("//") and "=" not in \
                    stripped.split("(")[0]:
                current = header.group(2)
                self.computations[current] = []
                if header.group(1):
                    self.entry = current
                continue
            if stripped.startswith("}"):
                continue
            m = _OP_RE.match(line)
            if m and current is not None:
                name, type_str, opcode, rest = m.groups()
                self.computations[current].append(
                    _Op(name, type_str.strip(), opcode, rest))

    # ------------------------------------------------------------ costing
    def cost(self, cond_weight: float = 0.5) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self._computation_cost(self.entry, cond_weight, top=True)

    def _computation_cost(self, name: str, cw: float, top: bool,
                          in_loop: bool = False) -> Cost:
        key = (name, cw, top, in_loop)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        symbols = {op.name: op.type_str
                   for op in self.computations.get(name, ())}
        for op in self.computations.get(name, ()):
            total += self._op_cost(op, symbols, cw, top, in_loop)
        self._memo[key] = total
        return total

    def _has_dot(self, comp: str, _seen=None) -> bool:
        if not hasattr(self, "_dot_memo"):
            self._dot_memo = {}
        if comp in self._dot_memo:
            return self._dot_memo[comp]
        _seen = _seen or set()
        if comp in _seen:
            return False
        _seen.add(comp)
        result = False
        for op in self.computations.get(comp, ()):
            if op.opcode in ("dot", "convolution"):
                result = True
                break
            m = _CALL_ATTR.search(op.rest)
            if m and self._has_dot(m.group(1), _seen):
                result = True
                break
        self._dot_memo[comp] = result
        return result

    def _fused_dus_bytes(self, comp: str):
        """If the fused computation's root work is a dynamic-update-slice,
        return the update operand's bytes (else None)."""
        ops = self.computations.get(comp, ())
        symbols = {o.name: o.type_str for o in ops}
        for o in ops:
            if o.opcode == "dynamic-update-slice":
                refs = re.findall(r"%([\w.\-]+)", o.rest.split(")", 1)[0])
                if len(refs) > 1 and refs[1] in symbols:
                    return _shape_bytes_numel(symbols[refs[1]])[0]
                return _shape_bytes_numel(o.type_str)[0] * 0.01
        return None

    def _operand_bytes(self, op: _Op, symbols: dict) -> float:
        args = op.rest.split(")", 1)[0]
        total = 0
        for ref in re.findall(r"%([\w.\-]+)", args):
            if ref in symbols:
                total += _shape_bytes_numel(symbols[ref])[0]
        return total

    def _op_cost(self, op: _Op, symbols: dict, cw: float, top: bool,
                 in_loop: bool = False) -> Cost:
        oc = op.opcode
        res_bytes, res_numel = _shape_bytes_numel(op.type_str)
        c = Cost()

        if oc == "while":
            trips = 1
            m = _TRIP.search(op.rest)
            if m:
                trips = int(m.group(1))
            body = cond = None
            mb = re.search(r"body=%?([\w.\-]+)", op.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
            if mb:
                body = self._computation_cost(mb.group(1), cw, top,
                                              in_loop=True)
            if mc:
                cond = self._computation_cost(mc.group(1), cw, top,
                                              in_loop=True)
            if body:
                c += body.scaled(trips)
            if cond:
                c += cond.scaled(trips + 1)
            return c

        if oc == "conditional":
            branches = []
            mb = _BRANCHES.search(op.rest)
            if mb:
                branches = re.findall(r"%?([\w.\-]+)", mb.group(1))
            else:
                branches = [m.group(1) for m in re.finditer(
                    r"(?:true|false)_computation=%?([\w.\-]+)", op.rest)]
            if branches:
                costs = [self._computation_cost(b, cw, top, in_loop)
                         for b in branches]
                if len(costs) == 2:
                    # weight: cw on the heavier branch, 1-cw on the lighter
                    heavy, light = sorted(costs, key=lambda x: -x.flops)
                    c += heavy.scaled(cw)
                    c += light.scaled(1.0 - cw)
                else:
                    for b in costs:
                        c += b.scaled(1.0 / len(costs))
            return c

        if oc in ("call", "async-start"):
            m = _CALL_ATTR.search(op.rest)
            if m:
                c += self._computation_cost(m.group(1), cw, top, in_loop)
            return c

        if oc == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.rest)
            called = m.group(1) if m else None
            inner = (self._computation_cost(called, cw, top=False, in_loop=in_loop)
                     if called else Cost())
            c.flops += inner.flops
            for k, v in inner.collective_bytes.items():
                c.collective_bytes[k] += v
            if top:
                dus_bytes = self._fused_dus_bytes(called) if called else None
                if called and self._has_dot(called):
                    c.bytes += res_bytes + self._operand_bytes(op, symbols)
                elif dus_bytes is not None:
                    # fused dynamic-update-slice: in place on TPU — only
                    # the updated slice moves, not the whole buffer
                    c.bytes += 2.0 * dus_bytes
                else:
                    c.bytes += res_bytes      # elementwise fusion: write-only
            return c

        if any(oc.startswith(k) for k in _COLLECTIVES):
            kind = next(k for k in _COLLECTIVES if oc.startswith(k))
            if not oc.endswith("-done"):
                c.collective_bytes[kind] += res_bytes
                if top:
                    c.bytes += res_bytes + self._operand_bytes(op, symbols)
            return c

        if oc in _ZERO_COST:
            return c

        if oc == "dot":
            # resolve lhs operand shape for contracting size
            args = op.rest.split(")", 1)[0]
            refs = re.findall(r"%([\w.\-]+)", args)
            contract = 1
            mcd = _CONTRACT.search(op.rest)
            if refs and refs[0] in symbols and mcd:
                _, shape = _first_array(symbols[refs[0]])
                for d in mcd.group(1).split(","):
                    if d and int(d) < len(shape):
                        contract *= shape[int(d)]
            c.flops += 2.0 * res_numel * contract
            if top:
                c.bytes += res_bytes + self._operand_bytes(op, symbols)
            return c

        if oc == "convolution":
            args = op.rest.split(")", 1)[0]
            refs = re.findall(r"%([\w.\-]+)", args)
            kernel = 1
            if len(refs) > 1 and refs[1] in symbols:
                _, kshape = _first_array(symbols[refs[1]])
                for d in kshape:
                    kernel *= d
                # divide by output features (last dim of kernel, conv dnums
                # o dim) to get per-output-element macs
                if kshape:
                    kernel //= max(kshape[-1], 1)
            c.flops += 2.0 * res_numel * max(kernel, 1)
            if top:
                c.bytes += res_bytes + self._operand_bytes(op, symbols)
            return c

        if oc in ("dynamic-update-slice",):
            # in-place: only the update slice moves
            args = op.rest.split(")", 1)[0]
            refs = re.findall(r"%([\w.\-]+)", args)
            upd = (_shape_bytes_numel(symbols[refs[1]])[0]
                   if len(refs) > 1 and refs[1] in symbols else res_bytes)
            if top:
                c.bytes += 2.0 * upd
            return c

        if oc in ("copy", "copy-start", "copy-done"):
            # whole-carry copies inside rolled loops are a CPU-backend
            # double-buffering artifact; TPU aliases loop carries in place
            if top and not in_loop:
                c.bytes += 2.0 * res_bytes
            return c

        if oc in ("dynamic-slice", "gather", "slice"):
            if top:
                c.bytes += 2.0 * res_bytes
            return c

        # generic elementwise / reduce / convert / custom-call / rng / ...
        # (fused into producers on TPU: count the result write only)
        c.flops += float(res_numel)
        if top:
            c.bytes += res_bytes
        return c


def analyze(hlo_text: str, cond_weight: float = 0.5) -> dict:
    """Returns {"flops", "bytes", "collectives": {kind: bytes}} for one
    partition of the compiled program, loop trip counts included."""
    prog = HloProgram(hlo_text)
    c = prog.cost(cond_weight=cond_weight)
    return {"flops": c.flops, "bytes": c.bytes,
            "collectives": dict(c.collective_bytes)}
