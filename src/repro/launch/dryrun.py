import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces — with ShapeDtypeStruct inputs only, no
device allocation — the compiled SPMD executable plus:

  * ``memory_analysis()``  (bytes/device: proves the cell fits),
  * ``cost_analysis()``    (per-partition FLOPs / bytes accessed),
  * collective bytes parsed from the optimized HLO,
  * the derived roofline terms (launch/roofline.py).

Artifacts go to ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and are
skipped when already present (incremental; delete to re-run).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file cells.txt]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_applicable, get_arch, get_shape
from repro.configs.model_config import ModelConfig, ShapeConfig, TrainConfig
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineTerms, model_flops
from repro.models.model import Model, build_model
from repro.parallel.compat import peak_memory_bytes, use_mesh
from repro.train.step import make_train_step, train_step_shardings

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")

# Per-cell step configuration (memory-driven; see EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES: dict[str, int] = {
    "qwen1.5-32b": 16, "pixtral-12b": 16, "yi-6b": 8, "mamba2-2.7b": 8,
}
DEFAULT_TRAIN_MICROBATCHES = 8
# remat policy for train cells ("full" = recompute inside each layer;
# hillclimbed per-cell in EXPERIMENTS.md §Perf)
TRAIN_REMAT: dict[str, str] = {}
DEFAULT_TRAIN_REMAT = "full" 


def cell_name(arch: str, shape: str, multi_pod: bool,
              variant: str = "") -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{variant}" if variant else ""
    return f"{arch}__{shape}__{mesh}{suffix}"


def _ns(mesh, tree):
    from repro.parallel.sharding import named_tree
    return named_tree(mesh, tree)


def build_step(model: Model, cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (jitted_fn, example_specs) for the cell's step kind."""
    if shape.kind == "train":
        mb = TRAIN_MICROBATCHES.get(cfg.name, DEFAULT_TRAIN_MICROBATCHES)
        if cfg.sharding_recipe == "dp":
            mb = 1      # batch spreads over all axes; 1 sample/chip
        remat = TRAIN_REMAT.get(cfg.name, DEFAULT_TRAIN_REMAT)
        cfg = dataclasses.replace(cfg, remat=remat)
        model = dataclasses.replace(model, cfg=cfg)
        tcfg = TrainConfig(microbatches=mb)
        step = make_train_step(model, tcfg)
        in_s, out_s = train_step_shardings(model, tcfg, mesh)
        pshapes = model.shapes()
        oshapes = {
            "m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32),
                pshapes),
            "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32),
                pshapes),
            "step": jax.ShapeDtypeStruct((), jax.numpy.int32),
        }
        batch = model.input_specs(shape)
        jitted = jax.jit(step, in_shardings=in_s, out_shardings=out_s,
                         donate_argnums=(0, 1))
        return jitted, (pshapes, oshapes, batch)

    pspecs = _ns(mesh, model.specs())
    pshapes = model.shapes()
    bspecs = _ns(mesh, model.batch_spec(shape.global_batch))
    batch = model.input_specs(shape)

    if shape.kind == "prefill":
        bspec_sub = {k: bspecs[k] for k in batch}
        # explicit out_shardings: without them GSPMD replicated the cache
        # output across the model axis (41 GiB/chip on qwen; §Perf 3)
        logits_s = _ns(mesh, model.fitted_rules(shape.global_batch)
                       .spec("batch", None, None))
        cache_s = _ns(mesh, model.cache_specs(shape.global_batch))
        jitted = jax.jit(model.prefill, in_shardings=(pspecs, bspec_sub),
                         out_shardings=(logits_s, cache_s))
        return jitted, (pshapes, batch)

    # decode: serve_step(params, cache, token_batch)
    cache_specs = _ns(mesh, model.cache_specs(shape.global_batch))
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    tok_spec = _ns(mesh, {k: v
                          for k, v in model.batch_spec(shape.global_batch).items()
                          if k == "tokens"})
    tok_spec["index"] = NamedSharding(mesh, P())
    jitted = jax.jit(model.decode,
                     in_shardings=(pspecs, cache_specs, tok_spec),
                     donate_argnums=(1,))
    return jitted, (pshapes, cache_shapes, batch)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = ARTIFACT_DIR, verbose: bool = True,
             overrides: dict | None = None, variant: str = "") -> dict:
    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    ok, reason = cell_applicable(cfg, shape)
    record = {"arch": arch, "shape": shape_name, "variant": variant,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "status": "skipped", "reason": reason}
    name = cell_name(arch, shape_name, multi_pod, variant)
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, name + ".json")
    if not ok:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        if verbose:
            print(f"[dryrun] {name}: SKIP ({reason})")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    model = build_model(cfg, mesh)

    t0 = time.perf_counter()
    with use_mesh(mesh):
        jitted, specs = build_step(model, cfg, shape, mesh)
        lowered = jitted.lower(*specs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    # XLA's cost_analysis counts loop bodies once; re-derive with loop
    # multipliers from the optimized HLO (launch/hlo_cost.py).
    cond_weight = (1.0 / cfg.attn_every if cfg.family == "hybrid" else 0.5)
    walked = hlo_analyze(compiled.as_text(), cond_weight=cond_weight)
    coll = walked["collectives"]

    flops, bts = walked["flops"], walked["bytes"]
    adjustment = None
    if variant.endswith("flash"):
        # ACCEL variant: swap the attention function's terms for the
        # Pallas kernel's analytic profile (launch/kernel_model.py)
        from repro.launch.kernel_model import flash_adjustment
        mb = (TRAIN_MICROBATCHES.get(cfg.name, DEFAULT_TRAIN_MICROBATCHES)
              if shape.kind == "train" else 1)
        if cfg.sharding_recipe == "dp" and shape.kind == "train":
            mb = 1
        tp = (1 if cfg.sharding_recipe == "dp" else mesh.shape["model"])
        dp = chips // tp
        adj = flash_adjustment(cfg, shape, chips=chips, tp=tp, dp=dp,
                               microbatches=mb)
        flops += adj.d_flops
        bts += adj.d_bytes
        adjustment = {"ref_attn_flops": adj.ref_flops,
                      "ref_attn_bytes": adj.ref_bytes,
                      "kernel_attn_flops": adj.kernel_flops,
                      "kernel_attn_bytes": adj.kernel_bytes}

    terms = RooflineTerms(
        flops_per_chip=flops,
        hbm_bytes_per_chip=bts,
        collective_bytes_per_chip=sum(coll.values()),
        model_flops_per_chip=model_flops(cfg, shape, chips),
    )

    record.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": peak_memory_bytes(mem),
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_xla_once": {k: v for k, v in cost.items()
                          if "flops" in k or k == "bytes accessed"},
        "collectives": coll,
        "kernel_adjustment": adjustment,
        "roofline": terms.as_dict(),
    })
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    if verbose:
        m = record["memory"]
        r = record["roofline"]
        print(f"[dryrun] {name}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s "
              f"peak={m['peak_bytes']/2**30:.2f}GiB/chip "
              f"args={m['argument_bytes']/2**30:.2f}GiB "
              f"bottleneck={r['bottleneck']} "
              f"roofline_frac={r['roofline_fraction']:.3f}")
    return record


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="'flash' -> ACCEL kernel-substituted roofline")
    args = ap.parse_args()
    overrides = ({"sharding_recipe": "dp"} if args.variant.startswith("dp")
                 else None)

    cells = (all_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            out_path = os.path.join(
                args.out, cell_name(arch, shape, mp, args.variant) + ".json")
            if os.path.exists(out_path) and not args.force:
                print(f"[dryrun] {cell_name(arch, shape, mp, args.variant)}: cached")
                continue
            try:
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         overrides=overrides, variant=args.variant)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] {cell_name(arch, shape, mp)}: FAIL {e!r}")
                traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested cells OK")


if __name__ == "__main__":
    main()
