"""Production mesh construction (assignment-required entry point).

Functions only — importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.parallel.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)
