"""Kernel-substituted roofline terms for the ACCEL (Pallas) variant.

The dry-run lowers the HOST (reference) program and derives its roofline
from the compiled HLO.  The ACCEL variant swaps the attention *function*
for the Pallas flash kernel at the Xar-Trek function boundary; its
roofline is the HOST walk with the attention contribution replaced by
the kernel's analytic profile (derived from the kernel's BlockSpec
tiling — auditable below).  Interpret-mode lowering of the kernel is a
Python emulation and does not represent the TPU lowering, so it is not
used for cost analysis (it IS used for correctness tests).

Reference attention cost per (layer, pass), per chip, causal:
  flops_ref  = 2 dots x 2 * Bc * Hc * S^2/2 * hd        (blockwise/causal)
  bytes_ref  = Bc * Hc * S^2/2 * (4+4+2+2)              (f32 scores w+r,
                                                         bf16 probs w+r)
Kernel (block_q = block_k = 256, VMEM-resident accumulators):
  flops_knl  = same dot flops (the MXU work is identical)
  bytes_knl  = q + o + nq * (k + v)                     (K/V re-streamed
                                                         once per q-block)
Training passes: fwd + full-remat recompute use the kernel; the backward
uses the reference VJP (a dedicated bwd kernel is future work), so the
bwd score traffic (~2 fwd passes worth) remains in BOTH variants.
"""
from __future__ import annotations

import dataclasses

from repro.configs.model_config import ModelConfig, ShapeConfig

BLOCK = 256


@dataclasses.dataclass
class AttnAdjustment:
    ref_flops: float
    ref_bytes: float
    kernel_flops: float
    kernel_bytes: float

    @property
    def d_flops(self) -> float:
        return self.kernel_flops - self.ref_flops

    @property
    def d_bytes(self) -> float:
        return self.kernel_bytes - self.ref_bytes


def _attention_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // max(cfg.attn_every, 1)
    return cfg.num_layers


def flash_adjustment(cfg: ModelConfig, shape: ShapeConfig, *,
                     chips: int, tp: int, dp: int,
                     microbatches: int = 1) -> AttnAdjustment:
    """Per-chip attention-term swap for one step of the cell."""
    S = shape.seq_len
    heads_padded = -(-max(cfg.num_heads, 1) // tp) * tp
    Hc = heads_padded // tp
    hd = cfg.resolved_head_dim
    L = _attention_layers(cfg)
    if L == 0 or shape.kind == "decode":
        return AttnAdjustment(0, 0, 0, 0)

    B_step = shape.global_batch // max(dp, 1)        # per-chip batch
    Bc = B_step // microbatches if shape.kind == "train" else B_step
    n_mb = microbatches if shape.kind == "train" else 1

    # fwd passes using the fused path: train = fwd + full-remat recompute
    fwd_passes = 2.0 if shape.kind == "train" else 1.0
    # bwd stays on the reference VJP in both variants (cancels out) — but
    # the REF fwd passes' materialisation is what the kernel removes.

    # the HOST path is plain full-square attention at S <= 8192 and the
    # causal block schedule above that (models/attention.py:attention)
    live_frac = (1.0 if S <= 8192
                 else 0.5 + BLOCK / (2.0 * S))
    pairs_elems = Bc * Hc * S * S * live_frac
    knl_pairs = Bc * Hc * S * S * (0.5 + BLOCK / (2.0 * S))

    ref_flops = 2.0 * 2.0 * pairs_elems * hd * fwd_passes * L * n_mb
    knl_flops = 2.0 * 2.0 * knl_pairs * hd * fwd_passes * L * n_mb

    ref_bytes = pairs_elems * (4 + 4 + 2 + 2) * fwd_passes * L * n_mb
    nq = S // BLOCK
    qo = 2.0 * Bc * Hc * S * hd * 2                  # q read + o write, bf16
    kv = 2.0 * nq * Bc * Hc * S * hd * 2             # K+V per q-block pass
    knl_bytes = (qo + kv) * fwd_passes * L * n_mb
    return AttnAdjustment(ref_flops=ref_flops, ref_bytes=ref_bytes,
                          kernel_flops=knl_flops, kernel_bytes=knl_bytes)
