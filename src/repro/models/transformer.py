"""Unified decoder-only transformer: dense / MoE / VLM / audio families.

Layer weights are stacked along a leading L dim and the layer loop is a
``lax.scan`` (compile time O(1) in depth; enables remat policies).  MoE
uses shard_map expert-parallelism over the ``model`` axis: activations
are replicated across TP, so each model shard routes the same tokens to
*its* experts locally and the combine is a single psum — no all-to-all
required (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.model_config import ModelConfig
from repro.models import attention as attn_lib
from repro.models.common import (
    ParamDef, apply_rope, cross_entropy, gelu_mlp, rmsnorm, swiglu,
)
from repro.parallel.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS, batch_axes
from repro.parallel.sharding import (
    DEFAULT_RULES, ShardingRules, padded_size,
)

from repro.parallel.compat import shard_map  # noqa: F401  (re-exported)


# --------------------------------------------------------------- geometry

@dataclasses.dataclass(frozen=True)
class Geometry:
    """TP-padded sizes derived from (config, mesh tp size)."""

    tp: int
    heads: int            # padded query heads
    kv_heads: int         # (unpadded; replicated across TP)
    vocab: int            # padded vocab
    shard_kv: bool        # kv heads TP-shardable

    @staticmethod
    def of(cfg: ModelConfig, tp: int) -> "Geometry":
        hp = padded_size(max(cfg.num_heads, 1), tp)
        vp = padded_size(cfg.vocab_size, tp)
        if cfg.num_heads and cfg.num_kv_heads == cfg.num_heads:
            # MHA: pad KV heads along with Q heads and shard both —
            # replicated K/V projections cost 4.4e14 extra FLOPs/chip on
            # the qwen prefill cell (EXPERIMENTS.md §Perf 3)
            return Geometry(tp=tp, heads=hp, kv_heads=hp, vocab=vp,
                            shard_kv=True)
        return Geometry(tp=tp, heads=hp, kv_heads=cfg.num_kv_heads,
                        vocab=vp, shard_kv=False)


def kv_index_for(cfg: ModelConfig, geom: Geometry):
    """Static q-head -> kv-head map, or None when identity (incl. the
    MHA-padded case where both are padded identically)."""
    if geom.kv_heads == geom.heads:
        return None
    return attn_lib.kv_head_index(cfg.num_heads, cfg.num_kv_heads,
                                  geom.heads)


def make_rules(geom: Geometry, recipe: str = "tp") -> ShardingRules:
    if recipe == "dp":
        # pure data parallelism: batch over every mesh axis, weights and
        # caches replicated; right when the model fits one chip
        rules = dict(DEFAULT_RULES)
        for k in ("vocab", "heads", "kv_heads", "mlp", "experts",
                  "ssm_inner", "ssm_heads", "cache_seq"):
            rules[k] = None
        rules["batch"] = (POD_AXIS, DATA_AXIS, MODEL_AXIS)
        return ShardingRules(rules)
    rules = dict(DEFAULT_RULES)
    if geom.shard_kv:
        # shard the cache on heads instead of sequence (a spec may use
        # each mesh axis once)
        rules["kv_heads"] = MODEL_AXIS
        rules["cache_seq"] = None
    return ShardingRules(rules)


# ------------------------------------------------------------- param defs

def transformer_defs(cfg: ModelConfig, geom: Geometry) -> dict:
    d, L, ff = cfg.d_model, cfg.num_layers, cfg.d_ff
    hd = cfg.resolved_head_dim
    Hp, KV, Vp = geom.heads, geom.kv_heads, geom.vocab
    H = cfg.num_heads

    attn = {
        "wq": ParamDef((L, d, Hp, hd), ("layers", "embed", "heads", "head_dim"),
                       "scaled", mask_dims={2: H}),
        "wk": ParamDef((L, d, KV, hd), ("layers", "embed", "kv_heads", "head_dim"),
                       "scaled", mask_dims={2: cfg.num_kv_heads}),
        "wv": ParamDef((L, d, KV, hd), ("layers", "embed", "kv_heads", "head_dim"),
                       "scaled", mask_dims={2: cfg.num_kv_heads}),
        "wo": ParamDef((L, Hp, hd, d), ("layers", "heads", "head_dim", "embed"),
                       "scaled", mask_dims={1: H}),
    }
    if cfg.qkv_bias:
        attn["bq"] = ParamDef((L, Hp, hd), ("layers", "heads", "head_dim"),
                              "zeros", mask_dims={1: H})
        attn["bk"] = ParamDef((L, KV, hd), ("layers", "kv_heads", "head_dim"),
                              "zeros", mask_dims={1: cfg.num_kv_heads})
        attn["bv"] = ParamDef((L, KV, hd), ("layers", "kv_heads", "head_dim"),
                              "zeros", mask_dims={1: cfg.num_kv_heads})

    if cfg.family == "moe":
        E = cfg.num_experts
        mlp = {
            "router": ParamDef((L, d, E), ("layers", "embed", None), "scaled"),
            "w_gate": ParamDef((L, E, d, ff),
                               ("layers", "experts", "embed", "expert_mlp"), "scaled"),
            "w_up": ParamDef((L, E, d, ff),
                             ("layers", "experts", "embed", "expert_mlp"), "scaled"),
            "w_down": ParamDef((L, E, ff, d),
                               ("layers", "experts", "expert_mlp", "embed"), "scaled"),
        }
    elif cfg.mlp_type == "swiglu":
        mlp = {
            "w_gate": ParamDef((L, d, ff), ("layers", "embed", "mlp"), "scaled"),
            "w_up": ParamDef((L, d, ff), ("layers", "embed", "mlp"), "scaled"),
            "w_down": ParamDef((L, ff, d), ("layers", "mlp", "embed"), "scaled"),
        }
    else:  # gelu
        mlp = {
            "w_in": ParamDef((L, d, ff), ("layers", "embed", "mlp"), "scaled"),
            "w_out": ParamDef((L, ff, d), ("layers", "mlp", "embed"), "scaled"),
        }

    layers = {
        "attn": attn,
        "mlp": mlp,
        "ln1": ParamDef((L, d), ("layers", "embed"), "ones", dtype="float32"),
        "ln2": ParamDef((L, d), ("layers", "embed"), "ones", dtype="float32"),
    }

    K = max(cfg.num_codebooks, 1)
    if cfg.family == "audio" and K > 1:
        embed = {"table": ParamDef((K, Vp, d), ("codebooks", "vocab", "embed"),
                                   "normal", mask_dims={1: cfg.vocab_size})}
        head = {"w": ParamDef((K, d, Vp), ("codebooks", "embed", "vocab"), "scaled")}
    else:
        embed = {"table": ParamDef((Vp, d), ("vocab", "embed"), "normal",
                                   mask_dims={0: cfg.vocab_size})}
        head = ({} if cfg.tie_embeddings
                else {"w": ParamDef((d, Vp), ("embed", "vocab"), "scaled")})

    return {
        "embed": embed,
        "layers": layers,
        "final_norm": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "head": head,
    }


# ---------------------------------------------------------------- blocks

def qkv_project(x, lp, cfg: ModelConfig, geom: Geometry, positions):
    """x: (B,S,d) -> q (B,S,Hp,hd), k, v (B,S,KV,hd) with RoPE applied."""
    ap = lp["attn"]
    q = jnp.einsum("bsd,dhk->bshk", x, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, ap["wv"])
    if cfg.qkv_bias:
        q = q + ap["bq"]
        k = k + ap["bk"]
        v = v + ap["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(x, lp, cfg: ModelConfig, geom: Geometry, *,
                    positions, mode: str, cache_kv=None, cache_index=None,
                    mesh=None, backend: str = "xla"):
    """Returns (out, (k_new, v_new)).  x: (B,S,d).

    For decode, ``cache_kv`` must ALREADY contain the new token's k/v at
    ``cache_index`` (callers write-then-attend so the token sees itself).
    cfg.attn_impl selects the HOST ("ref") or ACCEL ("flash" Pallas
    kernel) implementation for train/prefill; ``backend="pallas"``
    forces the Pallas kernel regardless of cfg (the per-call ACCEL
    selector the serve engine threads through).
    """
    q, k, v = qkv_project(x, lp, cfg, geom, positions)
    kv_idx = kv_index_for(cfg, geom)
    if mode == "decode":
        k_cache, v_cache = cache_kv
        out = attn_lib.decode_attention(q, k_cache, v_cache, cache_index,
                                        kv_index=kv_idx, backend=backend)
    elif backend == "pallas" or cfg.attn_impl == "flash":
        out = attn_lib.flash_attention_sharded(q, k, v, mesh,
                                               kv_index=kv_idx)
    else:
        out = attn_lib.attention(q, k, v, causal=True, kv_index=kv_idx)
    out = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
    return out, (k, v)


def dense_mlp_block(x, lp, cfg: ModelConfig):
    mp = lp["mlp"]
    if cfg.mlp_type == "swiglu":
        return swiglu(x, mp["w_gate"], mp["w_up"], mp["w_down"])
    return gelu_mlp(x, mp["w_in"], mp["w_out"])


# ------------------------------------------------------------------- MoE

def _local_moe(x_flat, router, w_gate, w_up, w_down, cfg: ModelConfig,
               expert_offset: int, num_experts_total: int, capacity: int):
    """Route T tokens to local experts; returns (partial_out, aux_stats).

    x_flat: (T, d); w_*: (E_loc, ...).  Partial output must be psum'd over
    the model axis by the caller (each shard only applies its experts).
    """
    T, d = x_flat.shape
    E_loc = w_gate.shape[0]
    k = cfg.top_k
    logits = jnp.einsum("td,de->te", x_flat, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_w, top_ids = jax.lax.top_k(probs, k)                     # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # Assignments hitting this shard's experts.
    local = (top_ids >= expert_offset) & (top_ids < expert_offset + E_loc)
    local_ids = jnp.where(local, top_ids - expert_offset, E_loc)  # E_loc = drop bin

    # Position of each assignment within its expert (capacity-limited).
    onehot = jax.nn.one_hot(local_ids, E_loc, dtype=jnp.int32)    # (T, k, E_loc)
    flat_oh = onehot.reshape(T * k, E_loc)
    pos = jnp.cumsum(flat_oh, axis=0) * flat_oh                   # rank+1 where set
    pos_in_expert = (jnp.sum(pos, axis=-1) - 1).reshape(T, k)     # -1 where dropped
    expert_of = local_ids
    keep = local & (pos_in_expert >= 0) & (pos_in_expert < capacity)

    # Scatter tokens into (E_loc, C, d) buffers.
    buf = jnp.zeros((E_loc, capacity, d), x_flat.dtype)
    e_idx = jnp.where(keep, expert_of, 0)
    c_idx = jnp.where(keep, pos_in_expert, 0)
    src = jnp.repeat(x_flat[:, None, :], k, axis=1)               # (T, k, d)
    src = jnp.where(keep[..., None], src, 0)
    buf = buf.at[e_idx.reshape(-1), c_idx.reshape(-1)].add(
        src.reshape(T * k, d), mode="drop")

    # Per-expert FFN.
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)               # (E_loc, C, d)

    # Combine back to token order with routing weights.
    gathered = out_buf[e_idx.reshape(-1), c_idx.reshape(-1)].reshape(T, k, d)
    w = jnp.where(keep, top_w, 0.0).astype(gathered.dtype)
    y = jnp.sum(gathered * w[..., None], axis=1)                  # (T, d)

    # Switch-style aux load-balance stats (computed on full routing).
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_ids[:, 0], num_experts_total), axis=0)
    aux = jnp.sum(me * ce) * num_experts_total
    return y, aux


def moe_block(x, lp, cfg: ModelConfig, mesh: Optional[jax.sharding.Mesh]):
    """x: (B,S,d) -> (y, aux_loss)."""
    B, S, d = x.shape
    mp = lp["mlp"]
    E = cfg.num_experts

    if (mesh is None or MODEL_AXIS not in getattr(mesh, "axis_names", ())
            or cfg.sharding_recipe == "dp"):
        cap = max(int(cfg.capacity_factor * B * S * cfg.top_k / E), cfg.top_k)
        y, aux = _local_moe(x.reshape(B * S, d), mp["router"], mp["w_gate"],
                            mp["w_up"], mp["w_down"], cfg, 0, E, cap)
        return y.reshape(B, S, d), aux

    tp = mesh.shape[MODEL_AXIS]
    bdims = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in bdims])) if bdims else 1
    E_loc = E // tp
    T_loc = (B // dp) * S
    cap = max(int(cfg.capacity_factor * T_loc * cfg.top_k / E), cfg.top_k)

    def shard_fn(xs, router, w_gate, w_up, w_down):
        T = xs.shape[0] * xs.shape[1]
        idx = jax.lax.axis_index(MODEL_AXIS)
        offset = idx * E_loc
        y, aux = _local_moe(xs.reshape(T, d), router, w_gate, w_up, w_down,
                            cfg, offset, E, cap)
        y = jax.lax.psum(y, MODEL_AXIS)           # combine expert partials
        aux = jax.lax.pmean(aux, MODEL_AXIS)
        if bdims:
            aux = jax.lax.pmean(aux, bdims)
        return y.reshape(xs.shape), aux

    y, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bdims or None, None, None), P(None, None),
                  P(MODEL_AXIS, None, None), P(MODEL_AXIS, None, None),
                  P(MODEL_AXIS, None, None)),
        out_specs=(P(bdims or None, None, None), P()),
        check_vma=False,
    )(x, mp["router"], mp["w_gate"], mp["w_up"], mp["w_down"])
    return y, aux


# ------------------------------------------------------------ layer body

def layer_body(x, lp, cfg: ModelConfig, geom: Geometry, mesh, *,
               positions, mode: str, cache_kv=None, cache_index=None,
               backend: str = "xla"):
    h, kv = attention_block(rmsnorm(x, lp["ln1"], cfg.norm_eps), lp, cfg, geom,
                            positions=positions, mode=mode,
                            cache_kv=cache_kv, cache_index=cache_index,
                            mesh=mesh, backend=backend)
    x = x + h
    if cfg.family == "moe":
        h, aux = moe_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, cfg, mesh)
    else:
        h = dense_mlp_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, cfg)
        aux = jnp.zeros((), jnp.float32)
    return x + h, kv, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "nothing":
        return fn
    if cfg.remat == "dots":
        # no-batch-dims: saves weight-matmul outputs but RECOMPUTES the
        # (S x S)-shaped attention dots — saving those stacks an
        # O(L*B*S^2) tensor across the layer scan (catastrophic at 4k+)
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# --------------------------------------------------------------- embed/IO

def embed_inputs(params, batch: dict, cfg: ModelConfig) -> jax.Array:
    table = params["embed"]["table"]
    if cfg.family == "audio" and cfg.num_codebooks > 1:
        # tokens: (B, K, S); sum the K codebook embeddings.
        toks = batch["tokens"]
        x = jnp.zeros(toks.shape[:1] + toks.shape[2:] + (cfg.d_model,),
                      table.dtype)
        for c in range(cfg.num_codebooks):
            x = x + jnp.take(table[c], toks[:, c], axis=0)
        return x
    x = jnp.take(table, batch["tokens"], axis=0)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    return x


def output_logits(params, x, cfg: ModelConfig) -> jax.Array:
    if cfg.family == "audio" and cfg.num_codebooks > 1:
        return jnp.einsum("bsd,kdv->bskv", x, params["head"]["w"])
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["head"]["w"])
    return jnp.einsum("bsd,dv->bsv", x, w)


def lm_loss(logits, batch, cfg: ModelConfig) -> jax.Array:
    if cfg.family == "audio" and cfg.num_codebooks > 1:
        labels = jnp.moveaxis(batch["labels"], 1, 2)      # (B,S,K)
        return cross_entropy(logits, labels, cfg.vocab_size)
    mask = None
    if cfg.family == "vlm":
        S = batch["labels"].shape[1]
        mask = (jnp.arange(S) >= cfg.num_patches)[None, :].astype(jnp.float32)
        mask = jnp.broadcast_to(mask, batch["labels"].shape)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size, mask)


# ------------------------------------------------------------- full model

def _write_kv_layer(stack, new, li, cache_index):
    """Write ``new`` (B,1,KV,hd-or-1) into layer ``li`` of a cache stack
    (L,B,Smax,KV,hd-or-1) at ``cache_index``: a shared scalar position, or
    a (B,) vector of ragged per-row positions (continuous batching)."""
    if cache_index.ndim:
        return jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice(
                c, n[None].astype(c.dtype), (li, i, 0, 0)),
            in_axes=(1, 0, 0), out_axes=1)(stack, new, cache_index)
    return jax.lax.dynamic_update_slice(
        stack, new.astype(stack.dtype)[None], (li, 0, cache_index, 0, 0))


def _write_kv_block(stack, new, li, blk, off):
    """Scatter the new token's KV (B,1,KV,hd) into layer ``li`` of the
    block pool at per-row (physical block, offset).  Rows sharing a
    target (inactive rows all hit junk block 0 offset 0) are benign:
    nothing ever reads the junk block.  A lane-aligned pool (hd padded
    to 128 at allocation) zero-pads the per-token write — cheap, unlike
    padding the whole pool per read."""
    if new.shape[-1] != stack.shape[-1]:
        new = jnp.pad(new, ((0, 0),) * (new.ndim - 1)
                      + ((0, stack.shape[-1] - new.shape[-1]),))
    return stack.at[li, blk, off].set(new[:, 0].astype(stack.dtype))


def forward(params, batch, cfg: ModelConfig, geom: Geometry, mesh, *,
            mode: str, cache: dict | None = None, backend: str = "xla"):
    """mode: train | prefill | decode.

    Decode reads a dense (L,B,Smax,KV,hd) cache, or — when the batch
    carries a ``block_table`` — a paged (L,NB,BS,KV,hd) block pool.
    ``backend`` selects the attention implementation for prefill/decode:
    "xla" (HOST reference) or "pallas" (ACCEL kernels — flash prefill,
    flash-decoding / paged-streaming decode).  A paged int8 pool keeps
    the selector (its ACCEL build is the int8-dequantising paged
    kernel); DENSE int8 decode still ignores it and runs XLA math.
    Returns (logits, new_cache_or_None, aux_loss).
    """
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    if mode == "decode":
        # index: scalar () for position-synchronised decode, or (B,) for
        # ragged continuous-batching decode (each row at its own position)
        idx = batch["index"]
        positions = jnp.broadcast_to(
            idx[:, None] if idx.ndim else idx, (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    lp_stack = params["layers"]

    if mode == "decode":
        cache_index = batch["index"]
        kv_idx = kv_index_for(cfg, geom)

        attn_index = (cache_index[:, None, None, None] if cache_index.ndim
                      else cache_index)

        def body(carry, lp):
            x, ck, cv, li, aux = carry
            xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = qkv_project(xn, lp, cfg, geom, positions)
            # read the OLD cache, pass the new token explicitly, then write
            # — independent read/write lets XLA alias the carried cache
            # in place instead of copying it per layer (§Perf 2)
            kc = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
            out = attn_lib.decode_attention(
                q, kc.astype(x.dtype), vc.astype(x.dtype), attn_index,
                kv_index=kv_idx, k_new=k, v_new=v, backend=backend)
            ck = _write_kv_layer(ck, k, li, cache_index)
            cv = _write_kv_layer(cv, v, li, cache_index)
            x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
            if cfg.family == "moe":
                h, a = moe_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp,
                                 cfg, mesh)
            else:
                h = dense_mlp_block(rmsnorm(x, lp["ln2"], cfg.norm_eps),
                                    lp, cfg)
                a = jnp.zeros((), jnp.float32)
            return (x + h, ck, cv, li + 1, aux + a), None

        if "block_table" in batch:
            return _forward_decode_paged(params, batch, cfg, geom, mesh,
                                         cache, x, positions,
                                         backend=backend)
        if cache["k"].dtype == jnp.int8:
            return _forward_decode_int8(params, batch, cfg, geom, mesh,
                                        cache, x, positions)
        (x, ck, cv, _, aux), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"], jnp.int32(0), jnp.zeros((), jnp.float32)),
            lp_stack)
        new_cache = dict(cache, k=ck, v=cv)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return output_logits(params, x, cfg), new_cache, aux

    def body(x_aux, lp):
        x, aux = x_aux
        x, kv, a = layer_body(x, lp, cfg, geom, mesh, positions=positions,
                              mode=mode, backend=backend)
        if mode == "prefill":
            return (x, aux + a), kv
        return (x, aux + a), None

    body_fn = _remat(body, cfg) if mode == "train" else body
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 lp_stack)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = output_logits(params, x, cfg)

    new_cache = None
    if mode == "prefill":
        k_all, v_all = kvs  # (L, B, S, KV, hd)
        if cfg.kv_cache_dtype == "int8":
            from repro.models.common import quantize_int8
            kq, ks = quantize_int8(k_all, axis=-1)
            vq, vs = quantize_int8(v_all, axis=-1)
            new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            cdt = jnp.dtype(cfg.kv_cache_dtype)
            new_cache = {"k": k_all.astype(cdt), "v": v_all.astype(cdt)}
    return logits, new_cache, aux


def _forward_decode_int8(params, batch, cfg, geom, mesh, cache, x, positions):
    """Decode-layer scan with an int8-quantised KV cache (write-then-attend)."""
    cache_index = batch["index"]
    kv_idx = kv_index_for(cfg, geom)
    from repro.models.common import dequantize_int8, quantize_int8
    attn_index = (cache_index[:, None, None, None] if cache_index.ndim
                  else cache_index)

    def body(carry, lp):
        x, ck, cv, ks, vs, li, aux = carry
        xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = qkv_project(xn, lp, cfg, geom, positions)
        # read-old / explicit-new-token / write (aliasing-friendly; §Perf 2)
        kc = dequantize_int8(
            jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(ks, li, 0, keepdims=False), x.dtype)
        vc = dequantize_int8(
            jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(vs, li, 0, keepdims=False), x.dtype)
        out = attn_lib.decode_attention(q, kc, vc, attn_index,
                                        kv_index=kv_idx, k_new=k, v_new=v)
        kq, ksc = quantize_int8(k, axis=-1)
        vq, vsc = quantize_int8(v, axis=-1)
        ck = _write_kv_layer(ck, kq, li, cache_index)
        cv = _write_kv_layer(cv, vq, li, cache_index)
        ks = _write_kv_layer(ks, ksc, li, cache_index)
        vs = _write_kv_layer(vs, vsc, li, cache_index)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        if cfg.family == "moe":
            h, a = moe_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, cfg, mesh)
        else:
            h = dense_mlp_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, cfg)
            a = jnp.zeros((), jnp.float32)
        return (x + h, ck, cv, ks, vs, li + 1, aux + a), None

    (x, ck, cv, ks, vs, _, aux), _ = jax.lax.scan(
        body,
        (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"],
         jnp.int32(0), jnp.zeros((), jnp.float32)),
        params["layers"])
    new_cache = {"k": ck, "v": cv, "k_scale": ks, "v_scale": vs}
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return output_logits(params, x, cfg), new_cache, aux


def _forward_decode_paged(params, batch, cfg, geom, mesh, cache, x, positions,
                          backend: str = "xla"):
    """Decode-layer scan over a paged (block-pool) KV cache.

    batch carries ragged per-row state: ``index`` (B,) logical write
    positions and ``block_table`` (B, NBT) physical block ids.  Each
    layer attends the row's blocks in logical order with the
    explicit-new-token path (write-then-attend preserved: the pool
    never contributes the current position — it is masked by ``index``
    — and the new token's KV is passed to attention directly, then
    scattered into the pool).  Math is identical to the dense body; only
    the cache addressing differs, so greedy tokens match byte-for-byte
    when the attention span (NBT * BS) equals the dense max_seq.

    backend="xla" gathers each row's blocks into a logical-order cache
    per layer (HOST); backend="pallas" hands the pool plus the block
    table to the paged decode kernel, which streams the blocks in-kernel
    with no materialised gather (ACCEL).

    An int8 pool (``"k_scale" in cache``) quantises on write — each
    token independently, symmetric over head_dim — scattering q values
    and scales into their parallel pools, and attends over the raw int8
    pool plus scales (HOST dequantises the gathered rows; ACCEL
    dequantises in-kernel).  The current token still enters attention
    at full precision (write-then-attend: it is not read back from the
    pool this step).
    """
    cache_index = batch["index"]                   # (B,)
    table = batch["block_table"]                   # (B, NBT) int32
    bs = cache["k"].shape[2]
    int8 = "k_scale" in cache
    kv_idx = kv_index_for(cfg, geom)
    blk = jnp.take_along_axis(table, (cache_index // bs)[:, None],
                              axis=1)[:, 0]        # (B,) physical block
    off = cache_index % bs
    from repro.models.common import quantize_int8

    def body(carry, lp):
        if int8:
            x, ck, cv, ks, vs, li, aux = carry
        else:
            x, ck, cv, li, aux = carry
        xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = qkv_project(xn, lp, cfg, geom, positions)
        kcp = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
        vcp = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
        if int8:
            out = attn_lib.paged_decode_attention(
                q, kcp, vcp, table, cache_index, k_new=k, v_new=v,
                kv_index=kv_idx, backend=backend,
                k_scale=jax.lax.dynamic_index_in_dim(ks, li, 0,
                                                     keepdims=False),
                v_scale=jax.lax.dynamic_index_in_dim(vs, li, 0,
                                                     keepdims=False))
            kq, ksc = quantize_int8(k, axis=-1)
            vq, vsc = quantize_int8(v, axis=-1)
            ck = _write_kv_block(ck, kq, li, blk, off)
            cv = _write_kv_block(cv, vq, li, blk, off)
            ks = _write_kv_block(ks, ksc, li, blk, off)
            vs = _write_kv_block(vs, vsc, li, blk, off)
        else:
            out = attn_lib.paged_decode_attention(
                q, kcp.astype(x.dtype), vcp.astype(x.dtype), table,
                cache_index, k_new=k, v_new=v, kv_index=kv_idx,
                backend=backend)
            ck = _write_kv_block(ck, k, li, blk, off)
            cv = _write_kv_block(cv, v, li, blk, off)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        if cfg.family == "moe":
            h, a = moe_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp,
                             cfg, mesh)
        else:
            h = dense_mlp_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, cfg)
            a = jnp.zeros((), jnp.float32)
        if int8:
            return (x + h, ck, cv, ks, vs, li + 1, aux + a), None
        return (x + h, ck, cv, li + 1, aux + a), None

    if int8:
        (x, ck, cv, ks, vs, _, aux), _ = jax.lax.scan(
            body,
            (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"],
             jnp.int32(0), jnp.zeros((), jnp.float32)),
            params["layers"])
        new_cache = dict(cache, k=ck, v=cv, k_scale=ks, v_scale=vs)
    else:
        (x, ck, cv, _, aux), _ = jax.lax.scan(
            body,
            (x, cache["k"], cache["v"], jnp.int32(0),
             jnp.zeros((), jnp.float32)),
            params["layers"])
        new_cache = dict(cache, k=ck, v=cv)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return output_logits(params, x, cfg), new_cache, aux


def forward_prefill_paged(params, batch, cfg, geom, mesh, cache,
                          backend: str = "xla", attn_fn=None):
    """Chunked prefill over a paged KV cache (prefix caching).

    The chunk's tokens EXTEND a prefix already resident in the block
    pool: batch carries ``tokens`` (B, W) — the uncached span, bucketed
    — plus ``offset`` (B,) first uncached position, ``length`` (B,)
    total feed length, and ``block_table`` (B, NBT) whose first
    ``offset // BS`` entries are the cached (possibly shared) blocks.
    Each layer attends chunk queries at absolute positions
    ``offset + j`` over pool positions [0, offset) plus the chunk
    itself, causally (``attn_lib.paged_prefill_attention``).

    The pool is READ-ONLY here and the chunk's per-layer KV is RETURNED
    (like prefill's cache), not written: matched prefix blocks are
    shared across slots and must not be mutated, so the engine scatters
    the returned chunk KV into the slot's private blocks explicitly.
    Returns (logits (B, W, V), chunk_cache {"k","v"} (L, B, W, KV, hd),
    aux).  With ``offset == 0`` (no cache hit) this degenerates to the
    bucketed dense prefill bit-for-bit: every pool column is masked
    (exact-zero softmax terms), and positions/causality match.

    An int8 pool (``"k_scale" in cache``) dequantises the gathered
    context per layer and the returned chunk cache is quantised
    (``{"k","v","k_scale","v_scale"}``) so the engine's scatter writes
    pool-dtype leaves — note the chunk attends over ROUNDED prefix KV,
    which is exactly why lossy pools sit behind
    ``allow_lossy_prefix_cache`` (serve/README.md tolerance story).

    ``attn_fn`` swaps the per-layer attention implementation (default
    ``attn_lib.paged_prefill_attention``); it must share that ABI.
    ``forward_verify_paged`` uses it to thread the verify-named wrapper
    through the same body.
    """
    if attn_fn is None:
        attn_fn = attn_lib.paged_prefill_attention
    x = embed_inputs(params, batch, cfg)
    B, W = x.shape[0], x.shape[1]
    offset = batch["offset"].astype(jnp.int32)         # (B,)
    length = batch["length"].astype(jnp.int32)         # (B,)
    table = batch["block_table"]                       # (B, NBT)
    positions = offset[:, None] + jnp.arange(W)[None, :]
    kv_idx = kv_index_for(cfg, geom)
    int8 = "k_scale" in cache

    def body(x_aux, xs):
        x, aux = x_aux
        if int8:
            lp, kcp, vcp, kscp, vscp = xs
        else:
            lp, kcp, vcp = xs
            kscp = vscp = None
        xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = qkv_project(xn, lp, cfg, geom, positions)
        out = attn_fn(
            q, kcp, vcp, table, offset, length, k_new=k, v_new=v,
            kv_index=kv_idx, backend=backend, k_scale=kscp, v_scale=vscp)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        if cfg.family == "moe":
            h, a = moe_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp,
                             cfg, mesh)
        else:
            h = dense_mlp_block(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, cfg)
            a = jnp.zeros((), jnp.float32)
        return (x + h, aux + a), (k, v)

    xs = ((params["layers"], cache["k"], cache["v"],
           cache["k_scale"], cache["v_scale"]) if int8
          else (params["layers"], cache["k"], cache["v"]))
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    k_all, v_all = kvs                                  # (L, B, W, KV, hd)
    if cfg.kv_cache_dtype == "int8":
        from repro.models.common import quantize_int8
        kq, ks = quantize_int8(k_all, axis=-1)
        vq, vs = quantize_int8(v_all, axis=-1)
        chunk_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    else:
        cdt = jnp.dtype(cfg.kv_cache_dtype)
        chunk_cache = {"k": k_all.astype(cdt), "v": v_all.astype(cdt)}
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return output_logits(params, x, cfg), chunk_cache, aux


def forward_verify_paged(params, batch, cfg, geom, mesh, cache,
                         backend: str = "xla"):
    """Speculative-decode verify forward: score W candidate tokens per
    row in one batched step over the paged pool.

    Identical body to ``forward_prefill_paged`` — verify IS chunk
    prefill at offset (batch: ``tokens`` (B, W) = the fed candidates,
    ``offset`` (B,) committed position, ``length`` (B,) =
    ``offset + n_valid``, ``block_table``) — but attention routes
    through ``attn_lib.paged_verify_attention`` so the ACCEL build hits
    the verify-named Pallas wrapper and the runtime accounts verify
    calls separately from chunked prefill.  Returns the full
    (logits (B, W, V), chunk_cache (L, B, W, KV, hd), aux) triple; the
    caller samples every column (positions ``offset + 1 + j``) and
    scatters only the accepted prefix's KV.
    """
    return forward_prefill_paged(params, batch, cfg, geom, mesh, cache,
                                 backend=backend,
                                 attn_fn=attn_lib.paged_verify_attention)
