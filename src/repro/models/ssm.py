"""Mamba2 (SSD — state-space duality) blocks, pure JAX reference.

The chunked SSD algorithm [arXiv:2405.21060]: sequence is split into
chunks; intra-chunk term is a (masked) quadratic attention-like matmul,
inter-chunk term is a linear recurrence over per-chunk states.  This
module is the HOST/oracle path; ``repro.kernels.ssd_scan`` is the ACCEL
Pallas kernel implementing the same tiling in VMEM.

Decode is the O(1)-state recurrent form: the "KV cache" is a constant
size (conv_state, ssd_state) pair — which is why the long_500k cell is
runnable for SSM/hybrid archs only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.model_config import ModelConfig
from repro.models.common import ParamDef, rmsnorm


def ssm_defs(cfg: ModelConfig, num_layers: int | None = None) -> dict:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    L = num_layers if num_layers is not None else cfg.num_layers
    ck = cfg.conv_kernel
    return {
        "in_z": ParamDef((L, d, di), ("layers", "embed", "ssm_inner"), "scaled"),
        "in_x": ParamDef((L, d, di), ("layers", "embed", "ssm_inner"), "scaled"),
        "in_B": ParamDef((L, d, ns), ("layers", "embed", "ssm_state"), "scaled"),
        "in_C": ParamDef((L, d, ns), ("layers", "embed", "ssm_state"), "scaled"),
        "in_dt": ParamDef((L, d, nh), ("layers", "embed", "ssm_heads"), "scaled"),
        "conv_x": ParamDef((L, di, ck), ("layers", "ssm_inner", "conv_kernel"),
                           "normal", scale=0.3),
        "conv_B": ParamDef((L, ns, ck), ("layers", "ssm_state", "conv_kernel"),
                           "normal", scale=0.3),
        "conv_C": ParamDef((L, ns, ck), ("layers", "ssm_state", "conv_kernel"),
                           "normal", scale=0.3),
        "A_log": ParamDef((L, nh), ("layers", "ssm_heads"), "zeros", dtype="float32"),
        "D": ParamDef((L, nh), ("layers", "ssm_heads"), "ones", dtype="float32"),
        "dt_bias": ParamDef((L, nh), ("layers", "ssm_heads"), "zeros",
                            dtype="float32"),
        "gate_norm": ParamDef((L, di), ("layers", "ssm_inner"), "ones",
                              dtype="float32"),
        "out": ParamDef((L, di, d), ("layers", "ssm_inner", "embed"), "scaled"),
        "ln": ParamDef((L, d), ("layers", "embed"), "ones", dtype="float32"),
    }


def causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv.  x: (B,S,C), w: (C,K).

    With ``state`` ((B, C, K-1) trailing inputs) performs the streaming
    update for decode; returns (y, new_state).
    """
    B, S, C = x.shape
    K = w.shape[-1]
    if state is None:
        pad = jnp.zeros((B, K - 1, C), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
        new_state = jnp.moveaxis(xp[:, -(K - 1):, :], 1, 2) if K > 1 else None
    else:
        xp = jnp.concatenate([jnp.moveaxis(state, 1, 2).astype(x.dtype), x],
                             axis=1)
        new_state = jnp.moveaxis(xp[:, -(K - 1):, :], 1, 2)
    idx = jnp.arange(S)[:, None] + jnp.arange(K)[None, :]
    windows = xp[:, idx, :]                             # (B, S, K, C)
    y = jnp.einsum("bskc,ck->bsc", windows, w.astype(x.dtype))
    return jax.nn.silu(y), new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., l, h) -> (..., h, l, l) lower-tri pairwise sums of a."""
    l = a.shape[-2]
    a = jnp.moveaxis(a, -1, -2)                         # (..., h, l)
    cs = jnp.cumsum(a, axis=-1)
    # L[i, j] = sum over (j, i] of a  (decay applied strictly after step j)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD scan (reference oracle).

    x:  (B, S, H, P)   dt-discretised below
    dt: (B, S, H)      positive step sizes
    A:  (H,)           negative decay rates
    Bm: (B, S, N), Cm: (B, S, N)   (ngroups=1, broadcast over heads)
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, Pdim = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xd = (x * dt[..., None]).astype(jnp.float32)
    a = (A[None, None, :] * dt).astype(jnp.float32)     # (B,S,H) log-decay

    xc = xd.reshape(Bsz, nc, chunk, H, Pdim)
    ac = a.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)

    # 1) intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(ac))                         # (B,nc,H,l,l)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)      # (B,nc,l,l)
    Y_diag = jnp.einsum("bchls,bcls,bcshp->bclhp", Lmat, scores, xc)

    # 2) per-chunk states
    a_cum = jnp.cumsum(ac, axis=2)                      # (B,nc,l,H)
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,l,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xc)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])           # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, Pdim, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s_prev, inp):
        dec, st = inp
        s = s_prev * dec[:, :, None, None] + st
        return s, s_prev

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # 4) state -> output contribution
    state_decay = jnp.exp(a_cum)                        # (B,nc,l,H)
    Y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)

    y = (Y_diag + Y_off).reshape(Bsz, S, H, Pdim)
    return y.astype(x.dtype), final


def ssd_recurrent_step(state, x_t, dt_t, A, B_t, C_t):
    """One decode step.  state: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H);
    B_t, C_t: (B,N).  Returns (y_t, new_state)."""
    a = jnp.exp(A[None, :] * dt_t).astype(jnp.float32)          # (B,H)
    xd = (x_t * dt_t[..., None]).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xd, B_t.astype(jnp.float32))
    new_state = state * a[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new_state


def mamba_mix(x, lp, cfg: ModelConfig, *, mode: str, conv_state=None,
              ssd_state=None):
    """Full Mamba2 mixer.  x: (B,S,d) -> (y, (conv_state, ssd_state))."""
    B, S, d = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim

    z = jnp.einsum("bsd,de->bse", x, lp["in_z"])
    xs = jnp.einsum("bsd,de->bse", x, lp["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", x, lp["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", x, lp["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, lp["in_dt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])

    if mode == "decode":
        cs_x, cs_B, cs_C = conv_state
        xs, ncs_x = causal_conv(xs, lp["conv_x"], cs_x)
        Bm, ncs_B = causal_conv(Bm, lp["conv_B"], cs_B)
        Cm, ncs_C = causal_conv(Cm, lp["conv_C"], cs_C)
        new_conv = (ncs_x, ncs_B, ncs_C)
    else:
        xs, ncs_x = causal_conv(xs, lp["conv_x"])
        Bm, ncs_B = causal_conv(Bm, lp["conv_B"])
        Cm, ncs_C = causal_conv(Cm, lp["conv_C"])
        new_conv = (ncs_x, ncs_B, ncs_C)

    A = -jnp.exp(lp["A_log"])                            # (nh,)
    xh = xs.reshape(B, S, nh, hd)

    if mode == "decode":
        y, new_state = ssd_recurrent_step(
            ssd_state, xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]                                   # (B,1,nh,hd)
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm,
                                   chunk=min(cfg.ssm_chunk, S))

    y = y + xh.astype(jnp.float32) * lp["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, lp["out"])
    return out, (new_conv, new_state)


def init_ssm_cache(cfg: ModelConfig, num_layers: int, batch: int) -> dict:
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    k = cfg.conv_kernel - 1
    dt = jnp.dtype(cfg.dtype)
    return {
        "conv_x": jnp.zeros((num_layers, batch, di, k), dt),
        "conv_B": jnp.zeros((num_layers, batch, ns, k), dt),
        "conv_C": jnp.zeros((num_layers, batch, ns, k), dt),
        "ssd": jnp.zeros((num_layers, batch, nh, hd, ns), jnp.float32),
    }


def ssm_cache_specs(rules) -> dict:
    return {
        "conv_x": rules.spec("layers", "batch", "ssm_inner", None),
        "conv_B": rules.spec("layers", "batch", "ssm_state", None),
        "conv_C": rules.spec("layers", "batch", "ssm_state", None),
        "ssd": rules.spec("layers", "batch", "ssm_heads", None, None),
    }
