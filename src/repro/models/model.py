"""Unified model API over all 10 assigned architectures.

``Model`` exposes pure functions (init / loss / prefill / decode) plus
their PartitionSpecs; callers (train/serve/launch) jit them with the
appropriate shardings.  Nothing here touches devices at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


import jax
import jax.numpy as jnp

from repro.configs.model_config import ModelConfig, ShapeConfig
from repro.models import hybrid as hybrid_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tf_lib
from repro.models.common import (
    init_params, param_shapes, param_specs, rmsnorm,
)
from repro.models.transformer import Geometry, make_rules
from repro.parallel.mesh import MODEL_AXIS


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    geom: Geometry
    mesh: Optional[jax.sharding.Mesh]

    # ------------------------------------------------------------ params
    @property
    def rules(self):
        return make_rules(self.geom, self.cfg.sharding_recipe)

    def fitted_rules(self, global_batch: Optional[int] = None):
        """Rules with the batch axes fitted to ``global_batch``: axes whose
        product doesn't divide B are dropped (e.g. long_500k's B=1 cell
        replicates the batch dim; the decode_32k B=128 cell shards it over
        pod x data = 32)."""
        rules = self.rules
        if self.mesh is None or global_batch is None:
            return rules
        from repro.parallel.mesh import DATA_AXIS, POD_AXIS
        axes = [a for a in (POD_AXIS, DATA_AXIS)
                if a in self.mesh.axis_names]
        if self.cfg.sharding_recipe == "dp":
            from repro.parallel.mesh import MODEL_AXIS
            axes = axes + [MODEL_AXIS]
        candidates = [tuple(axes)]
        if DATA_AXIS in axes:
            candidates.append((DATA_AXIS,))
        for cand in candidates:
            prod = 1
            for a in cand:
                prod *= self.mesh.shape[a]
            if cand and global_batch % prod == 0:
                rules.rules = dict(rules.rules, batch=cand)
                return rules
        rules.rules = dict(rules.rules, batch=None)
        return rules

    def defs(self) -> dict:
        cfg, geom = self.cfg, self.geom
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            return tf_lib.transformer_defs(cfg, geom)
        base = tf_lib.transformer_defs(
            dataclasses.replace(cfg, family="dense"), geom)
        out = {"embed": base["embed"], "final_norm": base["final_norm"],
               "head": base["head"]}
        if cfg.family == "ssm":
            out["mamba"] = ssm_lib.ssm_defs(cfg)
        elif cfg.family == "hybrid":
            out.update(hybrid_lib.hybrid_defs(cfg, geom))
        else:
            raise ValueError(cfg.family)
        return out

    def init(self, key) -> dict:
        return init_params(key, self.defs(), jnp.dtype(self.cfg.dtype))

    def specs(self) -> dict:
        return param_specs(self.defs(), self.rules)

    def shapes(self) -> dict:
        return param_shapes(self.defs(), jnp.dtype(self.cfg.dtype))

    # ----------------------------------------------------------- forward
    def _core(self, params, x, *, mode: str, positions, cache):
        cfg, geom, mesh = self.cfg, self.geom, self.mesh
        if cfg.family == "ssm":
            return _ssm_core(params, x, cfg, mode=mode, cache=cache)
        if cfg.family == "hybrid":
            return hybrid_lib.hybrid_forward_core(
                params, x, cfg, geom, mesh, mode=mode, positions=positions,
                cache=cache)
        raise ValueError(cfg.family)

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            logits, _, aux = tf_lib.forward(params, batch, cfg, self.geom,
                                            self.mesh, mode="train")
        else:
            x = tf_lib.embed_inputs(params, batch, cfg)
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1])[None, :], x.shape[:2])
            x, _ = self._core(params, x, mode="train", positions=positions,
                              cache=None)
            x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
            logits = tf_lib.output_logits(params, x, cfg)
            aux = jnp.zeros((), jnp.float32)
        loss = tf_lib.lm_loss(logits, batch, cfg)
        total = loss + 0.01 * aux
        return total, {"lm_loss": loss, "aux_loss": aux}

    def prefill(self, params, batch, backend: str = "xla"
                ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            logits, cache, _ = tf_lib.forward(params, batch, cfg, self.geom,
                                              self.mesh, mode="prefill",
                                              backend=backend)
            return logits[:, -1:], cache
        x = tf_lib.embed_inputs(params, batch, cfg)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                                     x.shape[:2])
        x, cache = self._core(params, x, mode="prefill", positions=positions,
                              cache=None)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = tf_lib.output_logits(params, x[:, -1:], cfg)
        return logits, cache

    def prefill_at(self, params, batch, backend: str = "xla"
                   ) -> tuple[jax.Array, dict]:
        """Prefill over right-padded prompts (continuous batching's shape
        buckets).  batch: {"tokens": (B, S_pad), "length": (B,) int32 real
        prompt lengths}.  Returns logits at each row's last REAL position
        (causal masking makes right-padding invisible to positions before
        it) and the full padded-cache — callers slice [:length) per row.
        ``backend``: "xla" reference attention or "pallas" flash kernel.
        Attention families only (ssm/hybrid state has no per-row seek)."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise NotImplementedError(
                f"prefill_at: {cfg.family} caches are position-synchronised")
        fwd = {k: v for k, v in batch.items() if k != "length"}
        logits, cache, _ = tf_lib.forward(params, fwd, cfg, self.geom,
                                          self.mesh, mode="prefill",
                                          backend=backend)
        idx = batch["length"].astype(jnp.int32) - 1          # (B,)
        if cfg.family == "audio" and cfg.num_codebooks > 1:
            last = jnp.take_along_axis(
                logits, idx[:, None, None, None], axis=1)    # (B,1,K,V)
        else:
            last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)
        return last, cache

    def prefill_at_sampled(self, params, batch, backend: str = "xla"
                           ) -> tuple[jax.Array, jax.Array, dict]:
        """``prefill_at`` with in-graph per-request sampling of the first
        generated token.  ``batch`` additionally carries the (B,) sampling
        vectors (see models/sampling.SAMPLING_KEYS); the token's absolute
        position is the prompt length, so its PRNG key —
        ``fold_in(PRNGKey(seed), length)`` — is identical on every
        backend and across preempt/resume re-prefills.  Returns
        ((B,) int32 tokens, (B,) f32 chosen-token logprobs, cache) —
        the logprob is always computed (cheap: one log_softmax gather)
        so the compile signature stays static whether or not the
        request asked for it."""
        from repro.models import sampling as sampling_lib
        fwd = {k: v for k, v in batch.items()
               if k not in sampling_lib.SAMPLING_KEYS}
        last, cache = self.prefill_at(params, fwd, backend=backend)
        if last.ndim != 3:
            raise NotImplementedError(
                "in-graph sampling supports single-codebook logits only")
        toks, logps = sampling_lib.sample_tokens(
            last[:, -1, :], batch["temperature"], batch["top_k"],
            batch["top_p"], batch["seed"], batch["length"])
        return toks, logps, cache

    def prefill_ctx_sampled(self, params, cache, batch,
                            backend: str = "xla"
                            ) -> tuple[jax.Array, jax.Array, dict]:
        """Chunked prefill against a paged cache holding the feed's
        cached prefix (prefix caching), with in-graph sampling of the
        first generated token.

        batch: {"tokens": (B, W_pad) uncached span (bucketed),
        "offset": (B,) first uncached position, "length": (B,) total
        feed length, "block_table": (B, NBT)} plus the (B,) sampling
        vectors.  The chunk's last REAL token sits at column
        ``length - offset - 1``; the sampled token's absolute position
        is ``length`` — the SAME position convention as
        ``prefill_at_sampled``, so a cached and an uncached admission
        of the same request draw the identical seeded token.  Returns
        ((B,) tokens, (B,) logprobs, chunk_cache (L, B, W, KV, hd)) —
        the chunk KV is returned for the caller to scatter into
        private blocks (shared prefix blocks are never written here).
        Attention families only, like ``prefill_at``."""
        from repro.models import sampling as sampling_lib
        cfg = self.cfg
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise NotImplementedError(
                f"prefill_ctx: {cfg.family} caches are "
                "position-synchronised")
        fwd = {k: v for k, v in batch.items()
               if k not in sampling_lib.SAMPLING_KEYS}
        logits, chunk_cache, _ = tf_lib.forward_prefill_paged(
            params, fwd, cfg, self.geom, self.mesh, cache,
            backend=backend)
        if logits.ndim != 3:
            raise NotImplementedError(
                "in-graph sampling supports single-codebook logits only")
        idx = (batch["length"] - batch["offset"]).astype(jnp.int32) - 1
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)
        toks, logps = sampling_lib.sample_tokens(
            last[:, -1, :], batch["temperature"], batch["top_k"],
            batch["top_p"], batch["seed"], batch["length"])
        return toks, logps, chunk_cache

    def decode_verify(self, params, cache, batch, backend: str = "xla"
                      ) -> tuple[jax.Array, jax.Array, dict]:
        """Speculative-decode verify step: score W candidate tokens per
        row in ONE forward over the paged pool, sample EVERY column, and
        scatter the candidates' KV into the pool in-graph.

        batch: {"tokens": (B, W) candidates — column 0 is the row's
        committed last token, columns 1.. the drafted tokens;
        "offset": (B,) committed KV position (column j sits at absolute
        position offset + j); "length": (B,) = offset + n_valid;
        "n_valid": (B,) real candidate count (rows shrunk below W by
        policy/budget pad with masked columns); "block_table": (B, NBT)}
        plus the (B,) sampling vectors.  Column j samples the token at
        absolute position ``offset + j + 1`` with the SAME
        ``fold_in(seed, position)`` key sequential decode would use —
        that is the whole byte-identity argument: the verify pass
        re-derives exactly the tokens one-at-a-time decode would have
        produced, and the engine keeps the longest drafted prefix that
        matches them.

        The candidates' per-layer KV is scattered into the (donated)
        pool here, masked to ``n_valid`` — rejected-tail columns beyond
        a row's real span land in the junk block 0, so rollback is free:
        nothing ever reads them (pool-junk isolation is tested).
        Returns ((B, W) int32 tokens, (B, W) f32 logprobs, new_cache).
        """
        from repro.models import sampling as sampling_lib
        fwd = {k: v for k, v in batch.items()
               if k not in sampling_lib.SAMPLING_KEYS and k != "n_valid"}
        logits, chunk_cache, _ = tf_lib.forward_verify_paged(
            params, fwd, self.cfg, self.geom, self.mesh, cache,
            backend=backend)
        if logits.ndim != 3:
            raise NotImplementedError(
                "in-graph sampling supports single-codebook logits only")
        B, W = batch["tokens"].shape
        offset = batch["offset"].astype(jnp.int32)
        n_valid = batch["n_valid"].astype(jnp.int32)
        pos = offset[:, None] + 1 + jnp.arange(W)[None, :]      # (B, W)
        rep = {k: jnp.repeat(batch[k], W)
               for k in sampling_lib.SAMPLING_KEYS}
        toks, logps = sampling_lib.sample_tokens(
            logits.reshape(B * W, -1), rep["temperature"], rep["top_k"],
            rep["top_p"], rep["seed"], pos.reshape(-1))
        toks = toks.reshape(B, W)
        logps = logps.reshape(B, W).astype(jnp.float32)

        # fused candidate-KV scatter: columns < n_valid write their
        # logical position's (block, offset); masked columns write the
        # junk block (0, 0) — duplicate junk writes are benign
        table = batch["block_table"]
        NBT = table.shape[1]
        bs = cache["k"].shape[2]
        posn = offset[:, None] + jnp.arange(W)[None, :]          # (B, W)
        valid = ((jnp.arange(W)[None, :] < n_valid[:, None])
                 & (posn // bs < NBT))
        blk = jnp.where(
            valid,
            jnp.take_along_axis(table, jnp.clip(posn // bs, 0, NBT - 1),
                                axis=1), 0)
        off = jnp.where(valid, posn % bs, 0)
        new_cache = dict(cache)
        for name, part in chunk_cache.items():
            pool = cache[name]
            if part.shape[-1] != pool.shape[-1]:     # lane-aligned pool
                part = jnp.pad(part, ((0, 0),) * (part.ndim - 1)
                               + ((0, pool.shape[-1] - part.shape[-1]),))
            new_cache[name] = pool.at[:, blk, off].set(
                part.astype(pool.dtype))
        return toks, logps, new_cache

    def decode_draft(self, params, cache, batch, backend: str = "xla", *,
                     max_steps: int = 4
                     ) -> tuple[jax.Array, jax.Array, dict]:
        """Fused draft chain for speculative decoding: run up to
        ``max_steps`` chained single-token decode steps in ONE dispatch,
        feeding each sampled token back in.

        batch: {"tokens": (B, 1) the committed last token, "index": (B,)
        its dense-cache write position, "n_steps": () traced live step
        count (max over the batch's per-row draft budgets — lowers to a
        while_loop, so shrinking k never recompiles)} plus the (B,)
        sampling vectors.  Step i feeds its token at position
        ``index + i`` and samples position ``index + i + 1`` with the
        standard fold_in key, exactly like sequential decode on the
        draft model.  Returns ((B, max_steps) int32 drafts — column i
        is the token sampled at ``index + i + 1``; steps >= n_steps
        leave zeros —, matching (B, max_steps) f32 logprobs, and the
        updated dense draft cache, whose write frontier advances to
        ``index + n_steps`` (the last sampled token is NOT written).
        """
        from repro.models import sampling as sampling_lib
        B = batch["tokens"].shape[0]
        idx0 = batch["index"].astype(jnp.int32)                  # (B,)
        n_steps = jnp.minimum(batch["n_steps"].astype(jnp.int32),
                              max_steps)
        drafts0 = jnp.zeros((B, max_steps), jnp.int32)
        logps0 = jnp.zeros((B, max_steps), jnp.float32)

        def step(i, carry):
            tok, cache, drafts, logps = carry
            logits, cache = self.decode(
                params, cache, {"tokens": tok, "index": idx0 + i},
                backend=backend)
            t, lp = sampling_lib.sample_tokens(
                logits[:, -1, :], batch["temperature"], batch["top_k"],
                batch["top_p"], batch["seed"], idx0 + i + 1)
            drafts = jax.lax.dynamic_update_slice(
                drafts, t[:, None].astype(jnp.int32), (0, i))
            logps = jax.lax.dynamic_update_slice(
                logps, lp[:, None].astype(jnp.float32), (0, i))
            return (t[:, None].astype(jnp.int32), cache, drafts, logps)

        _, cache, drafts, logps = jax.lax.fori_loop(
            0, n_steps, step,
            (batch["tokens"].astype(jnp.int32), cache, drafts0, logps0))
        return drafts, logps, cache

    def decode_sampled(self, params, cache, batch, backend: str = "xla"
                       ) -> tuple[jax.Array, jax.Array, dict]:
        """``decode`` with in-graph per-request sampling fused into the
        step: the returned value is the (B,) int32 next tokens (plus
        their (B,) f32 chosen-token logprobs), not logits, so host code
        never re-implements the sampling math and both HOST/ACCEL
        builds trace the identical transform.  The sampled token's
        absolute position is ``index + 1`` (the fed token's KV lands at
        ``index``; the new token sits one past it), matching
        ``prefill_at_sampled``'s position convention.  The sampling
        vectors are (B,) data leaves — one static compile signature
        regardless of the request mix (binary.shape_key), and the
        logprob leaf is always present so opting in to logprobs never
        forks the signature."""
        from repro.models import sampling as sampling_lib
        fwd = {k: v for k, v in batch.items()
               if k not in sampling_lib.SAMPLING_KEYS}
        logits, new_cache = self.decode(params, cache, fwd, backend=backend)
        if logits.ndim != 3:
            raise NotImplementedError(
                "in-graph sampling supports single-codebook logits only")
        idx = batch["index"]
        B = logits.shape[0]
        pos = (idx if idx.ndim else jnp.broadcast_to(idx, (B,))) + 1
        toks, logps = sampling_lib.sample_tokens(
            logits[:, -1, :], batch["temperature"], batch["top_k"],
            batch["top_p"], batch["seed"], pos)
        return toks, logps, new_cache

    def decode(self, params, cache, batch, backend: str = "xla"
               ) -> tuple[jax.Array, dict]:
        """batch: {"tokens": (B,1)|(B,K,1), "index": scalar int32}.

        Attention families additionally accept ``index`` as a (B,) int32
        vector of per-row positions for ragged continuous-batching decode
        (ssm/hybrid caches remain position-synchronised), and a
        ``backend`` selector: "xla" (HOST reference) or "pallas" (ACCEL —
        the flash-decoding / paged-streaming Pallas kernels)."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            logits, new_cache, _ = tf_lib.forward(
                params, batch, cfg, self.geom, self.mesh, mode="decode",
                cache=cache, backend=backend)
            return logits, new_cache
        x = tf_lib.embed_inputs(params, batch, cfg)
        positions = jnp.broadcast_to(batch["index"], x.shape[:2])
        cache_in = dict(cache, index=batch["index"])
        x, new_cache = self._core(params, x, mode="decode",
                                  positions=positions, cache=cache_in)
        new_cache.pop("index", None)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = tf_lib.output_logits(params, x, cfg)
        return logits, new_cache

    # ------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg, geom = self.cfg, self.geom
        hd = cfg.resolved_head_dim
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            from repro.models.attention import init_kv_cache
            return init_kv_cache(cfg.num_layers, batch, max_seq,
                                 geom.kv_heads, hd, cfg.kv_cache_dtype)
        if cfg.family == "ssm":
            return ssm_lib.init_ssm_cache(cfg, cfg.num_layers, batch)
        # hybrid
        cache = ssm_lib.init_ssm_cache(cfg, cfg.num_layers, batch)
        n_inv = hybrid_lib.num_attn_invocations(cfg)
        cache["attn_k"] = jnp.zeros((n_inv, batch, max_seq, geom.kv_heads, hd),
                                    jnp.dtype(cfg.dtype))
        cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
        return cache

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         lane_align: Optional[bool] = None) -> dict:
        """Block-pool KV cache (see attention.init_paged_kv_cache).
        ``num_blocks`` counts physical blocks, including the reserved
        junk block 0.  ``lane_align=None`` pads head_dim to the TPU lane
        width when compiling natively (and leaves it alone in interpret
        mode); pass True/False to force.  Attention families only:
        ssm/hybrid carry scan-state, not an addressable KV plane."""
        cfg, geom = self.cfg, self.geom
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise NotImplementedError(
                f"paged KV cache: {cfg.family} has no paged layout")
        from repro.models.attention import init_paged_kv_cache
        return init_paged_kv_cache(cfg.num_layers, num_blocks, block_size,
                                   geom.kv_heads, cfg.resolved_head_dim,
                                   cfg.kv_cache_dtype,
                                   lane_align=lane_align)

    def cache_specs(self, global_batch: Optional[int] = None) -> dict:
        cfg = self.cfg
        rules = self.fitted_rules(global_batch)
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            from repro.models.attention import cache_specs
            return cache_specs(rules, cfg.kv_cache_dtype == "int8")
        if cfg.family == "ssm":
            return ssm_lib.ssm_cache_specs(rules)
        out = ssm_lib.ssm_cache_specs(rules)
        s = rules.spec(None, "batch", "cache_seq", "kv_heads", "head_dim")
        out["attn_k"] = s
        out["attn_v"] = s
        return out

    # ------------------------------------------------------- input specs
    def batch_spec(self, global_batch: Optional[int] = None) -> dict:
        """PartitionSpecs for a training/prefill batch dict."""
        cfg = self.cfg
        rules = self.fitted_rules(global_batch)
        bs = rules.spec("batch", None)
        out = {"tokens": bs, "labels": bs}
        if cfg.family == "audio" and cfg.num_codebooks > 1:
            bks = rules.spec("batch", None, None)
            out = {"tokens": bks, "labels": bks}
        if cfg.family == "vlm":
            out["patch_embeds"] = rules.spec("batch", None, None)
        return out

    def input_specs(self, shape: ShapeConfig, *, with_labels: bool = True) -> dict:
        """ShapeDtypeStructs for one assigned cell (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        K = cfg.num_codebooks
        tok = jnp.int32

        def sds(shp, dt):
            return jax.ShapeDtypeStruct(shp, dt)

        if shape.kind == "decode":
            if cfg.family == "audio" and K > 1:
                batch = {"tokens": sds((B, K, 1), tok)}
            else:
                batch = {"tokens": sds((B, 1), tok)}
            batch["index"] = sds((), tok)
            return batch
        if cfg.family == "audio" and K > 1:
            batch = {"tokens": sds((B, K, S), tok)}
            if with_labels and shape.kind == "train":
                batch["labels"] = sds((B, K, S), tok)
        else:
            batch = {"tokens": sds((B, S), tok)}
            if with_labels and shape.kind == "train":
                batch["labels"] = sds((B, S), tok)
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds(
                (B, min(cfg.num_patches, S), cfg.d_model), jnp.bfloat16)
        return batch

    def dummy_batch(self, key, shape: ShapeConfig) -> dict:
        """Concrete random batch matching input_specs (for smoke tests)."""
        specs = self.input_specs(shape)
        out = {}
        for name, s in specs.items():
            key, sub = jax.random.split(key)
            if name == "index":
                out[name] = jnp.zeros((), jnp.int32)
            elif jnp.issubdtype(s.dtype, jnp.integer):
                out[name] = jax.random.randint(sub, s.shape, 0,
                                               self.cfg.vocab_size, s.dtype)
            else:
                # embedding-scale floats (unit-scale patch embeddings blow
                # up activation magnitudes and numeric comparisons)
                out[name] = (jax.random.normal(sub, s.shape, jnp.float32)
                             * 0.02).astype(s.dtype)
        return out


def _ssm_core(params, x, cfg: ModelConfig, *, mode: str, cache):
    """Pure-Mamba2 layer stack (train / prefill / decode)."""
    mp = params["mamba"]

    if mode == "decode":
        def body(carry, per_layer):
            x, ssd_st, cx, cb, cc, li = carry
            lp = per_layer
            conv_l = tuple(
                jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False)
                for c in (cx, cb, cc))
            ssd_l = jax.lax.dynamic_index_in_dim(ssd_st, li, 0, keepdims=False)
            h, (ncv, nssd) = ssm_lib.mamba_mix(
                rmsnorm(x, lp["ln"], cfg.norm_eps), lp, cfg, mode="decode",
                conv_state=conv_l, ssd_state=ssd_l)
            cx, cb, cc = (
                jax.lax.dynamic_update_slice(c, n.astype(c.dtype)[None],
                                             (li, 0, 0, 0))
                for c, n in zip((cx, cb, cc), ncv))
            ssd_st = jax.lax.dynamic_update_slice(
                ssd_st, nssd[None].astype(ssd_st.dtype), (li, 0, 0, 0, 0))
            return (x + h, ssd_st, cx, cb, cc, li + 1), None

        carry0 = (x, cache["ssd"], cache["conv_x"], cache["conv_B"],
                  cache["conv_C"], jnp.int32(0))
        (x, ssd_st, cx, cb, cc, _), _ = jax.lax.scan(body, carry0, mp)
        new_cache = dict(cache, ssd=ssd_st, conv_x=cx, conv_B=cb, conv_C=cc)
        new_cache.pop("index", None)
        return x, new_cache

    def body(x, lp):
        h, (ncv, nssd) = ssm_lib.mamba_mix(rmsnorm(x, lp["ln"], cfg.norm_eps),
                                           lp, cfg, mode=mode)
        if mode == "prefill":
            ys = (ncv[0].astype(x.dtype), ncv[1].astype(x.dtype),
                  ncv[2].astype(x.dtype), nssd)
        else:
            ys = None
        return x + h, ys

    if mode == "train" and cfg.remat != "nothing":
        policy = (jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
                  if cfg.remat == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)

    x, ys = jax.lax.scan(body, x, mp)
    if mode == "prefill":
        cx, cb, cc, ssd_st = ys
        return x, {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssd": ssd_st}
    return x, None


def build_model(cfg: ModelConfig,
                mesh: Optional[jax.sharding.Mesh] = None) -> Model:
    tp = 1
    if (mesh is not None and MODEL_AXIS in mesh.axis_names
            and cfg.sharding_recipe != "dp"):
        tp = mesh.shape[MODEL_AXIS]
    return Model(cfg=cfg, geom=Geometry.of(cfg, tp), mesh=mesh)
