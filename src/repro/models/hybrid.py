"""Zamba2-style hybrid: Mamba2 backbone + *shared* attention blocks.

One set of attention+MLP weights is re-applied every ``attn_every``
layers [arXiv:2411.15242].  Because weights are shared, the layer loop
stays a lax.scan over stacked Mamba params; the shared block is invoked
under ``lax.cond`` on a per-layer flag, so non-attention layers pay no
attention FLOPs.  Each invocation sees different activations, so decode
keeps ``n_inv = L // attn_every`` separate KV cache slots, indexed by a
running counter carried through the scan.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.model_config import ModelConfig
from repro.models.common import ParamDef, rmsnorm
from repro.models.ssm import mamba_mix, ssm_defs
from repro.models.transformer import Geometry, attention_block, dense_mlp_block


def num_attn_invocations(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def attn_layer_flags(cfg: ModelConfig) -> np.ndarray:
    """flags[l] = 1 where the shared attention block runs (after mamba)."""
    flags = np.zeros(cfg.num_layers, np.int32)
    flags[cfg.attn_every - 1::cfg.attn_every] = 1
    return flags


def hybrid_defs(cfg: ModelConfig, geom: Geometry) -> dict:
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.resolved_head_dim
    Hp, KV = geom.heads, geom.kv_heads
    H = cfg.num_heads
    shared = {
        "attn": {
            "wq": ParamDef((d, Hp, hd), ("embed", "heads", "head_dim"),
                           "scaled", mask_dims={1: H}),
            "wk": ParamDef((d, KV, hd), ("embed", "kv_heads", "head_dim"),
                           "scaled", mask_dims={1: cfg.num_kv_heads}),
            "wv": ParamDef((d, KV, hd), ("embed", "kv_heads", "head_dim"),
                           "scaled", mask_dims={1: cfg.num_kv_heads}),
            "wo": ParamDef((Hp, hd, d), ("heads", "head_dim", "embed"),
                           "scaled", mask_dims={0: H}),
        },
        "mlp": {
            "w_gate": ParamDef((d, ff), ("embed", "mlp"), "scaled"),
            "w_up": ParamDef((d, ff), ("embed", "mlp"), "scaled"),
            "w_down": ParamDef((ff, d), ("mlp", "embed"), "scaled"),
        },
        "ln1": ParamDef((d,), ("embed",), "ones", dtype="float32"),
        "ln2": ParamDef((d,), ("embed",), "ones", dtype="float32"),
    }
    return {"mamba": ssm_defs(cfg), "shared": shared}


def shared_block(x, sp, cfg: ModelConfig, geom: Geometry, *, positions,
                 mode: str, cache_kv=None, cache_index=None):
    lp = {"attn": sp["attn"]}
    h, kv = attention_block(rmsnorm(x, sp["ln1"], cfg.norm_eps), lp, cfg, geom,
                            positions=positions, mode=mode,
                            cache_kv=cache_kv, cache_index=cache_index)
    x = x + h
    h = dense_mlp_block(rmsnorm(x, sp["ln2"], cfg.norm_eps),
                        {"mlp": sp["mlp"]}, cfg)
    return x + h, kv


def _noop_branch(args):
    x, ak, av, slot = args
    return x, ak, av, slot


def hybrid_forward_core(params, x, cfg: ModelConfig, geom: Geometry, mesh, *,
                        mode: str, positions, cache: dict | None):
    """Scan over mamba layers with conditional shared-attn invocations.

    cache layout (decode input / prefill output):
      conv_x/conv_B/conv_C: (L, B, C, K-1), ssd: (L, B, nh, hd, ns),
      attn_k/attn_v: (n_inv, B, Smax, KV, hd)
    Decode additionally reads batch-level "index" via ``cache_index``.
    Returns (x, new_cache_or_None).
    """
    flags = jnp.asarray(attn_layer_flags(cfg))
    sp, mp = params["shared"], params["mamba"]
    B, S = x.shape[0], x.shape[1]
    n_inv = num_attn_invocations(cfg)
    hd = cfg.resolved_head_dim
    decode = mode == "decode"
    cache_index = cache.get("index") if (cache is not None and decode) else None

    def attn_branch(args):
        x, ak, av, slot = args
        if decode:
            from repro.models import attention as attn_lib
            from repro.models.transformer import qkv_project
            xn = rmsnorm(x, sp["ln1"], cfg.norm_eps)
            q, k, v = qkv_project(xn, {"attn": sp["attn"]}, cfg, geom,
                                  positions)
            # read-old / explicit-new-token / write (aliasing; §Perf 2)
            kc = jax.lax.dynamic_index_in_dim(ak, slot, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(av, slot, 0, keepdims=False)
            from repro.models.transformer import kv_index_for
            kv_idx = kv_index_for(cfg, geom)
            out = attn_lib.decode_attention(
                q, kc.astype(x.dtype), vc.astype(x.dtype), cache_index,
                kv_index=kv_idx, k_new=k, v_new=v)
            ak = jax.lax.dynamic_update_slice(
                ak, k.astype(ak.dtype)[None], (slot, 0, cache_index, 0, 0))
            av = jax.lax.dynamic_update_slice(
                av, v.astype(av.dtype)[None], (slot, 0, cache_index, 0, 0))
            x = x + jnp.einsum("bshk,hkd->bsd", out, sp["attn"]["wo"])
            h = dense_mlp_block(rmsnorm(x, sp["ln2"], cfg.norm_eps),
                                {"mlp": sp["mlp"]}, cfg)
            x = x + h
        else:
            x, (k, v) = shared_block(x, sp, cfg, geom, positions=positions,
                                     mode=mode)
            if mode == "prefill":
                ak = jax.lax.dynamic_update_slice(
                    ak, k.astype(ak.dtype)[None], (slot, 0, 0, 0, 0))
                av = jax.lax.dynamic_update_slice(
                    av, v.astype(av.dtype)[None], (slot, 0, 0, 0, 0))
        return x, ak, av, slot + 1

    # Attention-cache buffers (carried through the scan).
    if decode:
        ak, av = cache["attn_k"], cache["attn_v"]
    elif mode == "prefill":
        ak = jnp.zeros((n_inv, B, S, geom.kv_heads, hd), x.dtype)
        av = jnp.zeros_like(ak)
    else:  # train: dummies (cond still needs uniform signatures)
        ak = jnp.zeros((max(n_inv, 1), B, 1, geom.kv_heads, hd), x.dtype)
        av = jnp.zeros_like(ak)

    if decode:
        def body(carry, per_layer):
            x, ak, av, slot, ssd_st, cx, cb, cc = carry
            lp, flag, li = per_layer
            conv_l = tuple(
                jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False)
                for c in (cx, cb, cc))
            ssd_l = jax.lax.dynamic_index_in_dim(ssd_st, li, 0, keepdims=False)
            h, (ncv, nssd) = mamba_mix(
                rmsnorm(x, lp["ln"], cfg.norm_eps), lp, cfg, mode="decode",
                conv_state=conv_l, ssd_state=ssd_l)
            cx, cb, cc = (
                jax.lax.dynamic_update_slice(c, n.astype(c.dtype)[None],
                                             (li, 0, 0, 0))
                for c, n in zip((cx, cb, cc), ncv))
            ssd_st = jax.lax.dynamic_update_slice(
                ssd_st, nssd[None].astype(ssd_st.dtype), (li, 0, 0, 0, 0))
            x = x + h
            x, ak, av, slot = jax.lax.cond(flag > 0, attn_branch, _noop_branch,
                                           (x, ak, av, slot))
            return (x, ak, av, slot, ssd_st, cx, cb, cc), None

        carry0 = (x, ak, av, jnp.int32(0), cache["ssd"],
                  cache["conv_x"], cache["conv_B"], cache["conv_C"])
        per_layer = (mp, flags, jnp.arange(cfg.num_layers, dtype=jnp.int32))
        (x, ak, av, _, ssd_st, cx, cb, cc), _ = jax.lax.scan(
            body, carry0, per_layer)
        new_cache = dict(cache, attn_k=ak, attn_v=av, ssd=ssd_st,
                         conv_x=cx, conv_B=cb, conv_C=cc)
        return x, new_cache

    def body(carry, per_layer):
        x, ak, av, slot = carry
        lp, flag = per_layer
        h, (ncv, nssd) = mamba_mix(rmsnorm(x, lp["ln"], cfg.norm_eps), lp, cfg,
                                   mode=mode)
        x = x + h
        x, ak, av, slot = jax.lax.cond(flag > 0, attn_branch, _noop_branch,
                                       (x, ak, av, slot))
        if mode == "prefill":
            ys = (ncv[0].astype(x.dtype), ncv[1].astype(x.dtype),
                  ncv[2].astype(x.dtype), nssd)
        else:
            ys = None
        return (x, ak, av, slot), ys

    (x, ak, av, _), ys = jax.lax.scan(body, (x, ak, av, jnp.int32(0)),
                                      (mp, flags))
    if mode == "prefill":
        cx, cb, cc, ssd_st = ys
        new_cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssd": ssd_st,
                     "attn_k": ak, "attn_v": av}
        return x, new_cache
    return x, None
