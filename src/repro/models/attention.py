"""Reference (pure-jnp) attention paths: train, blockwise prefill, decode.

These are the HOST-target implementations (the "x86 software function" in
Xar-Trek terms).  The ACCEL target swaps in the Pallas kernels from
``repro.kernels`` at MigratableFunction boundaries: ``decode_attention``
and ``paged_decode_attention`` take a ``backend=`` selector ("xla" keeps
the reference math, "pallas" routes the same ABI through the Pallas
decode kernels), so the serve engine can register genuinely different
HOST/ACCEL builds of one step function.

GQA with padded query heads: query heads are padded to a TP-divisible
count ``Hp``; padded heads have zero weights and their kv mapping is
clamped, so they compute attention over zeros and contribute nothing.
KV heads are replicated across TP by default (small), while the KV
*cache* is sharded along the sequence dim.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.common import dequantize_int8, quantize_int8

NEG_INF = -1e30


def kv_head_index(num_heads: int, num_kv_heads: int,
                  padded_heads: int) -> np.ndarray | None:
    """Static map: query head -> kv head (padded heads clamp to the last).

    Returns None when the map is the identity (MHA, no padding): gathering
    with an identity index is not free under GSPMD — on the kv-sharded
    cache it lowered to a full cache all-gather (68 GB/chip on the olmoe
    decode_32k cell; see EXPERIMENTS.md §Perf 2).
    """
    group = max(num_heads // num_kv_heads, 1)
    idx = np.minimum(np.arange(padded_heads), num_heads - 1) // group
    idx = np.minimum(idx, num_kv_heads - 1)
    if len(idx) == num_kv_heads and np.array_equal(idx, np.arange(num_kv_heads)):
        return None
    return idx


def plain_attention(q, k, v, *, causal: bool = True,
                    kv_index: np.ndarray | None = None) -> jax.Array:
    """q: (B,S,Hp,hd)  k,v: (B,T,KV,hd) -> (B,S,Hp,hd).  O(S*T) memory."""
    B, S, Hp, hd = q.shape
    T = k.shape[1]
    if kv_index is not None:
        k = k[:, :, kv_index, :]
        v = v[:, :, kv_index, :]
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        scores = jnp.where(kpos <= qpos + (T - S), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)


def blockwise_attention(q, k, v, *, causal: bool = True,
                        kv_index: np.ndarray | None = None,
                        block_q: int = 1024, block_k: int = 1024) -> jax.Array:
    """FlashAttention-style online-softmax attention in pure jnp.

    Memory O(block_q * block_k) instead of O(S*T): required for the 32k
    prefill cells.  Iterates only the causally-live (qi, ki) block pairs
    (the full-square version wasted 2.1e14 FLOPs/chip on the qwen
    prefill cell; EXPERIMENTS.md §Perf 3).  Forward-only use (prefill);
    training uses plain_attention at 4k (cheaper to remat).
    """
    B, S, Hp, hd = q.shape
    T = k.shape[1]
    if kv_index is not None:
        k = k[:, :, kv_index, :]
        v = v[:, :, kv_index, :]
    nq, nk = S // block_q, T // block_k
    scale = 1.0 / np.sqrt(hd)
    off = T - S                                     # kv positions ahead of q

    qr = q.transpose(0, 2, 1, 3)                    # (B,H,S,hd)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)

    # static schedule of live block pairs, ki innermost
    pairs = []
    for qi in range(nq):
        hi = min(nk, (qi * block_q + block_q - 1 + off) // block_k + 1) \
            if causal else nk
        for ki in range(hi):
            pairs.append((qi, ki, ki == hi - 1))
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((B, Hp, block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hp, block_q, 1), jnp.float32)
    a0 = jnp.zeros((B, Hp, block_q, hd), jnp.float32)
    out0 = jnp.zeros((nq, B, Hp, block_q, hd), q.dtype)

    def step(carry, pk):
        m, l, acc, out = carry
        qi, ki = pk
        reset = (ki == 0)
        m = jnp.where(reset, NEG_INF, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)
        qb = jax.lax.dynamic_slice_in_dim(qr, qi * block_q, block_q, 2)
        kb = jax.lax.dynamic_slice_in_dim(kr, ki * block_k, block_k, 2)
        vb = jax.lax.dynamic_slice_in_dim(vr, ki * block_k, block_k, 2)
        s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb).astype(jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jnp.arange(block_q)[:, None]
            kpos = ki * block_k + jnp.arange(block_k)[None, :]
            s = jnp.where(kpos <= qpos + off, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(qb.dtype), vb).astype(jnp.float32)
        final = (acc / jnp.maximum(l, 1e-20)).astype(q.dtype)
        # last write per qi slot wins (= this qi's final ki step)
        out = jax.lax.dynamic_update_slice(
            out, final[None], (qi, 0, 0, 0, 0))
        return (m_new, l, acc, out), None

    (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, a0, out0),
                                     (qi_arr, ki_arr))
    # (nq,B,H,bq,hd) -> (B,S,H,hd)
    return out.transpose(1, 0, 3, 2, 4).reshape(B, S, Hp, hd)


def attention(q, k, v, *, causal: bool = True,
              kv_index: np.ndarray | None = None,
              blockwise_threshold: int = 8192) -> jax.Array:
    if q.shape[1] > blockwise_threshold:
        return blockwise_attention(q, k, v, causal=causal, kv_index=kv_index)
    return plain_attention(q, k, v, causal=causal, kv_index=kv_index)


# ------------------------------------------------- ACCEL (Pallas) path

@jax.custom_vjp
def flash_attention_hybrid(q, k, v):
    """Causal flash attention: Pallas kernel forward, reference backward.

    The forward streams q/k/v blocks through VMEM (no S x S score
    materialisation); the backward recomputes scores once via the
    reference path (a dedicated bwd kernel is the next step and would
    remove that too).  q/k/v: (B,S,H,hd) with kv already head-expanded.
    """
    from repro.kernels import ops as kernel_ops
    return kernel_ops.flash_attention(q, k, v, causal=True)


def _flash_fwd(q, k, v):
    return flash_attention_hybrid(q, k, v), (q, k, v)


def _flash_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda a, b, c: plain_attention(a, b, c, causal=True), q, k, v)
    return vjp(g)


flash_attention_hybrid.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_sharded(q, k, v, mesh, *,
                            kv_index: np.ndarray | None = None):
    """shard_map'd flash attention: batch over (pod, data), heads over
    model; per shard the Pallas kernel runs on its local blocks."""
    if kv_index is not None:
        k = k[:, :, kv_index, :]
        v = v[:, :, kv_index, :]
    if mesh is None:
        return flash_attention_hybrid(q, k, v)
    from jax.sharding import PartitionSpec as P
    from repro.models.transformer import shard_map
    from repro.parallel.mesh import MODEL_AXIS, batch_axes
    bdims = batch_axes(mesh)
    B = q.shape[0]
    dp = 1
    for a in bdims:
        dp *= mesh.shape[a]
    bspec = bdims if (bdims and B % dp == 0) else None
    spec = P(bspec, None, MODEL_AXIS, None)
    f = shard_map(flash_attention_hybrid, mesh=mesh,
                  in_specs=(spec, spec, spec), out_specs=spec,
                  check_vma=False)
    return f(q, k, v)


# ------------------------------------------------------------------ cache

def init_kv_cache(num_layers: int, batch: int, max_seq: int,
                  num_kv_heads: int, head_dim: int, dtype: str) -> dict:
    shape = (num_layers, batch, max_seq, num_kv_heads, head_dim)
    if dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        }
    dt = jnp.dtype(dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


LANE_WIDTH = 128     # TPU MXU/VPU lane width the Pallas kernels tile to


def init_paged_kv_cache(num_layers: int, num_blocks: int, block_size: int,
                        num_kv_heads: int, head_dim: int, dtype: str,
                        lane_align: bool | None = None) -> dict:
    """Block-pool KV cache for paged continuous batching.

    Layout (L, num_blocks, block_size, KV, hd): physical blocks replace
    the dense (batch, max_seq) plane; a per-request block table maps
    logical position p to (table[p // block_size], p % block_size).
    ``num_blocks`` counts PHYSICAL blocks, i.e. the pool's usable blocks
    plus the reserved junk block 0 (see serve.batch.BlockPool).

    ``lane_align`` pads ``head_dim`` up to the TPU lane width (128) *at
    allocation*, so the ACCEL paged kernel never has to lane-pad (=
    copy) the whole pool per decode call — writers zero-pad the
    per-token KV instead (cheap) and readers slice the real ``head_dim``
    back out.  ``None`` (default) aligns exactly when the Pallas
    kernels would compile natively (``not interpret``), and keeps the
    historical unpadded layout in interpret mode so CI behaviour — and
    CI memory — is unchanged.

    ``dtype="int8"`` stores the pool quantised with parallel f32 scale
    pools (``k_scale``/``v_scale``, same block structure, trailing dim 1)
    at per-(token, kv-head) granularity: every write quantises its own
    token independently (symmetric, round-to-nearest-even over
    ``head_dim``), so decode appends never re-scale a block's existing
    tokens and a COW fork copies scales the same way it copies blocks.
    At equal bytes an int8+scales pool holds ``~4*hd/(hd+4)`` as many
    blocks as f32 (~3.5x at hd=32, ~2x vs bf16); see
    ``paged_kv_block_bytes``.
    """
    if lane_align is None:
        from repro.kernels.ops import _interpret
        lane_align = not _interpret(None)
    hd_alloc = (head_dim + (-head_dim) % LANE_WIDTH if lane_align
                else head_dim)
    shape = (num_layers, num_blocks, block_size, num_kv_heads, hd_alloc)
    if dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        }
    dt = jnp.dtype(dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def paged_kv_block_bytes(block_size: int, num_kv_heads: int, head_dim: int,
                         dtype: str, lane_align: bool = False) -> int:
    """Bytes one physical KV block occupies (k + v + any scale pools).

    The equal-KV-bytes currency for capacity planning: at a fixed byte
    budget an int8 pool allocates ``budget // paged_kv_block_bytes``
    blocks — ~3.5x the f32 count at hd=32 (int8 pays 1 byte/element
    plus 4 bytes per (token, head) for the scale) — which is what
    ``PagedSlotManager.can_admit`` sees as extra admission headroom.
    """
    hd = (head_dim + (-head_dim) % LANE_WIDTH if lane_align else head_dim)
    per_pos = 2 * num_kv_heads * hd          # k + v elements per position
    if dtype == "int8":
        return block_size * (per_pos + 2 * num_kv_heads * 4)
    return block_size * per_pos * jnp.dtype(dtype).itemsize


def cache_specs(rules, int8: bool) -> dict:
    """PartitionSpecs matching init_kv_cache layout."""
    s = rules.spec("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    out = {"k": s, "v": s}
    if int8:
        sc = rules.spec("layers", "batch", "cache_seq", "kv_heads", None)
        out.update({"k_scale": sc, "v_scale": sc})
    return out


def update_cache_layer(cache: dict, layer: int, index: jax.Array,
                       k_new: jax.Array, v_new: jax.Array) -> dict:
    """Write (B, 1, KV, hd) new keys/values at ``index`` of layer ``layer``."""
    int8 = cache["k"].dtype == jnp.int8
    upd = dict(cache)
    if int8:
        kq, ks = quantize_int8(k_new, axis=-1)
        vq, vs = quantize_int8(v_new, axis=-1)
        for name, val in (("k", kq), ("v", vq), ("k_scale", ks), ("v_scale", vs)):
            upd[name] = jax.lax.dynamic_update_slice(
                upd[name], val[None].astype(upd[name].dtype),
                (layer, 0, index, 0, 0))
    else:
        for name, val in (("k", k_new), ("v", v_new)):
            upd[name] = jax.lax.dynamic_update_slice(
                upd[name], val[None].astype(upd[name].dtype),
                (layer, 0, index, 0, 0))
    return upd


def read_cache_layer(cache: dict, layer: int, dtype=jnp.bfloat16):
    k, v = cache["k"][layer], cache["v"][layer]
    if k.dtype == jnp.int8:
        k = dequantize_int8(k, cache["k_scale"][layer], dtype)
        v = dequantize_int8(v, cache["v_scale"][layer], dtype)
    return k, v


def _static_kv_index(kv_index) -> tuple | None:
    """np.ndarray -> hashable tuple for the jitted kernel wrappers."""
    if kv_index is None:
        return None
    return tuple(int(i) for i in np.asarray(kv_index))


def decode_attention(q, k_cache, v_cache, index: jax.Array,
                     kv_index: np.ndarray | None = None,
                     k_new=None, v_new=None, backend: str = "xla"
                     ) -> jax.Array:
    """Single-token attention over a (possibly seq-sharded) cache.

    q: (B,1,Hp,hd); k_cache/v_cache: (B,Smax,KV,hd).  ``index`` is a
    scalar shared position, or (B,1,1,1) ragged per-row positions
    (continuous batching) — both broadcast against the (…,Smax) masks.

    With ``k_new/v_new`` (B,1,KV,hd) given, attends over cache[0,index)
    plus the explicit current token — so callers can READ the old cache
    and WRITE the new entry independently.  (The write-then-read pattern
    defeats XLA's in-place aliasing of the scan-carried cache: the
    baseline olmoe decode cell copied the full 1 GB cache stack per layer
    — 103 GB/chip/step of pure copy traffic; EXPERIMENTS.md §Perf 2.)
    Without k_new, attends over [0, index] (cache already updated).

    ``backend="pallas"`` runs the same computation through the Pallas
    decode kernels (the ACCEL variant); "xla" is the reference below.
    """
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops
        kvt = _static_kv_index(kv_index)
        if k_new is None:
            return kernel_ops.gqa_decode(q, k_cache, v_cache, index,
                                         kv_index=kvt)
        return kernel_ops.gqa_decode_ragged(q, k_cache, v_cache, index,
                                            k_new, v_new, kv_index=kvt)
    B, _, Hp, hd = q.shape
    Smax = k_cache.shape[1]
    if kv_index is not None:
        k_cache = k_cache[:, :, kv_index, :]
        v_cache = v_cache[:, :, kv_index, :]
        if k_new is not None:
            k_new = k_new[:, :, kv_index, :]
            v_new = v_new[:, :, kv_index, :]
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
    positions = jnp.arange(Smax)[None, None, None, :]
    if k_new is None:
        mask = positions <= index
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v_cache)

    # explicit current-token term (cache holds only positions < index)
    mask = positions < index
    scores = jnp.where(mask, scores, NEG_INF)
    s_cur = (jnp.einsum("bqhd,bkhd->bhqk", q, k_new)
             .astype(jnp.float32) * scale)            # (B,Hp,1,1)
    m = jnp.maximum(jnp.max(scores, axis=-1, keepdims=True), s_cur)
    p = jnp.exp(scores - m)
    p_cur = jnp.exp(s_cur - m)
    denom = jnp.sum(p, axis=-1, keepdims=True) + p_cur
    out = jnp.einsum("bhqk,bkhd->bqhd", (p / denom).astype(q.dtype), v_cache)
    out = out + jnp.einsum("bhqk,bkhd->bqhd",
                           (p_cur / denom).astype(q.dtype), v_new)
    return out


def paged_decode_attention(q, k_pages, v_pages, table, index: jax.Array,
                           k_new, v_new,
                           kv_index: np.ndarray | None = None,
                           backend: str = "xla",
                           k_scale=None, v_scale=None) -> jax.Array:
    """Single-token attention over one layer of a paged (block-pool) cache.

    q: (B,1,Hp,hd); k_pages/v_pages: (NP,BS,KV,hd) physical blocks;
    table: (B,NBT) int32 block ids (logical block j of row b lives at
    ``table[b, j]``); index: (B,) int32 per-row write positions.  The
    pool contributes positions [0, index) and the current token's
    ``k_new/v_new`` (B,1,KV,hd) is folded in explicitly
    (write-then-attend, as in ``decode_attention``).

    With ``k_scale``/``v_scale`` (NP,BS,KV,1) f32 given the pool is
    int8 (see ``init_paged_kv_cache``): HOST dequantises the gathered
    rows before attending, ACCEL streams blocks + scales through the
    int8 kernel and dequantises in VMEM — same math, so greedy tokens
    agree across targets within the documented int8 tolerance.

    backend="xla" gathers the row's blocks into logical order and reuses
    ``decode_attention`` (the HOST reference — one materialised
    (B, NBT*BS, KV, hd) cache per call); backend="pallas" streams the
    blocks inside the paged decode kernel with no materialised gather
    (the ACCEL variant).
    """
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops
        if k_scale is not None:
            return kernel_ops.paged_gqa_decode_int8(
                q, k_pages, k_scale, v_pages, v_scale, k_new, v_new,
                table, index, kv_index=_static_kv_index(kv_index))
        return kernel_ops.paged_gqa_decode(
            q, k_pages, v_pages, k_new, v_new, table, index,
            kv_index=_static_kv_index(kv_index))
    B = q.shape[0]
    hd = q.shape[-1]
    NBT = table.shape[1]
    BS = k_pages.shape[1]
    rows_k = jnp.take(k_pages, table, axis=0)         # (B, NBT, BS, KV, hdp)
    rows_v = jnp.take(v_pages, table, axis=0)
    if k_scale is not None:
        # int8 pool: gather the per-token scales the same way and
        # dequantise only the (small) gathered rows, never the pool
        rows_k = (rows_k.astype(jnp.float32)
                  * jnp.take(k_scale, table, axis=0)).astype(q.dtype)
        rows_v = (rows_v.astype(jnp.float32)
                  * jnp.take(v_scale, table, axis=0)).astype(q.dtype)
    if rows_k.shape[-1] != hd:
        # lane-aligned pool (hd padded to 128 at allocation): the padded
        # tail is all-zero; slice AFTER the gather so only the (small)
        # gathered rows are touched, never the whole pool
        rows_k = rows_k[..., :hd]
        rows_v = rows_v[..., :hd]
    kc = rows_k.reshape(B, NBT * BS, *rows_k.shape[3:])
    vc = rows_v.reshape(B, NBT * BS, *rows_v.shape[3:])
    return decode_attention(q, kc, vc, index[:, None, None, None],
                            kv_index=kv_index, k_new=k_new, v_new=v_new)


def paged_prefill_attention(q, k_pages, v_pages, table, offset, length,
                            k_new, v_new,
                            kv_index: np.ndarray | None = None,
                            backend: str = "xla",
                            k_scale=None, v_scale=None) -> jax.Array:
    """Chunked-prefill attention: a multi-token chunk extends a prefix
    already resident in a paged cache (prefix caching's partial prefill).

    q: (B,W,Hp,hd) chunk queries at ABSOLUTE positions ``offset + j``;
    k_pages/v_pages: (NP,BS,KV,hd) one layer of the block pool; table:
    (B,NBT) int32 block ids; offset/length: (B,) int32.  The pool
    contributes logical positions [0, offset) — the cached prefix,
    written by an earlier request's prefill — and the chunk supplies
    positions [offset, length) causally through ``k_new/v_new``
    (B,W,KV,hd).  Chunk columns at or past ``length - offset`` are
    bucket padding: masked for every query, like the pool's junk-block
    columns past ``offset`` (their exp-underflowed scores are exact
    0.0, so padding width never changes the math — the same argument
    as the bucketed dense prefill).  Fully-masked PADDING query rows
    come out as garbage-but-finite values; callers never read them.

    With ``k_scale``/``v_scale`` (NP,BS,KV,1) given the pool is int8:
    the gathered context rows are dequantised (scale multiply, f32)
    before the chunk attends over them — the chunk's own ``k_new/v_new``
    stay full precision.

    backend="xla" gathers the row's blocks and attends over the
    materialised context (the HOST reference below); backend="pallas"
    streams pool blocks through the chunk-prefill kernel
    (``kernels.gqa_prefill.paged_gqa_prefill``) masked to [0, offset)
    with the chunk's causal self-attention folded in-kernel — chunked
    prefill is a genuinely different ACCEL build, like decode.
    """
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops
        kvt = _static_kv_index(kv_index)
        if k_scale is not None:
            return kernel_ops.paged_gqa_prefill_int8(
                q, k_pages, k_scale, v_pages, v_scale, k_new, v_new,
                table, offset, length, kv_index=kvt)
        return kernel_ops.paged_gqa_prefill(
            q, k_pages, v_pages, k_new, v_new, table, offset, length,
            kv_index=kvt)
    B, W, Hp, hd = q.shape
    NBT = table.shape[1]
    BS = k_pages.shape[1]
    rows_k = jnp.take(k_pages, table, axis=0)         # (B, NBT, BS, KV, hdp)
    rows_v = jnp.take(v_pages, table, axis=0)
    if k_scale is not None:
        rows_k = (rows_k.astype(jnp.float32)
                  * jnp.take(k_scale, table, axis=0)).astype(q.dtype)
        rows_v = (rows_v.astype(jnp.float32)
                  * jnp.take(v_scale, table, axis=0)).astype(q.dtype)
    if rows_k.shape[-1] != hd:
        rows_k = rows_k[..., :hd]                     # lane-aligned pool
        rows_v = rows_v[..., :hd]
    T = NBT * BS
    kc = rows_k.reshape(B, T, *rows_k.shape[3:]).astype(q.dtype)
    vc = rows_v.reshape(B, T, *rows_v.shape[3:]).astype(q.dtype)
    if kv_index is not None:
        kc = kc[:, :, kv_index, :]
        vc = vc[:, :, kv_index, :]
        k_new = k_new[:, :, kv_index, :]
        v_new = v_new[:, :, kv_index, :]
    k_full = jnp.concatenate([kc, k_new.astype(q.dtype)], axis=1)
    v_full = jnp.concatenate([vc, v_new.astype(q.dtype)], axis=1)
    scale = 1.0 / np.sqrt(hd)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q, k_full)
              .astype(jnp.float32) * scale)           # (B,Hp,W,T+W)
    ctx_valid = jnp.arange(T)[None, :] < offset[:, None]        # (B,T)
    qi = jnp.arange(W)[:, None]
    kj = jnp.arange(W)[None, :]
    n_real = (length - offset)[:, None, None]                   # (B,1,1)
    self_valid = (kj <= qi)[None] & (kj[None] < n_real)         # (B,W,W)
    mask = jnp.concatenate(
        [jnp.broadcast_to(ctx_valid[:, None, :], (B, W, T)), self_valid],
        axis=-1)[:, None]                                       # (B,1,W,T+W)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v_full)


def paged_verify_attention(q, k_pages, v_pages, table, offset, length,
                           k_new, v_new,
                           kv_index: np.ndarray | None = None,
                           backend: str = "xla",
                           k_scale=None, v_scale=None) -> jax.Array:
    """Speculative-decode VERIFY attention over a paged KV cache.

    The target model scores a slot's k drafted tokens in one batched
    step.  Mathematically this is ``paged_prefill_attention`` exactly:
    the "chunk" is the drafted span ``[offset, length)`` (W = draft
    width, columns past ``length - offset`` are per-row padding for
    shrunk drafts), and the pool contributes the committed prefix
    ``[0, offset)``.  The HOST path therefore delegates to the prefill
    reference verbatim; the ACCEL path routes through the verify-named
    kernel wrappers (``kernels.ops.paged_gqa_verify`` / ``_int8``) so
    the serve engine registers verify as a DISTINCT binary — the
    Xar-Trek runtime's migration log and ``summary()`` accounting then
    see draft and verify calls independently per target.
    """
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops
        kvt = _static_kv_index(kv_index)
        if k_scale is not None:
            return kernel_ops.paged_gqa_verify_int8(
                q, k_pages, k_scale, v_pages, v_scale, k_new, v_new,
                table, offset, length, kv_index=kvt)
        return kernel_ops.paged_gqa_verify(
            q, k_pages, v_pages, k_new, v_new, table, offset, length,
            kv_index=kvt)
    return paged_prefill_attention(q, k_pages, v_pages, table, offset,
                                   length, k_new, v_new, kv_index=kv_index,
                                   backend="xla", k_scale=k_scale,
                                   v_scale=v_scale)
