"""Model zoo: pure-JAX implementations of the 10 assigned architectures."""
