"""In-graph per-request token sampling for the serve decode step.

One vmapped kernel serves every request mix: it takes (B,) vectors of
temperature / top_k / top_p / seed alongside the (B, V) last-position
logits and the (B,) absolute token positions, so the jitted decode step
keeps ONE static compile signature no matter which sampling specs are
in flight (per ``core/binary.shape_key`` every leaf is keyed by
shape/dtype only — sampling params are *data*, not shapes, so no
per-request recompiles).

Determinism contract (the serve-migration analogue of Xar-Trek's
"migration must be transparent to the application"):

* the per-row PRNG key is ``fold_in(PRNGKey(seed), position)`` where
  ``position`` is the token's ABSOLUTE sequence position (prompt_len
  for the first generated token, prompt_len + k for the k-th).  The key
  depends only on (seed, position) — not on slot index, batch
  composition, wall clock, or how many times the request was preempted
  — so a seeded request replays identically across HOST/ACCEL builds,
  forced mid-stream migrations, and preempt/resume cycles.
* ``temperature == 0.0`` bypasses the sampled path entirely
  (``jnp.argmax`` over the raw logits), byte-identical to the greedy
  engines.
* the math is pure jnp traced identically into the HOST (XLA) and
  ACCEL (Pallas-attention) step builds — only attention differs between
  backends, never the sampling transform.

Filter order follows the common serving convention: temperature scale,
then top-k, then top-p (nucleus) over the surviving mass, then a
Gumbel-max draw (equivalent to a categorical sample, but needs no
normalisation and composes with the -inf masking).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

# keys of a serve batch dict that feed sampling, not the model forward
SAMPLING_KEYS = ("temperature", "top_k", "top_p", "seed")

NEG_INF = jnp.float32(-1e30)


def sampling_leaves(params, batch_size: int = 1) -> dict:
    """(B,)-vector leaves for one SamplingParams broadcast over a batch
    (the prefill path's B=1 case).  Kept here so every caller builds the
    exact same dtypes — a drifted dtype would silently fork the compile
    signature."""
    return {
        "temperature": np.full((batch_size,), params.temperature, np.float32),
        "top_k": np.full((batch_size,), params.top_k, np.int32),
        "top_p": np.full((batch_size,), params.top_p, np.float32),
        "seed": np.full((batch_size,), params.seed, np.int32),
    }


def _sample_row(logits, temperature, top_k, top_p, seed, pos):
    """One row: logits (V,) f32, scalars for the request's spec.

    Returns the sampled token id (int32).  Greedy (temperature 0) takes
    the argmax of the RAW logits — the exact pre-sampling behaviour.

    Both filters run in probability space off ONE descending sort
    (softmax is monotone, so the k-th largest prob is the k-th largest
    logit): ``pk`` is the top-k threshold, and top-p keeps the smallest
    sorted prefix covering ``top_p`` of the surviving mass (comparing
    against ``top_p * mass`` instead of renormalising — same selection,
    no second softmax or sort).  Every comparison is against an element
    of ``probs`` itself, so membership is exact, and the max-prob token
    always survives — the filters can never empty the support.
    """
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    greedy = jnp.argmax(logits).astype(jnp.int32)

    # deterministic per-(request, position) key — see module docstring
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)

    z = logits / jnp.maximum(temperature, jnp.float32(1e-6))
    probs = jax.nn.softmax(z)
    sp = jnp.sort(probs)[::-1]                  # descending

    # top-k: keep the k largest (k <= 0 disables; ties at the k-th value
    # are kept inclusively, which is deterministic)
    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V)
    pk = sp[k_eff - 1]
    spk = jnp.where(sp >= pk, sp, 0.0)          # top-k survivors, sorted
    mass = jnp.sum(spk)

    # top-p (nucleus): keep while the mass BEFORE the token is < top_p
    # of the surviving mass.  top_p >= 1.0 disables explicitly — the
    # f32 cumsum can round (csum - spk) up to >= mass and would
    # otherwise drop valid tail tokens even at top_p == 1.0
    csum = jnp.cumsum(spk)
    keep_sorted = ((top_p >= 1.0) | ((csum - spk) < top_p * mass)) \
        & (spk > 0)
    thr = jnp.min(jnp.where(keep_sorted, spk, jnp.float32(jnp.inf)))
    z = jnp.where((probs >= thr) & (probs >= pk), z, NEG_INF)

    # Gumbel-max draw == categorical(softmax(z)), no normalisation needed
    g = jax.random.gumbel(key, (V,), jnp.float32)
    sampled = jnp.argmax(z + g).astype(jnp.int32)
    tok = jnp.where(temperature <= 0.0, greedy, sampled)
    # chosen-token logprob under the UNMODIFIED model distribution
    # (raw logits, before temperature/filters): well-defined for greedy
    # and sampled rows alike, and a pure function of (logits, tok) so it
    # is byte-identical across backends and preemption history.  Always
    # computed — the step signature must stay static whether or not the
    # request opted in (the engine decides what to surface).
    logp = jax.nn.log_softmax(logits)[tok]
    return tok, logp


def sample_tokens(logits, temperature, top_k, top_p, seed, pos):
    """Batched in-graph sampling.

    logits: (B, V); temperature/top_p: (B,) f32; top_k/seed/pos: (B,)
    i32 — ``pos`` is each row's absolute position of the token being
    sampled.  Returns ((B,) int32 token ids, (B,) f32 chosen-token
    logprobs under the raw model distribution).
    """
    return jax.vmap(_sample_row)(
        logits, temperature.astype(jnp.float32), top_k.astype(jnp.int32),
        top_p.astype(jnp.float32), seed.astype(jnp.int32),
        pos.astype(jnp.int32))
