"""Common model building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays.  Each model module
defines a parallel tree of ``ParamDef`` (shape + logical axes + init),
from which we derive both the materialised params and their
PartitionSpecs.  Padded dimensions (heads/vocab made TP-divisible) are
zero-masked at init so they contribute exactly zero to fwd/bwd.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import ShardingRules, DEFAULT_RULES


@dataclasses.dataclass
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | scaled
    scale: float = 0.02
    # mask_dims: {dim_index: valid_size} -> zero out the padded tail
    mask_dims: dict[int, int] = dataclasses.field(default_factory=dict)
    dtype: Optional[str] = None     # override model dtype (e.g. fp32 norms)

    def spec(self, rules: ShardingRules) -> P:
        return rules.spec(*self.logical)


def _init_array(key, d: ParamDef, dtype) -> jax.Array:
    dt = jnp.dtype(d.dtype) if d.dtype else dtype
    if d.init == "zeros":
        x = jnp.zeros(d.shape, dt)
    elif d.init == "ones":
        x = jnp.ones(d.shape, dt)
    else:
        scale = d.scale
        if d.init == "scaled":  # 1/sqrt(fan_in) on the second-to-last dim
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 1.0 / math.sqrt(fan_in)
        x = (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt)
    for dim, valid in d.mask_dims.items():
        if valid < d.shape[dim]:
            mask = (jnp.arange(d.shape[dim]) < valid).astype(x.dtype)
            mask = mask.reshape([-1 if i == dim else 1 for i in range(x.ndim)])
            x = x * mask
    return x


def init_params(key, defs, dtype) -> dict:
    """Materialise a nested dict of ParamDef -> arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_array(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def param_specs(defs, rules: ShardingRules | None = None) -> dict:
    rules = rules or ShardingRules(DEFAULT_RULES)
    return jax.tree.map(
        lambda d: d.spec(rules), defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def param_shapes(defs, dtype) -> dict:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype) if d.dtype else dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ---------------------------------------------------------------- layers

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_in, w_out, b_in=None, b_out=None):
    h = jnp.einsum("...d,df->...f", x, w_in)
    if b_in is not None:
        h = h + b_in
    h = jax.nn.gelu(h)
    o = jnp.einsum("...f,fd->...d", h, w_out)
    if b_out is not None:
        o = o + b_out
    return o


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_valid: int, mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE; padded vocab columns are excluded."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vocab_valid < v:
        pad_bias = jnp.where(jnp.arange(v) < vocab_valid, 0.0, -1e9)
        logits = logits + pad_bias
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-slice int8 quantisation -> (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
