"""Trainer: the fault-tolerant loop with Xar-Trek hooks.

Responsibilities:
  * auto-resume from the newest valid checkpoint (elastic: restores onto
    whatever mesh it is launched with);
  * periodic (optionally async) checkpoints;
  * failure injection for tests/examples (SimulatedFailure at a step);
  * optional XarTrekRuntime integration: the train step is registered as
    a MigratableFunction and each step is dispatched through the
    scheduler (straggler mitigation: a slow target's observed step times
    raise its threshold and traffic drains away — Algorithm 1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.model_config import ModelConfig, ShapeConfig, TrainConfig
from repro.core.function import FunctionRegistry, MigratableFunction
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.data.pipeline import SyntheticPipeline
from repro.models.model import build_model
from repro.train.step import (init_train_state, make_train_step,
                              train_step_shardings)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically kills given steps (tests the restart path)."""

    fail_at_steps: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    shape: ShapeConfig
    tcfg: TrainConfig
    mesh: Optional[jax.sharding.Mesh] = None
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    async_ckpt: bool = False
    total_steps: int = 200
    runtime: Optional[XarTrekRuntime] = None
    seed: int = 0

    def __post_init__(self):
        self.model = build_model(self.cfg, self.mesh)
        self.step_fn = make_train_step(self.model, self.tcfg,
                                       total_steps=self.total_steps)
        if self.mesh is not None:
            in_s, out_s = train_step_shardings(self.model, self.tcfg,
                                               self.mesh)
            self._jitted = jax.jit(self.step_fn, in_shardings=in_s,
                                   out_shardings=out_s,
                                   donate_argnums=(0, 1))
        else:
            self._jitted = jax.jit(self.step_fn, donate_argnums=(0, 1))
        self.pipeline = SyntheticPipeline(
            self.cfg, self.shape, seed=self.seed, mesh=self.mesh,
            batch_spec=self.model.batch_spec() if self.mesh else None)
        self.manager = (CheckpointManager(self.ckpt_dir, keep=self.keep,
                                          save_async=self.async_ckpt)
                        if self.ckpt_dir else None)
        self.metrics_log: list[dict] = []

    # -------------------------------------------------------------- state
    def init_or_restore(self):
        params, opt_state = init_train_state(self.model, self.tcfg,
                                             self.mesh, seed=self.seed)
        start = 0
        if self.manager and self.manager.has_checkpoint():
            target = {"params": params, "opt": opt_state}
            shardings = None
            if self.mesh is not None:
                from repro.optim.adamw import AdamW
                from repro.parallel.sharding import named_tree
                pspecs = self.model.specs()
                ospecs = AdamW(self.tcfg).state_specs(
                    pspecs, self.model.shapes(), _dp(self.mesh))
                shardings = named_tree(self.mesh,
                                       {"params": pspecs, "opt": ospecs})
            state, step, _ = self.manager.restore(target, shardings)
            params, opt_state = state["params"], state["opt"]
            start = step
        return params, opt_state, start

    # --------------------------------------------------------------- run
    def run(self, steps: Optional[int] = None,
            injector: Optional[FailureInjector] = None,
            log_every: int = 10,
            max_restarts: int = 3) -> list[dict]:
        steps = steps or self.total_steps
        restarts = 0
        while True:
            try:
                self._run_once(steps, injector, log_every)
                return self.metrics_log
            except SimulatedFailure as e:
                restarts += 1
                if restarts > max_restarts or not self.manager:
                    raise
                print(f"[trainer] {e} -> restarting from latest checkpoint "
                      f"({restarts}/{max_restarts})")

    def _run_once(self, steps, injector, log_every):
        params, opt_state, start = self.init_or_restore()
        from repro.parallel.compat import use_mesh
        ctx = use_mesh(self.mesh)
        with ctx:
            for step in range(start, steps):
                batch = self.pipeline.batch(step)
                if injector:
                    injector.maybe_fail(step)
                t0 = time.perf_counter()
                if self.runtime is not None:
                    params, opt_state, metrics = self.runtime.call(
                        "train_step", params, opt_state, batch)
                else:
                    params, opt_state, metrics = self._jitted(
                        params, opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step"] = step + 1
                metrics["step_ms"] = (time.perf_counter() - t0) * 1e3
                self.metrics_log.append(metrics)
                if log_every and (step + 1) % log_every == 0:
                    print(f"[trainer] step {step+1}: "
                          f"loss={metrics['loss']:.4f} "
                          f"({metrics['step_ms']:.0f} ms)")
                if (self.manager and (step + 1) % self.ckpt_every == 0):
                    self.manager.save(
                        step + 1, {"params": params, "opt": opt_state},
                        meta={"arch": self.cfg.name})
            if self.manager:
                self.manager.save(steps, {"params": params,
                                          "opt": opt_state},
                                  meta={"arch": self.cfg.name})
                self.manager.wait()
        self.final_state = (params, opt_state)

    # --------------------------------------------- Xar-Trek registration
    def register_migratable(self, registry: FunctionRegistry,
                            accel_step: Optional[Callable] = None,
                            aux_step: Optional[Callable] = None) -> None:
        """Register the train step as a migratable function: HOST is the
        plain jit path, ACCEL the kernel-variant step, AUX an alternative
        configuration (e.g. different remat/sharding)."""
        variants = {TargetKind.HOST: self.step_fn}
        if aux_step is not None:
            variants[TargetKind.AUX] = aux_step
        if accel_step is not None:
            variants[TargetKind.ACCEL] = accel_step
        registry.register(MigratableFunction(
            "train_step", f"train-{self.cfg.name}", variants))


def _dp(mesh) -> int:
    dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dp *= mesh.shape[a]
    return dp


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
