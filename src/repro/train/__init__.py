from repro.train.step import make_train_step, init_train_state
from repro.train.trainer import Trainer, FailureInjector, SimulatedFailure
