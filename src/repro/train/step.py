"""Train step: grad-accum microbatching + AdamW, jitted with shardings.

The microbatch loop is a lax.scan with fp32 grad accumulators; XLA
overlaps each microbatch's DP reduce with the next microbatch's compute
(latency-hiding scheduler).  Optional int8+error-feedback compression of
the cross-pod reduction runs in a partially-manual shard_map over the
``pod`` axis (see optim/compression.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.model_config import TrainConfig
from repro.models.model import Model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel.sharding import named_tree


def _split_microbatches(batch: dict, n: int, model: Model) -> dict:
    """Reshape (B, ...) -> (n, B/n, ...) with an explicit sharding
    constraint: without it GSPMD loses the batch-dim sharding across the
    reshape and replicates activations (empirically: attention scores
    blow up 8x and a 500 GB scores all-reduce appears)."""
    specs = model.batch_spec() if model.mesh is not None else {}

    def split(name, x):
        x = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        spec = specs.get(name)
        if spec is not None:
            from repro.parallel.sharding import prune_spec
            full = P(*((None,) + tuple(spec)))
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(model.mesh, prune_spec(full, model.mesh)))
        return x

    return {k: split(k, v) for k, v in batch.items()}


def make_loss_and_grad(model: Model):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics
    return jax.value_and_grad(loss_fn, has_aux=True)


def make_train_step(model: Model, tcfg: TrainConfig,
                    total_steps: int = 10_000) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    opt = AdamW(tcfg)
    schedule = cosine_schedule(tcfg.learning_rate, warmup=min(100, total_steps // 10 + 1),
                               total=total_steps)
    grad_fn = make_loss_and_grad(model)
    n_mb = tcfg.microbatches

    def train_step(params, opt_state, batch):
        if n_mb > 1:
            mb = _split_microbatches(batch, n_mb, model)

            def accum(carry, mb_batch):
                gsum, lsum = carry
                (loss, metrics), grads = grad_fn(params, mb_batch)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            loss = lsum / n_mb
        else:
            (loss, _), grads = grad_fn(params, batch)

        lr = schedule(opt_state["step"])
        new_params, new_state = opt.update(grads, opt_state, params, lr)
        gnorm = new_state.pop("gnorm")
        metrics = {"loss": loss.astype(jnp.float32), "lr": lr,
                   "grad_norm": gnorm}
        return new_params, new_state, metrics

    return train_step


def train_step_shardings(model: Model, tcfg: TrainConfig, mesh: Mesh):
    """(in_shardings, out_shardings) for jitting the train step."""
    opt = AdamW(tcfg)
    pspecs = model.specs()
    pshapes = model.shapes()
    dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dp *= mesh.shape[a]
    ospecs = opt.state_specs(pspecs, pshapes, dp)
    bspecs = model.batch_spec()

    ns = lambda tree: named_tree(mesh, tree)
    in_s = (ns(pspecs), ns(ospecs), ns(bspecs))
    metric_s = {"loss": NamedSharding(mesh, P()),
                "lr": NamedSharding(mesh, P()),
                "grad_norm": NamedSharding(mesh, P())}
    out_s = (ns(pspecs), ns(ospecs), metric_s)
    return in_s, out_s


def init_train_state(model: Model, tcfg: TrainConfig,
                     mesh: Optional[Mesh] = None, seed: int = 0):
    """Sharded init: params + optimizer state materialised directly with
    their target shardings (no host round-trip)."""
    opt = AdamW(tcfg)
    key = jax.random.PRNGKey(seed)
    if mesh is None:
        params = model.init(key)
        return params, opt.init(params)
    pspecs = model.specs()
    pshapes = model.shapes()
    dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dp *= mesh.shape[a]
    ospecs = opt.state_specs(pspecs, pshapes, dp)

    ns = lambda tree: named_tree(mesh, tree)
    from repro.parallel.compat import use_mesh
    with use_mesh(mesh):
        params = jax.jit(model.init, out_shardings=ns(pspecs))(key)
        opt_state = jax.jit(opt.init, out_shardings=ns(ospecs))(params)
    return params, opt_state
