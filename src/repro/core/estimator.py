"""Step G — threshold estimation.

Paper procedure: (1) measure each application's total execution time in
isolation for the two migration scenarios (x86->ARM, x86->FPGA) — the
*in locus* measurement that folds in all communication overhead; then
(2) run the application on the host while increasing the host load until
its execution time exceeds each recorded scenario time; those loads are
the ARM/FPGA thresholds.

Two backends:
  * model-based (default): host time under load L follows the processor-
    sharing contention model t(L) = t0 * max(1, (L+1)/cores);
  * measured: calls a user-supplied ``host_time_fn(load)`` that actually
    runs the function under synthetic contention (used by the JAX-native
    runtime on real step functions).
"""
from __future__ import annotations

import math
from typing import Callable, Optional

from repro.core.sim import AppProfile
from repro.core.targets import DEFAULT_PLATFORM, Platform
from repro.core.thresholds import ThresholdRow, ThresholdTable

INF = math.inf


def host_time_model(t0_ms: float, cores: int) -> Callable[[float], float]:
    """Processor-sharing contention: with L other processes on the pool,
    this app runs at rate min(1, cores/(L+1))."""
    def t(load: float) -> float:
        return t0_ms * max(1.0, (load + 1.0) / cores)
    return t


def estimate_threshold(host_time_fn: Callable[[float], float],
                       scenario_ms: float, max_load: int = 256) -> float:
    """Threshold such that Algorithm 2's strict ``load > THR`` triggers
    exactly when host execution would exceed the migration scenario.

    If L_min is the smallest integer load with t_host(L_min) > scenario,
    the stored threshold is L_min - 0.5 (so load >= L_min migrates; the
    paper's Table 2 rounds this to an integer for display).  inf when the
    host never loses (FPGA-hostile apps like BFS/CG-A on small graphs).
    """
    for load in range(0, max_load + 1):
        if host_time_fn(load) > scenario_ms:
            return load - 0.5
    return INF


def estimate_table(apps: dict[str, AppProfile],
                   platform: Platform = DEFAULT_PLATFORM,
                   max_load: int = 256,
                   host_time_fns: Optional[dict[str, Callable]] = None,
                   ) -> ThresholdTable:
    """Produce the Table-2 artifact for a set of application profiles."""
    table = ThresholdTable()
    cores = platform.host.capacity
    for name, app in apps.items():
        t_host = (host_time_fns or {}).get(
            name, host_time_model(app.x86_ms, cores))
        row = ThresholdRow(
            app=name, hw_kernel=app.hw_kernel,
            fpga_thr=estimate_threshold(t_host, app.fpga_ms, max_load),
            arm_thr=estimate_threshold(t_host, app.arm_ms, max_load),
            x86_exec=app.x86_ms, arm_exec=app.arm_ms, fpga_exec=app.fpga_ms)
        table.rows[name] = row
    return table


def measure_profile(name: str, hw_kernel: str,
                    run_host: Callable[[], None],
                    run_aux: Callable[[], None],
                    run_accel: Callable[[], None],
                    repeats: int = 3) -> AppProfile:
    """Measured (non-simulated) profile of a real function: wall-time each
    target path end-to-end, migration included (the JAX-native runtime's
    estimator backend)."""
    import time

    def best(fn) -> float:
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            times.append((time.perf_counter() - t0) * 1e3)
        return min(times)

    return AppProfile(name=name, x86_ms=best(run_host),
                      fpga_ms=best(run_accel), arm_ms=best(run_aux),
                      hw_kernel=hw_kernel)
