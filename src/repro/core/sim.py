"""Calibrated discrete-event simulation of the paper's platform (§4).

This CPU-only container cannot time a Xeon + ThunderX + Alveo server, so
the paper's *evaluation* is reproduced on a processor-sharing simulator
whose per-app/target execution profiles are seeded from the paper's own
measurements (Table 1, Table 4).  The scheduler under test is the real
one — ``policy.schedule`` (Algorithm 2) + ``ThresholdTable.update``
(Algorithm 1) — exercised through the same request/report interface the
JAX-native runtime uses.

Model:
  * HOST pool: 6 cores, processor sharing (rate = min(1, cores/active)).
  * AUX pool: 96 cores, processor sharing.
  * ACCEL: serial FIFO device; non-resident kernels need a reconfiguration
    delay first (bounded residency slots, LRU).
  * A job's work is its isolated execution time on the chosen target
    (the Table-1 totals already include migration/data-transfer cost,
    the paper's in-locus measurement).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional

from repro.core.policy import (
    LoadSignals, PolicyLike, Residency, _Pin, resolve_policy,
)
from repro.core.targets import DEFAULT_PLATFORM, Platform, TargetKind
from repro.core.thresholds import ThresholdTable

INF = math.inf


@dataclasses.dataclass(frozen=True)
class AppProfile:
    """Per-target isolated execution times (ms) — Table 1 calibration."""

    name: str
    x86_ms: float
    fpga_ms: float
    arm_ms: float
    hw_kernel: str

    def work_ms(self, kind: TargetKind) -> float:
        return {TargetKind.HOST: self.x86_ms, TargetKind.ACCEL: self.fpga_ms,
                TargetKind.AUX: self.arm_ms}[kind]


# Table 1 of the paper (milliseconds).
PAPER_APPS: dict[str, AppProfile] = {
    "cg_a": AppProfile("cg_a", 2182, 10597, 8406, "KNL_HW_CG_A"),
    "facedet320": AppProfile("facedet320", 175, 332, 642, "KNL_HW_FD320"),
    "facedet640": AppProfile("facedet640", 885, 832, 2991, "KNL_HW_FD640"),
    "digit500": AppProfile("digit500", 883, 470, 2281, "KNL_HW_DR500"),
    "digit2000": AppProfile("digit2000", 3521, 1229, 8963, "KNL_HW_DR200"),
}

# Table 4: BFS (graph nodes -> ms); FPGA-hostile pointer chasing.
BFS_TABLE4 = {
    1000: (3.36, 726.50),
    2000: (115.74, 2282.54),
    3000: (256.94, 4981.05),
    4000: (458.04, 8760.80),
    5000: (721.48, 13524.76),
}


def bfs_profile(nodes: int) -> AppProfile:
    x86, fpga = BFS_TABLE4[nodes]
    # ARM ~ x86 scaled by the pools' single-thread ratio (not in Table 4).
    return AppProfile(f"bfs{nodes}", x86, fpga, x86 / 0.26,
                      f"KNL_HW_BFS{nodes}")


# The paper's background load generator (NPB MG-B instances).
MGB_MS = 30_000.0


@dataclasses.dataclass
class Job:
    jid: int
    app: AppProfile
    arrival: float
    calls: int = 1                     # selected-function invocations
    background: bool = False           # MG-B load generator (host-pinned)
    # runtime state
    target: Optional[TargetKind] = None
    remaining: float = 0.0
    calls_done: int = 0
    start: float = 0.0
    call_start: float = 0.0
    finish: float = -1.0


class PlatformSim:
    def __init__(self, platform: Platform = DEFAULT_PLATFORM,
                 table: Optional[ThresholdTable] = None,
                 policy: PolicyLike = "xartrek",
                 reconfig_ms: float = 4000.0,
                 accel_slots: int = 4,
                 preconfigure: tuple[str, ...] = ()):
        self.platform = platform
        # the scheduler under test is any SchedulingPolicy (legacy alias
        # strings resolve to the built-ins); threshold learning
        # (Algorithm 1) applies unless the placement is statically pinned
        self.policy = resolve_policy(policy)
        self._learn_thresholds = not isinstance(self.policy, _Pin)
        self.table = table or ThresholdTable()
        self.reconfig_ms = reconfig_ms
        self.accel_slots = accel_slots
        self.now = 0.0
        self.running: dict[TargetKind, list[Job]] = {k: [] for k in TargetKind}
        self.accel_queue: list[Job] = []
        self.resident: dict[str, float] = {}    # kernel -> last_used
        self.reconfig_until = 0.0
        self.reconfig_kernel: Optional[str] = None
        self.pending: list[tuple[float, int, Job]] = []   # arrival heap
        self.done: list[Job] = []
        self._jid = 0
        self.decisions = {k: 0 for k in TargetKind}
        for kern in preconfigure:
            self._make_resident(kern)

    # ------------------------------------------------------------- set-up
    def submit(self, app: AppProfile, at: float = 0.0, calls: int = 1,
               background: bool = False) -> Job:
        self._jid += 1
        job = Job(self._jid, app, at, calls=calls, background=background)
        heapq.heappush(self.pending, (at, self._jid, job))
        return job

    # ------------------------------------------------------------ helpers
    def _make_resident(self, kernel: str) -> None:
        if kernel in self.resident:
            self.resident[kernel] = self.now
            return
        if len(self.resident) >= self.accel_slots:
            victim = min(self.resident, key=self.resident.get)
            del self.resident[victim]
        self.resident[kernel] = self.now

    def host_load(self) -> float:
        """The paper's x86 CPU load: processes on the host pool."""
        return float(len(self.running[TargetKind.HOST]))

    def _rate(self, job: Job) -> float:
        kind = job.target
        if kind == TargetKind.ACCEL:
            return 1.0 if self.accel_queue and self.accel_queue[0] is job else 0.0
        pool = self.running[kind]
        cap = self.platform.by_kind(kind).capacity
        n = len(pool)
        return min(1.0, cap / n) if n else 1.0

    # --------------------------------------------------------- scheduling
    def _decide(self, job: Job) -> TargetKind:
        """One policy evaluation through the SchedulingPolicy protocol —
        the same ``decide(signals, row, residency)`` the JAX-native
        scheduler server calls, fed from the simulator's state."""
        if job.background:
            return TargetKind.HOST
        row = self.table.row(job.app.name, job.app.hw_kernel)
        kernel = job.app.hw_kernel
        signals = LoadSignals(
            x86_load=self.host_load(),
            aux_load=float(len(self.running[TargetKind.AUX])),
            accel_load=float(len(self.running[TargetKind.ACCEL])),
        )
        loading = (self.reconfig_kernel == kernel
                   and self.now < self.reconfig_until)
        d = self.policy.decide(signals, row,
                               Residency(resident=kernel in self.resident,
                                         loading=loading))
        if d.reconfigure:
            self._ensure_kernel(kernel)
        return d.target

    def _ensure_kernel(self, kernel: str) -> None:
        """Start an async reconfiguration if the device is free."""
        if kernel in self.resident:
            return
        if self.reconfig_kernel is None or self.now >= self.reconfig_until:
            self.reconfig_kernel = kernel
            self.reconfig_until = self.now + self.reconfig_ms

    def _start_call(self, job: Job) -> None:
        kind = self._decide(job)
        job.target = kind
        job.remaining = job.app.work_ms(kind)
        self.decisions[kind] += 1
        self.running[kind].append(job)
        if kind == TargetKind.ACCEL:
            self.accel_queue.append(job)

    def _finish_call(self, job: Job) -> None:
        kind = job.target
        self.running[kind].remove(job)
        if kind == TargetKind.ACCEL:
            self.accel_queue.remove(job)
            self.resident[job.app.hw_kernel] = self.now
        job.calls_done += 1
        if not job.background and self._learn_thresholds:
            # Algorithm 1: report observed time + load after the return
            elapsed = self.now - job.call_start
            self.table.update(job.app.name, kind, elapsed, self.host_load())
        if job.calls_done >= job.calls:
            job.finish = self.now
            self.done.append(job)
        else:
            self._start_call(job)
            job.call_start = self.now

    # -------------------------------------------------------------- run
    def run(self, until: float = INF,
            stop_when_idle: bool = True) -> None:
        while True:
            # activate arrivals at the current time
            while self.pending and self.pending[0][0] <= self.now + 1e-9:
                _, _, job = heapq.heappop(self.pending)
                job.start = self.now
                job.call_start = self.now
                self._start_call(job)

            active = [j for pool in self.running.values() for j in pool]
            if not active and not self.pending:
                if stop_when_idle:
                    return
            if self.now >= until:
                return

            # completion of the reconfiguration
            events = []
            if self.reconfig_kernel is not None and self.reconfig_until > self.now:
                events.append(self.reconfig_until - self.now)
            # next arrival
            if self.pending:
                events.append(self.pending[0][0] - self.now)
            # next job completion under current rates
            for j in active:
                r = self._rate(j)
                if r > 0:
                    events.append(j.remaining / r)
            if not events:
                return
            dt = max(min(events), 1e-9)
            dt = min(dt, until - self.now) if until < INF else dt

            # advance work
            for j in active:
                j.remaining -= dt * self._rate(j)
            self.now += dt

            if (self.reconfig_kernel is not None
                    and self.now >= self.reconfig_until - 1e-9):
                self._make_resident(self.reconfig_kernel)
                self.reconfig_kernel = None

            for j in list(active):
                if j.remaining <= 1e-6:
                    self._finish_call(j)

    # ------------------------------------------------------------ metrics
    def avg_execution_ms(self, include_background: bool = False) -> float:
        jobs = [j for j in self.done
                if include_background or not j.background]
        if not jobs:
            return 0.0
        return sum(j.finish - j.start for j in jobs) / len(jobs)

    def completed_calls(self, app_name: str) -> int:
        total = 0
        for j in self.done + [x for p in self.running.values() for x in p]:
            if j.app.name == app_name:
                total += j.calls_done
        return total
