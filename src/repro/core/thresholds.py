"""Threshold table + Algorithm 1 (dynamic threshold update) — faithful port.

The table is the compiler's Table-2 artifact: per application, the
hardware-kernel name and the x86-load thresholds above which migration
to ACCEL ("FPGA_THR") / AUX ("ARM_THR") is profitable.  The run-time
client refines it after every function return with the observed
execution time and load, exactly as the paper's Algorithm 1.
"""
from __future__ import annotations

import dataclasses
import json
import math

from repro.core.targets import TargetKind

INF = math.inf


@dataclasses.dataclass
class ThresholdRow:
    app: str
    hw_kernel: str
    fpga_thr: float = INF          # load above which ACCEL wins
    arm_thr: float = INF           # load above which AUX wins
    # last observed execution times per target (paper: recorded data)
    x86_exec: float = INF
    arm_exec: float = INF
    fpga_exec: float = INF

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ThresholdTable:
    rows: dict[str, ThresholdRow] = dataclasses.field(default_factory=dict)
    increase_step: float = 1.0     # "Increase THR" granularity (Alg.1 l.16/21)

    def row(self, app: str, hw_kernel: str = "") -> ThresholdRow:
        if app not in self.rows:
            self.rows[app] = ThresholdRow(app=app, hw_kernel=hw_kernel
                                          or f"KNL_HW_{app.upper()}")
        return self.rows[app]

    # ------------------------------------------------ Algorithm 1 (verbatim)
    def update(self, app: str, executed_on: TargetKind, exec_time: float,
               cpu_load: float) -> None:
        """One dynamic-threshold-update step after a function returns.

        Paper Algorithm 1: lines annotated.
        """
        r = self.row(app)
        # l.1-2: record application execution time + CPU load
        if executed_on == TargetKind.HOST:                      # l.3
            r.x86_exec = exec_time
            if (r.x86_exec > r.fpga_exec) and (cpu_load < r.fpga_thr):  # l.4
                r.fpga_thr = cpu_load                           # l.5
            elif (r.x86_exec > r.arm_exec) and (cpu_load < r.arm_thr):  # l.7
                r.arm_thr = cpu_load                            # l.8
            # else: only x86_exec recorded                      # l.10
        elif executed_on == TargetKind.AUX:                     # l.14
            r.arm_exec = exec_time
            if r.arm_exec > r.x86_exec:                         # l.15
                r.arm_thr += self.increase_step                 # l.16
        elif executed_on == TargetKind.ACCEL:                   # l.19
            r.fpga_exec = exec_time
            if r.fpga_exec > r.x86_exec:                        # l.20
                r.fpga_thr += self.increase_step                # l.21

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        def enc(v):
            return "inf" if v == INF else v

        data = {a: {k: enc(v) for k, v in r.to_dict().items()}
                for a, r in self.rows.items()}
        with open(path, "w") as f:
            json.dump(data, f, indent=2)

    @classmethod
    def load(cls, path: str) -> "ThresholdTable":
        def dec(v):
            return INF if v == "inf" else v

        with open(path) as f:
            data = json.load(f)
        table = cls()
        for app, row in data.items():
            table.rows[app] = ThresholdRow(
                **{k: dec(v) for k, v in row.items()})
        return table

    def as_table2(self) -> list[dict]:
        """Paper Table-2 shaped report."""
        return [{"Benchmark": r.app, "HW Kernel": r.hw_kernel,
                 "FPGA_THR": r.fpga_thr, "ARM_THR": r.arm_thr}
                for r in self.rows.values()]
