"""Run-time scheduler: client/server split, as in the paper (§3.2).

The server owns the policy (a pluggable ``SchedulingPolicy`` — the
default is Algorithm 2 as ``XarTrekHeuristic``), the threshold table
(Algorithm 1 updates arrive via client reports), the kernel bank(s) and
the load monitor.  A client instance is bound to each application/job;
it queries the server *before* the selected function's call (receiving
the migration flag) and reports *after* it returns.

Signals: the policy input is no longer just the monitor's synthetic
process counter.  Serve engines ``publish`` a ``LoadSignals`` snapshot
each step (queue depth, free KV fraction, per-target decode ms, latency
percentiles); the server aggregates the published snapshots across
engines and merges them with the monitor's process counts — so in a
multi-engine cluster one engine's pressure raises the load every
co-tenant's decision sees (the ROADMAP's "Algorithm 2 balances across
real co-tenant load").

Two transports: in-process (default — one JAX process drives the fleet)
and a line-JSON TCP transport mirroring the paper's socket setup (used
by the cluster front-ends, the multi-process example and tests); the
TCP protocol carries ``request`` / ``report`` / ``publish`` /
``handoff`` / ``heartbeat`` / ``kernel`` ops — ``handoff`` moves a
disaggregated prefill's KV span (opaque base64 payload) to a
registered decode-role sink, so phase handoffs ride the same control
plane as scheduling decisions; ``heartbeat`` is the process-cluster
liveness beat (the supervisor reads ``SchedulerServer.heartbeats`` to
detect dead/straggling workers); ``kernel`` reports a REMOTE worker's
kernel-bank residency, because an OS-process worker's bank lives in
its own address space where the central ``residency()`` lookup cannot
reach — without the report the policy would see every process
worker's ACCEL build as permanently absent.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import socket
import socketserver
import threading
import time
from typing import Callable, Optional

from repro.core.kernel_bank import KernelBank
from repro.core.monitor import LoadMonitor
from repro.core.policy import (
    Decision, LoadSignals, PolicyLike, Residency, resolve_policy,
)
from repro.core.targets import Platform, TargetKind
from repro.core.thresholds import ThresholdTable


class SchedulerServer:
    def __init__(self, platform: Platform, table: ThresholdTable,
                 bank: Optional[KernelBank] = None,
                 monitor: Optional[LoadMonitor] = None,
                 policy: PolicyLike = "xartrek"):
        self.platform = platform
        self.table = table
        self.bank = bank               # default bank (single-runtime case)
        self.monitor = monitor or LoadMonitor(platform)
        self._policy = resolve_policy(policy)
        self._lock = threading.Lock()
        self.decisions = {k: 0 for k in TargetKind}
        self.reconfigs = 0
        # kernel -> owning bank: in a cluster every runtime registers its
        # functions here so residency/reconfiguration reach the right bank
        self._owners: dict[str, KernelBank] = {}
        # engine_id -> latest published serve telemetry
        self._published: dict[str, LoadSignals] = {}
        # dest engine_id -> callable(req_id, payload) consuming a KV
        # handoff (disaggregation: prefill worker -> decode worker)
        self._handoff_sinks: dict[str, Callable[[int, bytes], None]] = {}
        self.handoffs = 0
        # worker_id -> last liveness beat (process-cluster supervision):
        # {"seq": int, "t": monotonic receipt time, "info": dict}
        self.heartbeats: dict[str, dict] = {}
        # kernel -> Residency reported by an out-of-process worker whose
        # bank this server cannot query directly
        self._remote_residency: dict[str, Residency] = {}

    # ------------------------------------------------------------- policy
    @property
    def policy(self) -> object:
        return self._policy

    @policy.setter
    def policy(self, value: PolicyLike) -> None:
        """Accepts a SchedulingPolicy instance or a legacy alias string
        ("xartrek" | "always_host" | "always_aux" | "always_accel" |
        "latency_aware") — callers flip it mid-stream in benchmarks."""
        self._policy = resolve_policy(value)

    # ------------------------------------------------------------ signals
    def publish(self, engine_id: str, signals: LoadSignals) -> None:
        """Engine-side telemetry feed: the latest snapshot per engine
        (no history — the policy wants current pressure, not a log)."""
        with self._lock:
            self._published[engine_id] = signals

    def signals(self) -> LoadSignals:
        """The policy input: the monitor's process counts merged with
        the cross-engine aggregate of published serve telemetry.
        Queued-but-unadmitted requests count into ``x86_load`` — the
        paper's load is "processes on or queued for the host", and a
        request waiting for a slot is queued host work."""
        base = self.monitor.signals()
        with self._lock:
            published = list(self._published.values())
        if not published:
            return base
        agg = LoadSignals.aggregate(published)
        return dataclasses.replace(
            agg,
            x86_load=base.x86_load + agg.queue_depth,
            aux_load=base.aux_load,
            accel_load=base.accel_load,
            band=self.monitor.band(
                int(base.x86_load + base.aux_load + base.accel_load
                    + agg.queue_depth)),
        )

    # ------------------------------------------------------------ handoff
    def register_handoff_sink(self, engine_id: str,
                              sink: Callable[[int, bytes], None]) -> None:
        """Bind a decode-role worker's span consumer: ``handoff`` calls
        deliver serialized KV spans addressed to ``engine_id`` here."""
        with self._lock:
            self._handoff_sinks[engine_id] = sink

    def handoff(self, dest: str, req_id: int, payload: bytes) -> None:
        """Deliver one prefill's serialized KV span to ``dest``'s sink.
        The sink runs OUTSIDE the lock — it rehydrates pool blocks and
        must not block scheduling decisions."""
        with self._lock:
            sink = self._handoff_sinks.get(dest)
            if sink is None:
                raise KeyError(f"no handoff sink registered for {dest!r}")
            self.handoffs += 1
        sink(req_id, payload)

    def register_kernel(self, kernel: str, bank: KernelBank) -> None:
        """Bind a hardware kernel to the bank that can load it (each
        cluster worker's runtime owns its own compiled variants)."""
        with self._lock:
            self._owners[kernel] = bank

    def register_remote_kernel(self, app: str, kernel: str,
                               resident: bool, loading: bool) -> None:
        """Residency report from an OS-process worker: its bank lives in
        another address space, so it pushes state here instead of being
        queried.  Also pins the app's threshold row to the kernel name —
        the central row may have been lazily created by a ``request``
        before this report, with the default placeholder kernel."""
        with self._lock:
            self.table.row(app).hw_kernel = kernel
            self._remote_residency[kernel] = Residency(
                resident=resident, loading=loading)

    def heartbeat(self, worker: str, seq: int,
                  info: Optional[dict] = None) -> None:
        """Record one liveness beat.  Receipt time is the SERVER's
        monotonic clock, so the supervisor's deadline math never
        depends on cross-process clock agreement."""
        with self._lock:
            self.heartbeats[worker] = {"seq": int(seq),
                                       "t": time.monotonic(),
                                       "info": dict(info or {})}

    def residency(self, kernel: str) -> Residency:
        bank = self._owners.get(kernel, self.bank)
        if bank is None:
            with self._lock:
                return self._remote_residency.get(kernel, Residency())
        return Residency(resident=bank.is_resident(kernel),
                         loading=bank.is_loading(kernel))

    # ------------------------------------------------------------- server
    def request(self, app: str) -> Decision:
        """Handle one client scheduling request (Algorithm 2 l.5-8)."""
        row = self.table.row(app)
        sig = self.signals()
        res = self.residency(row.hw_kernel)
        with self._lock:
            d = self._policy.decide(sig, row, res)
            self.decisions[d.target] += 1
            if d.reconfigure:
                self.reconfigs += 1
                bank = self._owners.get(row.hw_kernel, self.bank)
        if d.reconfigure and bank is not None:
            bank.load_async(row.hw_kernel)      # async; outside the lock
        return d

    def report(self, app: str, executed_on: TargetKind, exec_time: float,
               cpu_load: Optional[float] = None) -> None:
        """Client post-return report -> Algorithm 1 threshold update."""
        load = self.monitor.x86_load() if cpu_load is None else cpu_load
        with self._lock:
            self.table.update(app, executed_on, exec_time, load)


@dataclasses.dataclass
class SchedulerClient:
    """Instrumented into each application binary (step B)."""

    app: str
    server: SchedulerServer

    def before_call(self) -> Decision:
        return self.server.request(self.app)

    def after_call(self, executed_on: TargetKind, exec_time: float,
                   cpu_load: Optional[float] = None) -> None:
        self.server.report(self.app, executed_on, exec_time, cpu_load)

    def publish(self, engine_id: str, signals: LoadSignals) -> None:
        self.server.publish(engine_id, signals)

    def handoff(self, dest: str, req_id: int, payload: bytes) -> None:
        self.server.handoff(dest, req_id, payload)

    def heartbeat(self, worker: str, seq: int,
                  info: Optional[dict] = None) -> None:
        self.server.heartbeat(worker, seq, info)

    def register_remote_kernel(self, app: str, kernel: str,
                               resident: bool, loading: bool) -> None:
        self.server.register_remote_kernel(app, kernel, resident, loading)


# --------------------------------------------------------------- TCP mode

class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        for raw in self.rfile:
            try:
                msg = json.loads(raw)
                if msg["op"] == "request":
                    d = self.server.xar.request(msg["app"])
                    resp = {"flag": d.flag, "reconfigure": d.reconfigure}
                elif msg["op"] == "report":
                    self.server.xar.report(
                        msg["app"], TargetKind(msg["target"]),
                        float(msg["exec_time"]), msg.get("cpu_load"))
                    resp = {"ok": True}
                elif msg["op"] == "publish":
                    self.server.xar.publish(
                        msg["engine"], LoadSignals(**msg["signals"]))
                    resp = {"ok": True}
                elif msg["op"] == "handoff":
                    self.server.xar.handoff(
                        msg["dest"], int(msg["req_id"]),
                        base64.b64decode(msg["payload"]))
                    resp = {"ok": True}
                elif msg["op"] == "heartbeat":
                    self.server.xar.heartbeat(
                        msg["worker"], int(msg["seq"]), msg.get("info"))
                    resp = {"ok": True}
                elif msg["op"] == "kernel":
                    self.server.xar.register_remote_kernel(
                        msg["app"], msg["kernel"],
                        bool(msg["resident"]), bool(msg["loading"]))
                    resp = {"ok": True}
                else:
                    resp = {"error": f"unknown op {msg['op']}"}
            except Exception as e:  # noqa: BLE001 — report to client
                resp = {"error": str(e)}
            self.wfile.write((json.dumps(resp) + "\n").encode())
            self.wfile.flush()


class TcpSchedulerServer:
    """Paper-faithful socket transport around a SchedulerServer.

    Binding to port 0 (the default) takes a kernel-assigned ephemeral
    port with no reserve-then-rebind race; ``address`` carries the
    resolved port.  ``stop()`` is idempotent and joins the serve
    thread, so error-path teardown (``finally`` blocks, context
    managers, a front-end whose construction failed halfway) can call
    it unconditionally without tripping on a double ``server_close``
    or leaking the listener socket."""

    def __init__(self, inner: SchedulerServer, host: str = "127.0.0.1",
                 port: int = 0):
        self.inner = inner
        self._srv = socketserver.ThreadingTCPServer((host, port), _Handler)
        self._srv.daemon_threads = True
        self._srv.xar = inner
        self.address = self._srv.server_address
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)
        self._started = False
        self._stopped = False

    def start(self) -> tuple[str, int]:
        self._thread.start()
        self._started = True
        return self.address

    def stop(self) -> None:
        if self._stopped:
            return
        self._stopped = True
        if self._started:
            self._srv.shutdown()          # stops serve_forever
            self._thread.join(timeout=5.0)
        self._srv.server_close()          # closes the listener socket

    def __enter__(self) -> "TcpSchedulerServer":
        if not self._started:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class TcpSchedulerClient:
    def __init__(self, app: str, address: tuple[str, int]):
        self.app = app
        self._sock = socket.create_connection(address)
        self._file = self._sock.makefile("rw")
        self._lock = threading.Lock()    # one in-flight rpc per connection

    def _rpc(self, msg: dict) -> dict:
        with self._lock:
            self._file.write(json.dumps(msg) + "\n")
            self._file.flush()
            line = self._file.readline()
        if not line:
            raise ConnectionError(
                f"scheduler connection closed mid-rpc (op "
                f"{msg.get('op')!r}, app {self.app!r})")
        resp = json.loads(line)
        if "error" in resp:
            # surface server-side failures as exceptions instead of a
            # KeyError on the missing happy-path field three frames up
            raise RuntimeError(f"scheduler {msg.get('op')!r} failed: "
                               f"{resp['error']}")
        return resp

    def before_call(self) -> Decision:
        resp = self._rpc({"op": "request", "app": self.app})
        kind = {0: TargetKind.HOST, 1: TargetKind.AUX,
                2: TargetKind.ACCEL}[resp["flag"]]
        return Decision(kind, reconfigure=resp["reconfigure"])

    def after_call(self, executed_on: TargetKind, exec_time: float,
                   cpu_load: Optional[float] = None) -> None:
        self._rpc({"op": "report", "app": self.app,
                   "target": executed_on.value, "exec_time": exec_time,
                   "cpu_load": cpu_load})

    def publish(self, engine_id: str, signals: LoadSignals) -> None:
        self._rpc({"op": "publish", "engine": engine_id,
                   "signals": dataclasses.asdict(signals)})

    def handoff(self, dest: str, req_id: int, payload: bytes) -> None:
        self._rpc({"op": "handoff", "dest": dest, "req_id": req_id,
                   "payload": base64.b64encode(payload).decode()})

    def heartbeat(self, worker: str, seq: int,
                  info: Optional[dict] = None) -> None:
        self._rpc({"op": "heartbeat", "worker": worker, "seq": seq,
                   "info": info})

    def register_remote_kernel(self, app: str, kernel: str,
                               resident: bool, loading: bool) -> None:
        self._rpc({"op": "kernel", "app": app, "kernel": kernel,
                   "resident": resident, "loading": loading})

    def close(self) -> None:
        """Idempotent: both the buffered file wrapper and the socket
        close, and a second close (or one racing a failed construction)
        is a no-op instead of an exception."""
        for obj in (self._file, self._sock):
            try:
                obj.close()
            except OSError:
                pass
