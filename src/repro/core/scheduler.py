"""Run-time scheduler: client/server split, as in the paper (§3.2).

The server owns the policy (a pluggable ``SchedulingPolicy`` — the
default is Algorithm 2 as ``XarTrekHeuristic``), the threshold table
(Algorithm 1 updates arrive via client reports), the kernel bank(s) and
the load monitor.  A client instance is bound to each application/job;
it queries the server *before* the selected function's call (receiving
the migration flag) and reports *after* it returns.

Signals: the policy input is no longer just the monitor's synthetic
process counter.  Serve engines ``publish`` a ``LoadSignals`` snapshot
each step (queue depth, free KV fraction, per-target decode ms, latency
percentiles); the server aggregates the published snapshots across
engines and merges them with the monitor's process counts — so in a
multi-engine cluster one engine's pressure raises the load every
co-tenant's decision sees (the ROADMAP's "Algorithm 2 balances across
real co-tenant load").

Two transports: in-process (default — one JAX process drives the fleet)
and a line-JSON TCP transport mirroring the paper's socket setup (used
by the cluster front-end, the multi-process example and tests); the TCP
protocol carries ``request`` / ``report`` / ``publish`` / ``handoff``
ops — ``handoff`` moves a disaggregated prefill's KV span (opaque
base64 payload) to a registered decode-role sink, so phase handoffs
ride the same control plane as scheduling decisions.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import socket
import socketserver
import threading
from typing import Callable, Optional

from repro.core.kernel_bank import KernelBank
from repro.core.monitor import LoadMonitor
from repro.core.policy import (
    Decision, LoadSignals, PolicyLike, Residency, resolve_policy,
)
from repro.core.targets import Platform, TargetKind
from repro.core.thresholds import ThresholdTable


class SchedulerServer:
    def __init__(self, platform: Platform, table: ThresholdTable,
                 bank: Optional[KernelBank] = None,
                 monitor: Optional[LoadMonitor] = None,
                 policy: PolicyLike = "xartrek"):
        self.platform = platform
        self.table = table
        self.bank = bank               # default bank (single-runtime case)
        self.monitor = monitor or LoadMonitor(platform)
        self._policy = resolve_policy(policy)
        self._lock = threading.Lock()
        self.decisions = {k: 0 for k in TargetKind}
        self.reconfigs = 0
        # kernel -> owning bank: in a cluster every runtime registers its
        # functions here so residency/reconfiguration reach the right bank
        self._owners: dict[str, KernelBank] = {}
        # engine_id -> latest published serve telemetry
        self._published: dict[str, LoadSignals] = {}
        # dest engine_id -> callable(req_id, payload) consuming a KV
        # handoff (disaggregation: prefill worker -> decode worker)
        self._handoff_sinks: dict[str, Callable[[int, bytes], None]] = {}
        self.handoffs = 0

    # ------------------------------------------------------------- policy
    @property
    def policy(self) -> object:
        return self._policy

    @policy.setter
    def policy(self, value: PolicyLike) -> None:
        """Accepts a SchedulingPolicy instance or a legacy alias string
        ("xartrek" | "always_host" | "always_aux" | "always_accel" |
        "latency_aware") — callers flip it mid-stream in benchmarks."""
        self._policy = resolve_policy(value)

    # ------------------------------------------------------------ signals
    def publish(self, engine_id: str, signals: LoadSignals) -> None:
        """Engine-side telemetry feed: the latest snapshot per engine
        (no history — the policy wants current pressure, not a log)."""
        with self._lock:
            self._published[engine_id] = signals

    def signals(self) -> LoadSignals:
        """The policy input: the monitor's process counts merged with
        the cross-engine aggregate of published serve telemetry.
        Queued-but-unadmitted requests count into ``x86_load`` — the
        paper's load is "processes on or queued for the host", and a
        request waiting for a slot is queued host work."""
        base = self.monitor.signals()
        with self._lock:
            published = list(self._published.values())
        if not published:
            return base
        agg = LoadSignals.aggregate(published)
        return dataclasses.replace(
            agg,
            x86_load=base.x86_load + agg.queue_depth,
            aux_load=base.aux_load,
            accel_load=base.accel_load,
            band=self.monitor.band(
                int(base.x86_load + base.aux_load + base.accel_load
                    + agg.queue_depth)),
        )

    # ------------------------------------------------------------ handoff
    def register_handoff_sink(self, engine_id: str,
                              sink: Callable[[int, bytes], None]) -> None:
        """Bind a decode-role worker's span consumer: ``handoff`` calls
        deliver serialized KV spans addressed to ``engine_id`` here."""
        with self._lock:
            self._handoff_sinks[engine_id] = sink

    def handoff(self, dest: str, req_id: int, payload: bytes) -> None:
        """Deliver one prefill's serialized KV span to ``dest``'s sink.
        The sink runs OUTSIDE the lock — it rehydrates pool blocks and
        must not block scheduling decisions."""
        with self._lock:
            sink = self._handoff_sinks.get(dest)
            if sink is None:
                raise KeyError(f"no handoff sink registered for {dest!r}")
            self.handoffs += 1
        sink(req_id, payload)

    def register_kernel(self, kernel: str, bank: KernelBank) -> None:
        """Bind a hardware kernel to the bank that can load it (each
        cluster worker's runtime owns its own compiled variants)."""
        with self._lock:
            self._owners[kernel] = bank

    def residency(self, kernel: str) -> Residency:
        bank = self._owners.get(kernel, self.bank)
        if bank is None:
            return Residency()
        return Residency(resident=bank.is_resident(kernel),
                         loading=bank.is_loading(kernel))

    # ------------------------------------------------------------- server
    def request(self, app: str) -> Decision:
        """Handle one client scheduling request (Algorithm 2 l.5-8)."""
        row = self.table.row(app)
        sig = self.signals()
        res = self.residency(row.hw_kernel)
        with self._lock:
            d = self._policy.decide(sig, row, res)
            self.decisions[d.target] += 1
            if d.reconfigure:
                self.reconfigs += 1
                bank = self._owners.get(row.hw_kernel, self.bank)
        if d.reconfigure and bank is not None:
            bank.load_async(row.hw_kernel)      # async; outside the lock
        return d

    def report(self, app: str, executed_on: TargetKind, exec_time: float,
               cpu_load: Optional[float] = None) -> None:
        """Client post-return report -> Algorithm 1 threshold update."""
        load = self.monitor.x86_load() if cpu_load is None else cpu_load
        with self._lock:
            self.table.update(app, executed_on, exec_time, load)


@dataclasses.dataclass
class SchedulerClient:
    """Instrumented into each application binary (step B)."""

    app: str
    server: SchedulerServer

    def before_call(self) -> Decision:
        return self.server.request(self.app)

    def after_call(self, executed_on: TargetKind, exec_time: float,
                   cpu_load: Optional[float] = None) -> None:
        self.server.report(self.app, executed_on, exec_time, cpu_load)

    def publish(self, engine_id: str, signals: LoadSignals) -> None:
        self.server.publish(engine_id, signals)

    def handoff(self, dest: str, req_id: int, payload: bytes) -> None:
        self.server.handoff(dest, req_id, payload)


# --------------------------------------------------------------- TCP mode

class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        for raw in self.rfile:
            try:
                msg = json.loads(raw)
                if msg["op"] == "request":
                    d = self.server.xar.request(msg["app"])
                    resp = {"flag": d.flag, "reconfigure": d.reconfigure}
                elif msg["op"] == "report":
                    self.server.xar.report(
                        msg["app"], TargetKind(msg["target"]),
                        float(msg["exec_time"]), msg.get("cpu_load"))
                    resp = {"ok": True}
                elif msg["op"] == "publish":
                    self.server.xar.publish(
                        msg["engine"], LoadSignals(**msg["signals"]))
                    resp = {"ok": True}
                elif msg["op"] == "handoff":
                    self.server.xar.handoff(
                        msg["dest"], int(msg["req_id"]),
                        base64.b64decode(msg["payload"]))
                    resp = {"ok": True}
                else:
                    resp = {"error": f"unknown op {msg['op']}"}
            except Exception as e:  # noqa: BLE001 — report to client
                resp = {"error": str(e)}
            self.wfile.write((json.dumps(resp) + "\n").encode())
            self.wfile.flush()


class TcpSchedulerServer:
    """Paper-faithful socket transport around a SchedulerServer."""

    def __init__(self, inner: SchedulerServer, host: str = "127.0.0.1",
                 port: int = 0):
        self.inner = inner
        self._srv = socketserver.ThreadingTCPServer((host, port), _Handler)
        self._srv.daemon_threads = True
        self._srv.xar = inner
        self.address = self._srv.server_address
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    def start(self) -> tuple[str, int]:
        self._thread.start()
        return self.address

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()


class TcpSchedulerClient:
    def __init__(self, app: str, address: tuple[str, int]):
        self.app = app
        self._sock = socket.create_connection(address)
        self._file = self._sock.makefile("rw")
        self._lock = threading.Lock()    # one in-flight rpc per connection

    def _rpc(self, msg: dict) -> dict:
        with self._lock:
            self._file.write(json.dumps(msg) + "\n")
            self._file.flush()
            return json.loads(self._file.readline())

    def before_call(self) -> Decision:
        resp = self._rpc({"op": "request", "app": self.app})
        kind = {0: TargetKind.HOST, 1: TargetKind.AUX,
                2: TargetKind.ACCEL}[resp["flag"]]
        return Decision(kind, reconfigure=resp["reconfigure"])

    def after_call(self, executed_on: TargetKind, exec_time: float,
                   cpu_load: Optional[float] = None) -> None:
        self._rpc({"op": "report", "app": self.app,
                   "target": executed_on.value, "exec_time": exec_time,
                   "cpu_load": cpu_load})

    def publish(self, engine_id: str, signals: LoadSignals) -> None:
        self._rpc({"op": "publish", "engine": engine_id,
                   "signals": dataclasses.asdict(signals)})

    def handoff(self, dest: str, req_id: int, payload: bytes) -> None:
        resp = self._rpc({"op": "handoff", "dest": dest, "req_id": req_id,
                          "payload": base64.b64encode(payload).decode()})
        if "error" in resp:
            raise RuntimeError(f"handoff failed: {resp['error']}")

    def close(self) -> None:
        self._sock.close()
