"""Run-time scheduler: client/server split, as in the paper (§3.2).

The server owns the policy (Algorithm 2), the threshold table
(Algorithm 1 updates arrive via client reports), the kernel bank and
the load monitor.  A client instance is bound to each application/job;
it queries the server *before* the selected function's call (receiving
the migration flag) and reports *after* it returns.

Two transports: in-process (default — one JAX process drives the fleet)
and a line-JSON TCP transport mirroring the paper's socket setup (used
by the multi-process example and tests).
"""
from __future__ import annotations

import dataclasses
import json
import socket
import socketserver
import threading
from typing import Optional

from repro.core.kernel_bank import KernelBank
from repro.core.monitor import LoadMonitor
from repro.core.policy import Decision, schedule
from repro.core.targets import Platform, TargetKind
from repro.core.thresholds import ThresholdTable


class SchedulerServer:
    def __init__(self, platform: Platform, table: ThresholdTable,
                 bank: KernelBank,
                 monitor: Optional[LoadMonitor] = None,
                 policy: str = "xartrek"):
        self.platform = platform
        self.table = table
        self.bank = bank
        self.monitor = monitor or LoadMonitor(platform)
        self.policy = policy     # xartrek | always_host | always_aux | always_accel
        self._lock = threading.Lock()
        self.decisions = {k: 0 for k in TargetKind}
        self.reconfigs = 0

    # ------------------------------------------------------------- server
    def request(self, app: str) -> Decision:
        """Handle one client scheduling request (Algorithm 2 l.5-8)."""
        with self._lock:
            if self.policy == "always_host":
                d = Decision(TargetKind.HOST)
            elif self.policy == "always_aux":
                d = Decision(TargetKind.AUX)
            elif self.policy == "always_accel":
                d = Decision(TargetKind.ACCEL)
            else:
                row = self.table.row(app)
                load = self.monitor.x86_load()
                d = schedule(load, row, self.bank.is_resident(row.hw_kernel))
            self.decisions[d.target] += 1
        if d.reconfigure:
            self.reconfigs += 1
            self.bank.load_async(self.table.row(app).hw_kernel)
        return d

    def report(self, app: str, executed_on: TargetKind, exec_time: float,
               cpu_load: Optional[float] = None) -> None:
        """Client post-return report -> Algorithm 1 threshold update."""
        load = self.monitor.x86_load() if cpu_load is None else cpu_load
        with self._lock:
            self.table.update(app, executed_on, exec_time, load)


@dataclasses.dataclass
class SchedulerClient:
    """Instrumented into each application binary (step B)."""

    app: str
    server: SchedulerServer

    def before_call(self) -> Decision:
        return self.server.request(self.app)

    def after_call(self, executed_on: TargetKind, exec_time: float,
                   cpu_load: Optional[float] = None) -> None:
        self.server.report(self.app, executed_on, exec_time, cpu_load)


# --------------------------------------------------------------- TCP mode

class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        for raw in self.rfile:
            try:
                msg = json.loads(raw)
                if msg["op"] == "request":
                    d = self.server.xar.request(msg["app"])
                    resp = {"flag": d.flag, "reconfigure": d.reconfigure}
                elif msg["op"] == "report":
                    self.server.xar.report(
                        msg["app"], TargetKind(msg["target"]),
                        float(msg["exec_time"]), msg.get("cpu_load"))
                    resp = {"ok": True}
                else:
                    resp = {"error": f"unknown op {msg['op']}"}
            except Exception as e:  # noqa: BLE001 — report to client
                resp = {"error": str(e)}
            self.wfile.write((json.dumps(resp) + "\n").encode())
            self.wfile.flush()


class TcpSchedulerServer:
    """Paper-faithful socket transport around a SchedulerServer."""

    def __init__(self, inner: SchedulerServer, host: str = "127.0.0.1",
                 port: int = 0):
        self.inner = inner
        self._srv = socketserver.ThreadingTCPServer((host, port), _Handler)
        self._srv.daemon_threads = True
        self._srv.xar = inner
        self.address = self._srv.server_address
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    def start(self) -> tuple[str, int]:
        self._thread.start()
        return self.address

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()


class TcpSchedulerClient:
    def __init__(self, app: str, address: tuple[str, int]):
        self.app = app
        self._sock = socket.create_connection(address)
        self._file = self._sock.makefile("rw")

    def _rpc(self, msg: dict) -> dict:
        self._file.write(json.dumps(msg) + "\n")
        self._file.flush()
        return json.loads(self._file.readline())

    def before_call(self) -> Decision:
        resp = self._rpc({"op": "request", "app": self.app})
        kind = {0: TargetKind.HOST, 1: TargetKind.AUX,
                2: TargetKind.ACCEL}[resp["flag"]]
        return Decision(kind, reconfigure=resp["reconfigure"])

    def after_call(self, executed_on: TargetKind, exec_time: float,
                   cpu_load: Optional[float] = None) -> None:
        self._rpc({"op": "report", "app": self.app,
                   "target": executed_on.value, "exec_time": exec_time,
                   "cpu_load": cpu_load})

    def close(self) -> None:
        self._sock.close()
