"""Step C/D — multi-target binary generation (the Popcorn/Vitis analogue).

``MultiTargetBinary`` AOT-lowers and compiles every variant of a
MigratableFunction with its per-target shardings.  The shared pytree
treedef + dtypes across variants are the aligned ABI (Popcorn's symbol
alignment); ``serialized_sizes`` reproduces the paper's Figure-10
binary-size comparison using ``jax.export``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Optional

import jax

from repro.parallel.compat import use_mesh
from repro.core.function import MigratableFunction
from repro.core.policy import ewma
from repro.core.targets import TargetKind


def shape_key(args: tuple) -> tuple:
    """Hashable (treedef, leaf shapes/dtypes) signature of a call's args.
    Computed per runtime call, so no stringification — PyTreeDef hashes
    and compares natively, shapes/dtypes are already hashable.

    Every leaf participates, so paged-decode calls key on their
    block-table shape (B, table_width) alongside the cache pool and
    token leaves: a paged engine's steady-state decode signature is
    static and compiles exactly once, outside Algorithm 1's timed
    region.  The prefix-cache chunked prefill (``{prefix}_prefill_ctx``)
    keys the same way: its ``offset``/``length`` leaves are (1,) DATA
    vectors — match length and feed length vary per request without
    forking the signature — so only the chunk's power-of-two token
    bucket (and the pool/table shapes, static per engine) key the
    compile, bounding it to O(log max_chunk) buckets exactly like the
    plain bucketed prefill.  Non-array leaves (python scalars riding in
    a batch dict) key on (type, value) — a changed static scalar must
    not silently reuse another signature's executable."""
    leaves, treedef = jax.tree.flatten(args)
    return (treedef, tuple(
        (l.shape, l.dtype) if hasattr(l, "shape") else (type(l), l)
        for l in leaves))


@dataclasses.dataclass
class CompiledVariant:
    kind: TargetKind
    compiled: Any                  # jax.stages.Compiled
    compile_seconds: float
    flops: float = 0.0
    bytes_accessed: float = 0.0

    def __call__(self, *args):
        return self.compiled(*args)


class MultiTargetBinary:
    """All compiled variants of one function for one mesh."""

    def __init__(self, fn: MigratableFunction,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 donate_argnums: tuple = (),
                 max_shape_buckets: int = 8):
        self.fn = fn
        self.mesh = mesh
        self.donate_argnums = donate_argnums
        self.variants: dict[TargetKind, CompiledVariant] = {}
        self._jitted: dict[TargetKind, Any] = {}
        # shape-bucketed recompile cache: continuous batching calls the
        # same function with varying prefill widths; each shape signature
        # compiles once and lives in a small per-target LRU (per-target so
        # migration between kinds can't thrash the other kind's buckets)
        self.max_shape_buckets = max_shape_buckets
        self._default_keys: dict[TargetKind, tuple] = {}
        self._shape_cache: dict[TargetKind,
                                OrderedDict[tuple, CompiledVariant]] = {}
        self.shape_stats = {"hits": 0, "misses": 0, "evictions": 0}
        # per-target compile accounting (default + shape-bucket compiles),
        # surfaced by XarTrekRuntime.summary()
        self.compile_stats: dict[TargetKind, dict] = {}

    def _jit(self, kind: TargetKind):
        if kind not in self._jitted:
            fn = self.fn.variants[kind]
            kw = {}
            if kind in self.fn.shardings:
                in_s, out_s = self.fn.shardings[kind]
                kw = {"in_shardings": in_s, "out_shardings": out_s}
            self._jitted[kind] = jax.jit(
                fn, donate_argnums=self.donate_argnums, **kw)
        return self._jitted[kind]

    def _compile_specs(self, kind: TargetKind, specs: tuple) -> CompiledVariant:
        t0 = time.perf_counter()
        jitted = self._jit(kind)
        if self.mesh is not None:
            with use_mesh(self.mesh):
                lowered = jitted.lower(*specs)
                compiled = lowered.compile()
        else:
            lowered = jitted.lower(*specs)
            compiled = lowered.compile()
        dt = time.perf_counter() - t0
        flops = bytes_acc = 0.0
        try:
            cost = compiled.cost_analysis() or {}
            flops = float(cost.get("flops", 0.0))
            bytes_acc = float(cost.get("bytes accessed", 0.0))
        except Exception:
            pass
        cs = self.compile_stats.setdefault(
            kind, {"compiles": 0, "compile_seconds": 0.0})
        cs["compiles"] += 1
        cs["compile_seconds"] += dt
        return CompiledVariant(kind=kind, compiled=compiled,
                               compile_seconds=dt, flops=flops,
                               bytes_accessed=bytes_acc)

    def compile(self, kind: TargetKind, *example_specs) -> CompiledVariant:
        """Lower + compile one variant (used eagerly at launch for HOST,
        asynchronously by the KernelBank for ACCEL)."""
        if kind in self.variants:
            return self.variants[kind]
        cv = self._compile_specs(kind, example_specs)
        self.variants[kind] = cv
        self._default_keys[kind] = shape_key(example_specs)
        return cv

    def variant_for(self, kind: TargetKind, args: tuple) -> CompiledVariant:
        """Compiled variant matching ``args``' exact shapes: the eagerly
        compiled default when the signature matches, else a bounded-LRU
        shape-bucket recompile (ragged continuous-batching prefills)."""
        key = shape_key(args)
        if self._default_keys.get(kind) == key:
            return self.variants[kind]
        lru = self._shape_cache.setdefault(kind, OrderedDict())
        cv = lru.get(key)
        if cv is not None:
            lru.move_to_end(key)
            self.shape_stats["hits"] += 1
            return cv
        self.shape_stats["misses"] += 1
        specs = tuple(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), a)
            for a in args)
        cv = lru[key] = self._compile_specs(kind, specs)
        while len(lru) > self.max_shape_buckets:
            lru.popitem(last=False)
            self.shape_stats["evictions"] += 1
        return cv

    def note_exec(self, kind: TargetKind, ms: float) -> None:
        """Record one executed call's wall time against the target's
        stats: ``recent_exec_ms`` is an EWMA of the step time — the
        per-target speed signal ``LoadSignals`` carries to scheduling
        policies (a LatencyAwarePolicy compares HOST vs ACCEL step cost
        from here, not from a synthetic profile)."""
        cs = self.compile_stats.setdefault(
            kind, {"compiles": 0, "compile_seconds": 0.0})
        cs["calls"] = cs.get("calls", 0) + 1
        cs["recent_exec_ms"] = ewma(cs.get("recent_exec_ms"), ms)

    def compile_all(self, *example_specs) -> None:
        for kind in self.fn.targets():
            self.compile(kind, *example_specs)

    def is_compiled(self, kind: TargetKind) -> bool:
        return kind in self.variants

    # ------------------------------------------------------ Fig-10 support
    def serialized_sizes(self, *example_specs) -> dict[str, int]:
        """Bytes of the exported (serialized) executable per target."""
        sizes = {}
        for kind in self.fn.targets():
            jitted = self._jit(kind)
            try:
                exported = jax.export.export(jitted)(*example_specs)
                sizes[kind.value] = len(exported.serialize())
            except Exception:
                # fall back to HLO text size if export unsupported
                lowered = jitted.lower(*example_specs)
                sizes[kind.value] = len(lowered.as_text())
        return sizes
