"""Xar-Trek core: run-time execution migration across heterogeneous targets.

The paper's contribution, adapted to a JAX/TPU fleet (DESIGN.md §2):

  compiler side                      run-time side
  -------------                      -------------
  profile.py     (step A)            monitor.py    (x86 CPU load)
  function.py    (step B)            thresholds.py (Algorithm 1)
  binary.py      (step C, Popcorn)   policy.py     (Algorithm 2)
  kernel_bank.py (steps D-F, XCLBIN) scheduler.py  (client/server)
  estimator.py   (step G)            migration.py  (state transformation)
                                     runtime.py    (ties it together)
  sim.py: calibrated discrete-event platform model used to reproduce the
  paper's evaluation (Tables 1-4, Figures 3-9) on this CPU-only box.
"""
from repro.core.targets import TargetKind, ExecutionTarget, DEFAULT_PLATFORM
from repro.core.thresholds import ThresholdTable, ThresholdRow
from repro.core.policy import schedule, Decision
from repro.core.scheduler import SchedulerServer, SchedulerClient
