"""Step A — profiling manifest.

The paper's manual profiling step emits a text file naming (1) the
hardware platform, (2) the applications, and (3) the selected functions
per application.  We keep that exact artifact (it seeds instrumentation
and the Xilinx-object/XCLBIN steps) as a parse/serialize round-trippable
format:

    platform: tpu-v5e-256
    application: digitrec
      function: knn_digits targets: host,aux,accel
    application: facedet
      function: window_scores targets: host,accel
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class FunctionEntry:
    name: str
    targets: tuple[str, ...]       # subset of {host, aux, accel}


@dataclasses.dataclass
class ApplicationEntry:
    name: str
    functions: list[FunctionEntry] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ProfileManifest:
    platform: str
    applications: list[ApplicationEntry] = dataclasses.field(
        default_factory=list)

    def selected(self) -> list[tuple[str, FunctionEntry]]:
        return [(app.name, fn) for app in self.applications
                for fn in app.functions]

    def dumps(self) -> str:
        lines = [f"platform: {self.platform}"]
        for app in self.applications:
            lines.append(f"application: {app.name}")
            for fn in app.functions:
                lines.append(
                    f"  function: {fn.name} targets: {','.join(fn.targets)}")
        return "\n".join(lines) + "\n"

    @classmethod
    def loads(cls, text: str) -> "ProfileManifest":
        platform = ""
        apps: list[ApplicationEntry] = []
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if line.startswith("platform:"):
                platform = line.split(":", 1)[1].strip()
            elif line.startswith("application:"):
                apps.append(ApplicationEntry(line.split(":", 1)[1].strip()))
            elif line.startswith("function:"):
                body = line.split(":", 1)[1]
                name, _, tgt = body.partition("targets:")
                apps[-1].functions.append(FunctionEntry(
                    name.strip(),
                    tuple(t.strip() for t in tgt.split(",") if t.strip())))
        return cls(platform=platform, applications=apps)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def load(cls, path: str) -> "ProfileManifest":
        with open(path) as f:
            return cls.loads(f.read())
