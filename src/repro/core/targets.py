"""Execution targets: the x86 / ARM / FPGA triad mapped to a TPU fleet.

A target is (device-pool class, kernel-implementation set, capacity).
``HOST`` is the default contended pool (paper: x86 Xeon, 6 cores);
``AUX`` a larger but per-unit-slower pool (paper: ThunderX ARM, 96
cores); ``ACCEL`` the hardware-kernel path (paper: Alveo FPGA; here:
Pallas-kernel step variants behind the KernelBank).
"""
from __future__ import annotations

import dataclasses
import enum


class TargetKind(enum.Enum):
    HOST = "host"    # paper: x86 (flag 0: do not migrate)
    AUX = "aux"      # paper: ARM (flag 1: software migration)
    ACCEL = "accel"  # paper: FPGA (flag 2: hardware migration)

    @property
    def flag(self) -> int:
        return {"host": 0, "aux": 1, "accel": 2}[self.value]


@dataclasses.dataclass(frozen=True)
class ExecutionTarget:
    name: str
    kind: TargetKind
    capacity: int                  # concurrent job slots ("cores")
    kernel_impl: str               # "ref" | "pallas"
    speed_factor: float = 1.0      # per-slot relative speed vs HOST slot
    migrate_overhead_s: float = 0.0  # in-locus measured xfer cost (estimator refines)


@dataclasses.dataclass(frozen=True)
class Platform:
    """A heterogeneous server: one target per kind (paper's Figure 2)."""

    host: ExecutionTarget
    aux: ExecutionTarget
    accel: ExecutionTarget
    accel_slots: int = 4           # XCLBIN kernel slots ("FPGA area")
    reconfig_latency_s: float = 4.0  # Alveo partial-reconfig order of magnitude

    def by_kind(self, kind: TargetKind) -> ExecutionTarget:
        return {TargetKind.HOST: self.host, TargetKind.AUX: self.aux,
                TargetKind.ACCEL: self.accel}[kind]

    @property
    def total_cores(self) -> int:
        return self.host.capacity + self.aux.capacity


# The paper's evaluation platform (Table 3: 6 x86 + 96 ARM cores).
DEFAULT_PLATFORM = Platform(
    host=ExecutionTarget("xeon-x86", TargetKind.HOST, capacity=6,
                         kernel_impl="ref", speed_factor=1.0),
    aux=ExecutionTarget("thunderx-arm", TargetKind.AUX, capacity=96,
                        kernel_impl="ref", speed_factor=0.26,
                        migrate_overhead_s=0.05),
    accel=ExecutionTarget("alveo-fpga", TargetKind.ACCEL, capacity=1,
                          kernel_impl="pallas", speed_factor=1.0,
                          migrate_overhead_s=0.02),
)

# The TPU-fleet flavour used by the JAX-native runtime/examples: HOST is
# the default XLA path, AUX an alternative sharding on a second pool,
# ACCEL the Pallas kernel variants.
TPU_PLATFORM = Platform(
    host=ExecutionTarget("pool-default-xla", TargetKind.HOST, capacity=6,
                         kernel_impl="ref"),
    aux=ExecutionTarget("pool-aux-xla", TargetKind.AUX, capacity=96,
                        kernel_impl="ref", speed_factor=0.26,
                        migrate_overhead_s=0.02),
    accel=ExecutionTarget("pallas-kernels", TargetKind.ACCEL, capacity=1,
                          kernel_impl="pallas", migrate_overhead_s=0.01),
)
