"""Scheduling policies: the pluggable placement surface of the run-time.

The paper's run-time splits *mechanism* (compiled multi-target variants,
the kernel bank, migration) from *decision* (Algorithm 2).  This module
is the decision side, redesigned as a first-class protocol so the
decision can be swapped without touching the mechanism:

  ``SchedulingPolicy.decide(signals, row, residency) -> Decision``

* ``LoadSignals`` is everything a placement decision may consult — the
  paper's synthetic x86 process count PLUS real serve-engine telemetry
  (queue depth, free KV-block fraction, per-target recent decode
  milliseconds, TTFT/TPOT percentiles).  Engines publish one per step;
  the scheduler server aggregates across engines, so one engine's load
  pressure is visible to every co-tenant's decision.
* ``ThresholdRow`` is the compiler's Table-2 artifact for the function
  being placed (Algorithm 1 keeps refining it).
* ``Residency`` is the accelerator state for that function's hardware
  kernel (bank-resident / reconfiguration in flight).

Built-ins:

* ``XarTrekHeuristic`` — Algorithm 2, numerics unchanged (it delegates
  to the legacy ``schedule`` free function, which remains the
  line-annotated faithful port).
* ``PinHost`` / ``PinAux`` / ``PinAccel`` — the static placements that
  used to be the scheduler's ``"always_*"`` strings and the serve
  engine's ``backend="host"/"accel"`` special cases.
* ``LatencyAwarePolicy`` — decides from serve-level signals instead of
  the process counter: offloads decode to ACCEL under queue/KV/TTFT
  pressure (kicking an async reconfiguration first when the kernel is
  cold — the paper's §3.4 latency-hiding), returns to HOST when the
  pressure drains.

Policies move *placement only*: every target serves the same math (the
sampling transform is traced identically into each build), so outputs
are byte-identical across policies — the serve analogue of "migration
is transparent to the application".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Union, runtime_checkable

from repro.core.targets import TargetKind
from repro.core.thresholds import ThresholdRow


@dataclasses.dataclass(frozen=True)
class Decision:
    target: TargetKind
    reconfigure: bool = False      # start async ACCEL load of this kernel

    @property
    def flag(self) -> int:
        return self.target.flag


@dataclasses.dataclass(frozen=True)
class Residency:
    """Accelerator state of the function's hardware kernel."""

    resident: bool = False         # bank-resident, callable right now
    loading: bool = False          # async reconfiguration in flight


@dataclasses.dataclass(frozen=True)
class LoadSignals:
    """One policy input: real engine telemetry + the paper's load counter.

    ``x86_load`` is Algorithm 2's signal — processes on (or queued for)
    the host.  The serve-level fields come from the engines themselves
    (``ContinuousBatchingEngine.signals()``); ``None`` means "no
    observation yet" so policies can distinguish cold-start from zero.
    ``engines`` counts how many engines contributed (1 for a lone
    engine; N after ``LoadSignals.aggregate``).
    """

    x86_load: float = 0.0              # host processes (Algorithm 2's input)
    aux_load: float = 0.0
    accel_load: float = 0.0
    band: str = "low"                  # Table-3 low/medium/high band
    queue_depth: int = 0               # requests arrived but not admitted
    active_slots: int = 0              # in-flight decode rows
    free_kv_frac: float = 1.0          # free fraction of KV capacity
    host_decode_ms: Optional[float] = None   # recent decode step ms / target
    accel_decode_ms: Optional[float] = None
    ttft_p50_s: Optional[float] = None
    tpot_p50_s: Optional[float] = None
    # EWMA of per-step decode stall caused by interleaved prefill chunks
    # (ms decode had to wait while a chunk ran); decays toward 0 on
    # chunk-free steps.  The stall-feedback prefill_budget controller's
    # input.  None until an engine with chunking enabled reports.
    decode_stall_ms: Optional[float] = None
    engines: int = 1

    @staticmethod
    def aggregate(signals: list["LoadSignals"]) -> "LoadSignals":
        """Cross-engine aggregate: pressure sums (queue depth, active
        slots, loads), capacity takes the worst engine (min free KV),
        latency observations average over the engines that have any.
        This is the scheduler server's cluster-wide view — one engine's
        pressure raises the aggregate every co-tenant's decision sees."""
        if not signals:
            return LoadSignals(engines=0)

        def mean(vals):
            vals = [v for v in vals if v is not None]
            return sum(vals) / len(vals) if vals else None

        bands = [s.band for s in signals]
        band = ("high" if "high" in bands
                else "medium" if "medium" in bands else "low")
        return LoadSignals(
            x86_load=sum(s.x86_load for s in signals),
            aux_load=sum(s.aux_load for s in signals),
            accel_load=sum(s.accel_load for s in signals),
            band=band,
            queue_depth=sum(s.queue_depth for s in signals),
            active_slots=sum(s.active_slots for s in signals),
            free_kv_frac=min(s.free_kv_frac for s in signals),
            host_decode_ms=mean([s.host_decode_ms for s in signals]),
            accel_decode_ms=mean([s.accel_decode_ms for s in signals]),
            ttft_p50_s=mean([s.ttft_p50_s for s in signals]),
            tpot_p50_s=mean([s.tpot_p50_s for s in signals]),
            decode_stall_ms=mean([s.decode_stall_ms for s in signals]),
            engines=sum(s.engines for s in signals),
        )


def ewma(prev: Optional[float], value: float,
         alpha: float = 0.2) -> float:
    """The telemetry smoother every decode-ms signal source shares
    (binary.note_exec for runtime-dispatched steps, the engine's direct
    path): first observation seeds, later ones blend at ``alpha``."""
    return value if prev is None else (1.0 - alpha) * prev + alpha * value


# --------------------------------------------------------------- protocol

@runtime_checkable
class SchedulingPolicy(Protocol):
    """One placement decision per instrumented call.

    ``decide`` must be pure in the signals/row/residency inputs up to
    the policy's own internal state (a scripted policy may keep a step
    counter) and must never block: it runs under the scheduler server's
    lock on every client request.

    Policies may additionally expose an optional hook

        ``prefill_budget(signals, default) -> Optional[int]``

    consulted by chunked-prefill engines once per scheduler step: return
    the number of prompt tokens admission may prefill this step (``None``
    disables chunking for the step — finish monolithically).  Engines
    fall back to their static ``prefill_tokens_per_step`` when the
    policy has no hook.

    Speculative-decoding engines likewise consult an optional hook

        ``draft_len(signals, default) -> int``

    once per scheduler step: return the number of tokens the draft
    model may propose this round (``0`` disables speculation for the
    step — the engine falls back to plain decode), clamped by the
    engine to its compiled draft width.  Engines use ``default``
    (their configured ``spec_draft_len``) when the policy has no hook.
    Mirrors ``prefill_budget``: both let load shrink work the engine
    would otherwise do optimistically.
    """

    name: str

    def decide(self, signals: LoadSignals, row: ThresholdRow,
               residency: Residency) -> Decision:
        ...


def schedule(cpu_load: float, row: ThresholdRow,
             kernel_resident: bool) -> Decision:
    """One Algorithm-2 evaluation (lines annotated) — the paper's
    heuristic, kept as a free function so its numerics stay auditable
    against the paper; ``XarTrekHeuristic`` is its protocol wrapper."""
    arm_thr, fpga_thr = row.arm_thr, row.fpga_thr

    if (cpu_load <= arm_thr) and (cpu_load > fpga_thr) and not kernel_resident:
        # l.9-13: stay on x86, reconfigure FPGA in the background
        return Decision(TargetKind.HOST, reconfigure=True)
    if (cpu_load > arm_thr) and (cpu_load > fpga_thr) and not kernel_resident:
        # l.14-18: migrate to ARM, reconfigure FPGA in the background
        return Decision(TargetKind.AUX, reconfigure=True)
    if (cpu_load <= arm_thr) and (cpu_load <= fpga_thr):
        # l.19-21: low load -> stay on x86
        return Decision(TargetKind.HOST)
    if (cpu_load > arm_thr) and (cpu_load <= fpga_thr):
        # l.22-24: only ARM profitable
        return Decision(TargetKind.AUX)
    if (cpu_load > fpga_thr) and kernel_resident:
        # l.25-31: smaller threshold implies smaller execution time
        if fpga_thr < arm_thr:                                  # l.26-27
            return Decision(TargetKind.ACCEL)
        return Decision(TargetKind.AUX)                         # l.29-30
    # unreachable given the four exhaustive load/residency cases above,
    # but the paper's default is "continue on x86"
    return Decision(TargetKind.HOST)


# --------------------------------------------------------------- built-ins

class XarTrekHeuristic:
    """Algorithm 2 behind the protocol — numerics identical to
    ``schedule`` (regression-tested branch by branch)."""

    name = "xartrek"

    def decide(self, signals: LoadSignals, row: ThresholdRow,
               residency: Residency) -> Decision:
        return schedule(signals.x86_load, row, residency.resident)


class _Pin:
    """Static placement; absorbs the old ``"always_*"`` policy strings
    and the serve engine's ``backend="host"/"accel"`` escape hatches."""

    target: TargetKind

    def decide(self, signals: LoadSignals, row: ThresholdRow,
               residency: Residency) -> Decision:
        reconf = (self.target == TargetKind.ACCEL
                  and not residency.resident and not residency.loading)
        return Decision(self.target, reconfigure=reconf)


class PinHost(_Pin):
    name = "always_host"
    target = TargetKind.HOST


class PinAux(_Pin):
    name = "always_aux"
    target = TargetKind.AUX


class PinAccel(_Pin):
    """Pin to ACCEL.  While the kernel is still cold the decision keeps
    requesting an async reconfiguration; the runtime's mechanism layer
    falls back to HOST for the calls in between (latency hiding), so
    pinning never blocks on a compile."""

    name = "always_accel"
    target = TargetKind.ACCEL


@dataclasses.dataclass
class LatencyAwarePolicy:
    """Serve-signal-driven placement (no synthetic process counter).

    Pressure is any of: queue depth at/above ``queue_depth_hi``, free KV
    capacity at/below ``free_kv_lo``, or TTFT p50 above ``ttft_slo_s``.
    Under pressure the decode offloads to ACCEL — freeing the contended
    host for co-tenants, exactly Algorithm 2's rationale — kicking an
    async reconfiguration first if the kernel is cold.  Without
    pressure it serves on HOST, unless the measured ACCEL step time is
    strictly faster than HOST's (then ACCEL is simply the better
    device and there is no reason to come back).

    When ``prefill_tokens_per_step`` is set the policy also implements
    the chunked-prefill budget hook: the budget applies only while
    decodes are actually in flight (``active_slots > 0``) — an idle
    engine prefills monolithically, since there is nothing to stall.
    The budget is stall-feedback controlled: when the engines report a
    ``decode_stall_ms`` EWMA above ``stall_target_ms``, the budget
    contracts proportionally (``target / stall``, floored at one
    token) so decode stops paying for oversized chunks; at or below
    target the full configured budget applies.  Set
    ``stall_target_ms=None`` for the old static knob.

    ``draft_len`` implements the speculative-decoding hook the same
    way: draft length is an optimism dial, so queue pressure halves it
    and hard pressure (``pressured``) disables speculation outright —
    under load, guaranteed-progress plain decode beats speculative
    work that may be thrown away.
    """

    queue_depth_hi: int = 4
    free_kv_lo: float = 0.125
    ttft_slo_s: Optional[float] = None
    prefill_tokens_per_step: Optional[int] = None
    stall_target_ms: Optional[float] = 50.0
    name: str = "latency_aware"

    def pressured(self, s: LoadSignals) -> bool:
        return (s.queue_depth >= self.queue_depth_hi
                or s.free_kv_frac <= self.free_kv_lo
                or (self.ttft_slo_s is not None
                    and s.ttft_p50_s is not None
                    and s.ttft_p50_s > self.ttft_slo_s))

    def decide(self, signals: LoadSignals, row: ThresholdRow,
               residency: Residency) -> Decision:
        accel_strictly_faster = (
            signals.accel_decode_ms is not None
            and signals.host_decode_ms is not None
            and signals.accel_decode_ms < signals.host_decode_ms)
        want_accel = self.pressured(signals) or accel_strictly_faster
        if not want_accel:
            return Decision(TargetKind.HOST)
        if residency.resident:
            return Decision(TargetKind.ACCEL)
        # cold kernel: stay on HOST while the bank loads (§3.4)
        return Decision(TargetKind.HOST, reconfigure=not residency.loading)

    def prefill_budget(self, signals: LoadSignals,
                       default: Optional[int] = None) -> Optional[int]:
        budget = self.prefill_tokens_per_step or default
        if budget is None or signals.active_slots == 0:
            return None        # nothing to stall: prefill monolithically
        stall = signals.decode_stall_ms
        if (self.stall_target_ms is not None and stall is not None
                and stall > self.stall_target_ms):
            # stall-feedback contraction: chunk cost is ~linear in chunk
            # tokens, so scaling by target/stall steers the EWMA back to
            # the target; the floor keeps prefill from starving outright
            return max(int(budget * self.stall_target_ms / stall), 1)
        return budget

    def draft_len(self, signals: LoadSignals, default: int = 4) -> int:
        if self.pressured(signals):
            return 0           # hard pressure: no speculative work
        if signals.queue_depth >= max(self.queue_depth_hi // 2, 1):
            return max(default // 2, 1)
        return default


# legacy policy strings -> protocol instances (the scheduler server and
# the simulator accept either form)
POLICY_ALIASES = {
    "xartrek": XarTrekHeuristic,
    "always_host": PinHost,
    "always_aux": PinAux,
    "always_accel": PinAccel,
    "latency_aware": LatencyAwarePolicy,
}

PolicyLike = Union[str, SchedulingPolicy]


def resolve_policy(policy: PolicyLike) -> SchedulingPolicy:
    """Accepts a SchedulingPolicy instance or a legacy string alias."""
    if isinstance(policy, str):
        try:
            return POLICY_ALIASES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown policy {policy!r}; expected one of "
                f"{sorted(POLICY_ALIASES)} or a SchedulingPolicy") from None
    if isinstance(policy, type):           # a policy CLASS: instantiate
        policy = policy()
    if callable(getattr(policy, "decide", None)):
        return policy
    raise TypeError(f"not a SchedulingPolicy: {policy!r}")
