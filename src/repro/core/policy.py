"""Algorithm 2 — Xar-Trek's scheduling policy, faithful port.

Inputs: current x86 load, the app's threshold row, and whether the app's
hardware kernel is resident on the accelerator.  Output: the migration
flag (HOST/AUX/ACCEL) plus whether to kick an asynchronous accelerator
reconfiguration (the latency-hiding trick of §3.4: while the kernel is
being loaded, execution continues on a CPU target).
"""
from __future__ import annotations

import dataclasses

from repro.core.targets import TargetKind
from repro.core.thresholds import ThresholdRow


@dataclasses.dataclass(frozen=True)
class Decision:
    target: TargetKind
    reconfigure: bool = False      # start async ACCEL load of this kernel

    @property
    def flag(self) -> int:
        return self.target.flag


def schedule(cpu_load: float, row: ThresholdRow,
             kernel_resident: bool) -> Decision:
    """One Algorithm-2 evaluation (lines annotated)."""
    arm_thr, fpga_thr = row.arm_thr, row.fpga_thr

    if (cpu_load <= arm_thr) and (cpu_load > fpga_thr) and not kernel_resident:
        # l.9-13: stay on x86, reconfigure FPGA in the background
        return Decision(TargetKind.HOST, reconfigure=True)
    if (cpu_load > arm_thr) and (cpu_load > fpga_thr) and not kernel_resident:
        # l.14-18: migrate to ARM, reconfigure FPGA in the background
        return Decision(TargetKind.AUX, reconfigure=True)
    if (cpu_load <= arm_thr) and (cpu_load <= fpga_thr):
        # l.19-21: low load -> stay on x86
        return Decision(TargetKind.HOST)
    if (cpu_load > arm_thr) and (cpu_load <= fpga_thr):
        # l.22-24: only ARM profitable
        return Decision(TargetKind.AUX)
    if (cpu_load > fpga_thr) and kernel_resident:
        # l.25-31: smaller threshold implies smaller execution time
        if fpga_thr < arm_thr:                                  # l.26-27
            return Decision(TargetKind.ACCEL)
        return Decision(TargetKind.AUX)                         # l.29-30
    # unreachable given the four exhaustive load/residency cases above,
    # but the paper's default is "continue on x86"
    return Decision(TargetKind.HOST)
