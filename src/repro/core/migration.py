"""Run-time state transformation: migrate a live state pytree between
targets (the Popcorn stack/register transformation analogue).

In JAX the program state at a function boundary is an explicit pytree
(params, optimizer state, KV cache, RNG), so source->destination
transformation is a resharding ``device_put``.  ``check_abi`` mirrors
Popcorn's requirement that both sides agree on the symbol layout: the
treedefs and leaf shapes/dtypes must match exactly; only shardings may
differ.
"""
from __future__ import annotations

import time
from typing import Any

import jax


class AbiMismatch(ValueError):
    pass


def check_abi(state: Any, dst_shardings: Any) -> None:
    s_tree = jax.tree.structure(state)
    d_tree = jax.tree.structure(dst_shardings)
    if s_tree != d_tree:
        raise AbiMismatch(
            f"state/sharding trees differ: {s_tree} vs {d_tree}")


def migrate(state: Any, dst_shardings: Any, *,
            measure: bool = False) -> Any | tuple[Any, float]:
    """Reshard ``state`` onto the destination target's shardings.

    With ``measure=True`` returns (state, seconds) — the in-locus
    migration cost the estimator folds into its thresholds (§3.1 G).
    """
    check_abi(state, dst_shardings)
    t0 = time.perf_counter()
    out = jax.device_put(state, dst_shardings)
    if measure:
        out = jax.block_until_ready(out)
        return out, time.perf_counter() - t0
    return out


def migration_bytes(state: Any) -> int:
    """Upper bound of bytes moved by a migration (full state size)."""
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(state))
