"""Steps D-F — the KernelBank: XCLBIN partitioning + residency + async load.

The FPGA holds a bounded number of hardware kernels per configuration
image; swapping one in is a multi-second partial reconfiguration.  The
TPU analogue keeps a bounded bank of compiled ACCEL (Pallas-variant)
executables; loading a non-resident one is an asynchronous compile +
warm-up on a background thread.  Algorithm 2's "No HW Kernel" branches
consult ``is_resident``; the latency-hiding behaviour (keep running on
a CPU target until the load completes) falls out naturally.

``partition`` reproduces the XCLBIN-partitioning step: greedy grouping
of kernels into images under a per-image area budget.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional


@dataclasses.dataclass
class BankEntry:
    name: str
    loaded_at: float
    last_used: float
    payload: object = None          # compiled executable (or sim placeholder)


class KernelBank:
    def __init__(self, slots: int = 4,
                 load_fn: Optional[Callable[[str], object]] = None,
                 min_load_seconds: float = 0.0,
                 clock: Callable[[], float] = time.monotonic):
        """load_fn(name) -> payload; runs on the loader thread.

        ``min_load_seconds`` simulates reconfiguration latency when the
        real compile is instant (tests / simulator).
        """
        self.slots = slots
        self.load_fn = load_fn or (lambda name: name)
        self.min_load_seconds = min_load_seconds
        self.clock = clock
        self._resident: dict[str, BankEntry] = {}
        self._loading: dict[str, threading.Thread] = {}
        self._lock = threading.Lock()
        self.stats = {"loads": 0, "evictions": 0, "hits": 0, "misses": 0}

    # ------------------------------------------------------------ queries
    def is_resident(self, name: str) -> bool:
        with self._lock:
            hit = name in self._resident
            self.stats["hits" if hit else "misses"] += 1
            if hit:
                self._resident[name].last_used = self.clock()
            return hit

    def is_loading(self, name: str) -> bool:
        with self._lock:
            t = self._loading.get(name)
            return t is not None and t.is_alive()

    def get(self, name: str) -> object:
        with self._lock:
            e = self._resident[name]
            e.last_used = self.clock()
            return e.payload

    def resident_kernels(self) -> list[str]:
        with self._lock:
            return sorted(self._resident)

    # ------------------------------------------------------------ loading
    def load_async(self, name: str) -> None:
        """Algorithm 2 l.11/16: 'Reconfigure the FPGA' without blocking."""
        with self._lock:
            if name in self._resident:
                return
            t = self._loading.get(name)
            if t is not None and t.is_alive():
                return
            thread = threading.Thread(target=self._do_load, args=(name,),
                                      daemon=True)
            self._loading[name] = thread
            thread.start()

    def load_sync(self, name: str) -> None:
        self.load_async(name)
        t = self._loading.get(name)
        if t is not None:
            t.join()

    def _do_load(self, name: str) -> None:
        t0 = self.clock()
        payload = self.load_fn(name)
        elapsed = self.clock() - t0
        if elapsed < self.min_load_seconds:
            time.sleep(self.min_load_seconds - elapsed)
        with self._lock:
            if len(self._resident) >= self.slots:
                victim = min(self._resident.values(),
                             key=lambda e: e.last_used)
                del self._resident[victim.name]
                self.stats["evictions"] += 1
            now = self.clock()
            self._resident[name] = BankEntry(name=name, loaded_at=now,
                                             last_used=now, payload=payload)
            self.stats["loads"] += 1
            self._loading.pop(name, None)


def partition(kernel_areas: dict[str, float], image_budget: float,
              pinned: Optional[dict[str, int]] = None) -> list[list[str]]:
    """XCLBIN partitioning (step E): greedy first-fit-decreasing grouping
    of kernels into configuration images under an area budget.

    ``pinned`` optionally maps kernel -> image index (the paper's manual
    priority assignment path).
    """
    images: list[list[str]] = []
    loads: list[float] = []
    pinned = pinned or {}
    for name, idx in pinned.items():
        while len(images) <= idx:
            images.append([])
            loads.append(0.0)
        images[idx].append(name)
        loads[idx] += kernel_areas[name]
        if loads[idx] > image_budget:
            raise ValueError(f"pinned image {idx} exceeds budget")
    for name, area in sorted(
            ((n, a) for n, a in kernel_areas.items() if n not in pinned),
            key=lambda kv: -kv[1]):
        if area > image_budget:
            raise ValueError(f"kernel {name} larger than an image budget")
        for i, load in enumerate(loads):
            if load + area <= image_budget:
                images[i].append(name)
                loads[i] += area
                break
        else:
            images.append([name])
            loads.append(area)
    return images
