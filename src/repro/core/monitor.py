"""Load monitor: the paper's "x86 CPU load" (#processes) + Table-3 bands.

The monitor is one SOURCE of scheduling signals, not the policy input
itself any more: ``signals()`` packages the per-target process counts
and the Table-3 band as a ``LoadSignals`` that the scheduler server
merges with engine-published serve telemetry (see ``core.policy``).
"""
from __future__ import annotations

import dataclasses
import threading

from repro.core.targets import Platform, TargetKind


@dataclasses.dataclass
class LoadMonitor:
    platform: Platform

    def __post_init__(self):
        self._active: dict[TargetKind, int] = {k: 0 for k in TargetKind}
        self._lock = threading.Lock()

    def job_started(self, kind: TargetKind) -> None:
        with self._lock:
            self._active[kind] += 1

    def job_finished(self, kind: TargetKind) -> None:
        with self._lock:
            self._active[kind] = max(0, self._active[kind] - 1)

    def active(self, kind: TargetKind) -> int:
        with self._lock:
            return self._active[kind]

    def x86_load(self) -> float:
        """The scheduling signal: processes on (or queued for) the host."""
        return float(self.active(TargetKind.HOST))

    def band(self, total_processes: int) -> str:
        """Table 3: low/medium/high by #processes vs core counts."""
        host = self.platform.host.capacity
        total = self.platform.total_cores
        if total_processes < host:
            return "low"
        if total_processes <= total:
            return "medium"
        return "high"

    def signals(self) -> "LoadSignals":
        """The monitor's contribution to the policy input: per-target
        process counts plus the Table-3 band over the TOTAL processes in
        flight (the banding used to be dead code on the serve path —
        now every published LoadSignals carries it)."""
        from repro.core.policy import LoadSignals
        with self._lock:
            host = float(self._active[TargetKind.HOST])
            aux = float(self._active[TargetKind.AUX])
            accel = float(self._active[TargetKind.ACCEL])
        return LoadSignals(
            x86_load=host, aux_load=aux, accel_load=accel,
            band=self.band(int(host + aux + accel)))
