"""Load monitor: the paper's "x86 CPU load" (#processes) + Table-3 bands."""
from __future__ import annotations

import dataclasses
import threading

from repro.core.targets import Platform, TargetKind


@dataclasses.dataclass
class LoadMonitor:
    platform: Platform

    def __post_init__(self):
        self._active: dict[TargetKind, int] = {k: 0 for k in TargetKind}
        self._lock = threading.Lock()

    def job_started(self, kind: TargetKind) -> None:
        with self._lock:
            self._active[kind] += 1

    def job_finished(self, kind: TargetKind) -> None:
        with self._lock:
            self._active[kind] = max(0, self._active[kind] - 1)

    def active(self, kind: TargetKind) -> int:
        with self._lock:
            return self._active[kind]

    def x86_load(self) -> float:
        """The scheduling signal: processes on (or queued for) the host."""
        return float(self.active(TargetKind.HOST))

    def band(self, total_processes: int) -> str:
        """Table 3: low/medium/high by #processes vs core counts."""
        host = self.platform.host.capacity
        total = self.platform.total_cores
        if total_processes < host:
            return "low"
        if total_processes <= total:
            return "medium"
        return "high"
