"""XarTrekRuntime — the JAX-native integration of compiler + run-time.

Ties the pieces together for *real* jitted step functions:

  * ``prepare`` is the instrumentation the paper injects at main() start:
    eagerly compile the HOST variant, kick the ACCEL variant's
    asynchronous load (FPGA pre-configuration), seed the threshold table.
  * ``call`` is the instrumented call site: scheduler-client query ->
    execute the chosen compiled variant -> measure -> report (Alg. 1).
  * live state handed between variants is resharded via migration.py
    when targets disagree on shardings.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax

from repro.core.binary import MultiTargetBinary
from repro.core.function import GLOBAL_REGISTRY, FunctionRegistry
from repro.core.kernel_bank import KernelBank
from repro.core.migration import migrate
from repro.core.monitor import LoadMonitor
from repro.core.policy import LoadSignals, PolicyLike
from repro.core.scheduler import (
    SchedulerClient, SchedulerServer, TcpSchedulerClient,
)
from repro.core.targets import Platform, TargetKind, TPU_PLATFORM
from repro.core.thresholds import ThresholdTable


class XarTrekRuntime:
    def __init__(self, platform: Platform = TPU_PLATFORM,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 registry: FunctionRegistry = GLOBAL_REGISTRY,
                 table: Optional[ThresholdTable] = None,
                 policy: Optional[PolicyLike] = None,
                 bank_slots: Optional[int] = None,
                 min_reconfig_seconds: float = 0.0,
                 server: Optional[SchedulerServer] = None,
                 scheduler_address: Optional[tuple] = None):
        """``policy`` is a ``SchedulingPolicy`` instance or a legacy
        alias string.  ``server`` shares an EXTERNAL scheduler (the
        cluster case: N runtimes, one central policy over aggregate
        signals) — the runtime then registers its kernels/bank there and
        adopts the server's table and monitor.  ``scheduler_address``
        additionally routes client traffic (request/report/publish) over
        the paper-faithful TCP transport instead of in-process calls."""
        self.platform = platform
        self.mesh = mesh
        self.registry = registry
        self.binaries: dict[str, MultiTargetBinary] = {}
        self._specs: dict[str, tuple] = {}
        self.bank = KernelBank(
            slots=bank_slots or platform.accel_slots,
            load_fn=self._load_accel,
            min_load_seconds=min_reconfig_seconds)
        if server is not None:
            # the central scheduler owns policy and table; a caller who
            # passes either alongside server= would silently get the
            # server's — refuse the ambiguous combination instead
            if policy is not None or table is not None:
                raise ValueError(
                    "policy=/table= conflict with server=: the shared "
                    "scheduler already owns both (set them there)")
            self.server = server
            self.table = server.table
            self.monitor = server.monitor
        else:
            self.table = table or ThresholdTable()
            self.monitor = LoadMonitor(platform)
            self.server = SchedulerServer(platform, self.table, self.bank,
                                          self.monitor,
                                          policy=policy or "xartrek")
        self._scheduler_address = scheduler_address
        self._clients: dict[str, object] = {}
        self.call_log: list[dict] = []

    # ----------------------------------------------------------- prepare
    def prepare(self, fn_name: str, *example_args,
                table_row: Optional[dict] = None,
                donate_argnums: tuple = (),
                eager_accel: bool = False) -> None:
        """main()-start instrumentation: compile HOST now, pre-configure
        ACCEL (asynchronously by default; ``eager_accel=True`` blocks
        until the ACCEL build is bank-resident, so the first migration
        never pays compile time inside the timed region — the serve
        engine's choice), seed thresholds.  ``donate_argnums`` lets
        state-carrying callers (serve decode's KV cache) alias in place."""
        fn = self.registry.get(fn_name)
        fn.check_abi(example_args)
        specs = tuple(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), a)
            for a in example_args)
        self._specs[fn_name] = example_args
        binary = MultiTargetBinary(fn, mesh=self.mesh,
                                   donate_argnums=donate_argnums)
        self.binaries[fn_name] = binary
        binary.compile(TargetKind.HOST, *specs)
        if TargetKind.AUX in fn.variants:
            binary.compile(TargetKind.AUX, *specs)
        row = self.table.row(fn.app, hw_kernel=fn_name)
        if table_row:
            for k, v in table_row.items():
                setattr(row, k, v)
        if TargetKind.ACCEL in fn.variants:
            # bind this kernel to THIS runtime's bank on the scheduler
            # (shared-server clusters: residency and reconfiguration
            # must reach the worker that owns the compiled variants)
            self.server.register_kernel(fn_name, self.bank)
            if eager_accel:
                self.bank.load_sync(fn_name)
            else:
                self.bank.load_async(fn_name)   # pre-configuration

    def _load_accel(self, fn_name: str):
        binary = self.binaries[fn_name]
        specs = tuple(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), a)
            for a in self._specs[fn_name])
        return binary.compile(TargetKind.ACCEL, *specs)

    # -------------------------------------------------------------- call
    def _client(self, app: str):
        if app not in self._clients:
            if self._scheduler_address is not None:
                self._clients[app] = TcpSchedulerClient(
                    app, self._scheduler_address)
            else:
                self._clients[app] = SchedulerClient(app, self.server)
        return self._clients[app]

    def publish_signals(self, engine_id: str, signals: LoadSignals) -> None:
        """Feed one engine's serve telemetry to the scheduler (TCP when
        a scheduler_address was given, in-process otherwise); the policy
        sees it merged into the aggregate on the next decision."""
        if self._scheduler_address is not None:
            self._client("_signals").publish(engine_id, signals)
        else:
            self.server.publish(engine_id, signals)

    def call(self, fn_name: str, *args,
             state_shardings: Optional[dict] = None) -> Any:
        """The instrumented call site (steps B + §3.2).

        Args may differ in shape from the ``prepare`` examples (ragged
        continuous-batching prefills): the binary's shape-bucket cache
        then compiles/reuses a variant for the exact signature."""
        fn = self.registry.get(fn_name)
        binary = self.binaries[fn_name]
        client = self._client(fn.app)

        decision = client.before_call()
        kind = decision.target
        if kind == TargetKind.ACCEL and not binary.is_compiled(kind):
            kind = TargetKind.HOST           # bank raced; fall back
        if kind not in fn.variants:
            kind = TargetKind.HOST

        if state_shardings and kind in state_shardings:
            args = migrate(args, state_shardings[kind])

        # resolve (and possibly bucket-compile) BEFORE the timed region:
        # compile time must not reach Algorithm 1 as execution time or
        # hold the load monitor elevated
        variant = binary.variant_for(kind, args)

        self.monitor.job_started(kind)
        t0 = time.perf_counter()
        try:
            out = variant(*args)
            out = jax.block_until_ready(out)
        finally:
            self.monitor.job_finished(kind)
        dt = time.perf_counter() - t0
        binary.note_exec(kind, dt * 1e3)
        client.after_call(kind, dt * 1e3)
        self.call_log.append({"fn": fn_name, "target": kind.value,
                              "ms": dt * 1e3,
                              "reconfigure": decision.reconfigure})
        return out

    # ------------------------------------------------------------- stats
    def summary(self) -> dict:
        """Aggregate call/compile/migration accounting.

        ``per_function[fn]`` reports, per target, how many calls that
        variant actually served and how many compiles it cost (default
        + shape-bucket), plus how many times consecutive calls of ``fn``
        switched target (= run-time migrations) — so a benchmark artifact
        can prove which backend served tokens, not just which was
        registered.
        """
        per_target = {k.value: 0 for k in TargetKind}
        per_fn_calls: dict[str, dict[str, int]] = {}
        migrations: dict[str, int] = {}
        last: dict[str, str] = {}
        for rec in self.call_log:
            per_target[rec["target"]] += 1
            d = per_fn_calls.setdefault(rec["fn"], {})
            d[rec["target"]] = d.get(rec["target"], 0) + 1
            prev = last.get(rec["fn"])
            if prev is not None and prev != rec["target"]:
                migrations[rec["fn"]] = migrations.get(rec["fn"], 0) + 1
            last[rec["fn"]] = rec["target"]
        per_function = {}
        for name, binary in self.binaries.items():
            per_function[name] = {
                "calls": per_fn_calls.get(name, {}),
                "compiles": {k.value: dict(v)
                             for k, v in binary.compile_stats.items()},
                "migrations": migrations.get(name, 0),
            }
        return {"calls": len(self.call_log), "per_target": per_target,
                "per_function": per_function,
                "migrations": sum(migrations.values()),
                "bank": dict(self.bank.stats),
                "shape_buckets": {name: dict(b.shape_stats)
                                  for name, b in self.binaries.items()
                                  if sum(b.shape_stats.values())},
                "decisions": {k.value: v
                              for k, v in self.server.decisions.items()}}
