"""Step B — instrumentation: the MigratableFunction registry.

A MigratableFunction is one *selected function* from the profiling
manifest: a pure JAX callable with one implementation ("variant") per
execution target, all sharing the same input/output pytree ABI.  The
instrumentation the paper injects around call sites (scheduler client
query before the call, threshold update after the return, FPGA
pre-configuration at main()) lives in runtime.XarTrekRuntime.call.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.core.targets import TargetKind


@dataclasses.dataclass
class MigratableFunction:
    name: str
    app: str
    variants: dict[TargetKind, Callable]        # pure fns, identical ABI
    # optional per-target jit sharding hints (in_shardings/out_shardings)
    shardings: dict[TargetKind, tuple] = dataclasses.field(
        default_factory=dict)
    # abstract input signature for AOT compilation (filled by binary.py
    # from example args when not given)
    input_specs: Optional[tuple] = None

    def targets(self) -> tuple[TargetKind, ...]:
        return tuple(self.variants)

    def check_abi(self, example_args: tuple) -> None:
        """Symbol-alignment analogue: all variants must agree on the
        output pytree structure and leaf shapes/dtypes."""
        results = {}
        for kind, fn in self.variants.items():
            out = jax.eval_shape(fn, *example_args)
            results[kind] = jax.tree.structure(out), [
                (l.shape, str(l.dtype)) for l in jax.tree.leaves(out)]
        ref_kind = next(iter(results))
        for kind, (tree, leaves) in results.items():
            if (tree, leaves) != results[ref_kind]:
                raise ValueError(
                    f"{self.name}: ABI mismatch between {ref_kind} and "
                    f"{kind}: {results[ref_kind]} vs {(tree, leaves)}")


class FunctionRegistry:
    def __init__(self):
        self._fns: dict[str, MigratableFunction] = {}

    def register(self, fn: MigratableFunction) -> MigratableFunction:
        if fn.name in self._fns:
            raise ValueError(f"duplicate migratable function {fn.name!r}")
        self._fns[fn.name] = fn
        return fn

    def get(self, name: str) -> MigratableFunction:
        return self._fns[name]

    def __contains__(self, name: str) -> bool:
        return name in self._fns

    def names(self) -> list[str]:
        return sorted(self._fns)


GLOBAL_REGISTRY = FunctionRegistry()


def migratable(name: str, app: str, **variant_fns) -> MigratableFunction:
    """Convenience: migratable("knn", "digitrec", host=f, accel=g)."""
    variants = {TargetKind(k): v for k, v in variant_fns.items()}
    return GLOBAL_REGISTRY.register(
        MigratableFunction(name=name, app=app, variants=variants))
