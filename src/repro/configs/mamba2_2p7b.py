"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: decode state is O(1) in context length, so decode_32k and
long_500k lower with a constant-size (conv_state, ssd_state) cache.
"""
from repro.configs.model_config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
)
