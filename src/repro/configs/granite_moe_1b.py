"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

vocab 49155 is not TP-divisible; padded to 49168 (Megatron-style), padded
columns masked out of the loss.
"""
from repro.configs.model_config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64, mlp_type="swiglu",
    num_experts=32, top_k=8,
)
