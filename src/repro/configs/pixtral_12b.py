"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, num_patches, d_model) which overwrite
the first ``num_patches`` token embeddings; loss is masked to text
positions.
"""
from repro.configs.model_config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128, mlp_type="swiglu",
    num_patches=1024,
)
