"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

kv=40 == heads (MHA).  decode_32k KV cache at bf16 would be 5.5 TB
(21.5 GB/chip on 256 chips, over the v5e 16 GB budget) so this arch uses
an int8 KV cache; see EXPERIMENTS.md §Dry-run.
"""
from repro.configs.model_config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064, head_dim=128, qkv_bias=True,
    mlp_type="swiglu", kv_cache_dtype="int8",
)
