"""Architecture registry: the 10 assigned archs + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.configs.model_config import ModelConfig, ShapeConfig, SHAPES, TrainConfig

from repro.configs.smollm_135m import CONFIG as SMOLLM_135M
from repro.configs.smollm_360m import CONFIG as SMOLLM_360M
from repro.configs.qwen15_32b import CONFIG as QWEN15_32B
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.zamba2_1p2b import CONFIG as ZAMBA2_1P2B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.granite_moe_1b import CONFIG as GRANITE_MOE_1B
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.mamba2_2p7b import CONFIG as MAMBA2_2P7B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        SMOLLM_135M, QWEN15_32B, YI_6B, SMOLLM_360M, ZAMBA2_1P2B,
        OLMOE_1B_7B, GRANITE_MOE_1B, PIXTRAL_12B, MAMBA2_2P7B,
        MUSICGEN_MEDIUM,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: long_500k excluded per "
                       "assignment (sub-quadratic attention required); "
                       "see DESIGN.md §4")
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family reduced config for CPU smoke tests.

    Keeps the awkward properties (odd head counts that need TP padding,
    GQA ratios, codebooks, shared-block cadence) at toy sizes.
    """
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        vocab_size=257 if cfg.vocab_size % 16 else 256,
        remat="nothing",
        kv_cache_dtype=cfg.kv_cache_dtype,
    )
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        # preserve "heads don't divide TP" quirks where the full arch has them
        heads = 3 if cfg.num_heads % 2 else 4
        kv = max(1, heads // max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1))
        kw.update(num_heads=heads, num_kv_heads=kv, head_dim=32,
                  d_ff=256 if cfg.family != "moe" else 64)
    if cfg.family == "moe":
        kw.update(num_experts=8, top_k=2)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
        if cfg.family == "hybrid":
            kw.update(num_layers=4, attn_every=2, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256)
        else:
            kw.update(num_heads=0, num_kv_heads=0, d_ff=0)
    if cfg.family == "vlm":
        kw.update(num_patches=8)
    if cfg.family == "audio":
        kw.update(num_codebooks=cfg.num_codebooks, vocab_size=64)
    return dataclasses.replace(cfg, **kw)


SMOKE_SHAPES: dict[str, ShapeConfig] = {
    "smoke_train": ShapeConfig("smoke_train", 64, 2, "train"),
    "smoke_prefill": ShapeConfig("smoke_prefill", 64, 2, "prefill"),
    "smoke_decode": ShapeConfig("smoke_decode", 64, 2, "decode"),
}
