"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

The EnCodec/conditioning frontend is a STUB per the assignment: inputs
are the 4-codebook token grids; embeddings of the 4 codebooks are summed
and 4 output heads predict the next frame's codebooks.
"""
from repro.configs.model_config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64, mlp_type="gelu",
    num_codebooks=4,
)
