"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]."""
from repro.configs.model_config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64, mlp_type="swiglu",
    ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
)
