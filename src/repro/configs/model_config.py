"""Model/config dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (one instance per assigned arch)."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_type: str = "swiglu"        # swiglu | gelu
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (zamba2-style shared attention blocks) ---
    attn_every: int = 0             # apply shared attn block every k layers (0=off)
    # --- modality frontend stubs ---
    frontend: str = "none"          # none | patch (vlm) | frame (audio)
    num_patches: int = 1024         # vlm: precomputed patch embeddings per sample
    num_codebooks: int = 1          # audio: EnCodec codebooks
    # --- execution variant (Xar-Trek target implementations) ---
    attn_impl: str = "ref"          # ref (HOST path) | flash (ACCEL kernel)
    sharding_recipe: str = "tp"     # tp (weights over model axis) | dp
                                    # (pure data parallel: batch over ALL
                                    # axes, weights replicated — right for
                                    # small models; the AUX target recipe)
    # --- numerics / memory ---
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "dots"             # nothing | dots | full

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid archs)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (unpadded), for MODEL_FLOPS."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += d * V * max(self.num_codebooks, 1)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            per_layer += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            per_layer += self.num_heads * hd * d
            if self.mlp_type == "swiglu":
                ffn = 3 * d * self.d_ff
            else:
                ffn = 2 * d * self.d_ff
            if self.family == "moe":
                per_layer += d * self.num_experts + self.num_experts * ffn
            else:
                per_layer += ffn
            per_layer += 2 * d
            n += L * per_layer
        elif self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_nheads
            proj = 2 * di + 2 * ns + nh
            per_layer = d * proj + (di + 2 * ns) * self.conv_kernel
            per_layer += 3 * nh + di + di * d + 2 * d
            n += L * per_layer
            if self.family == "hybrid":
                # one shared attention+mlp block
                n += (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                      + self.num_heads * hd * d + 3 * d * self.d_ff + 2 * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        ffn = 3 * d * self.d_ff if self.mlp_type == "swiglu" else 2 * d * self.d_ff
        inactive = L * (self.num_experts - self.top_k) * ffn
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Step-level knobs (shape-dependent; perf loop rewrites these)."""

    microbatches: int = 1           # grad-accum splits of the global batch
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    zero1: bool = True              # shard optimizer moments over data axis
    grad_compression: str = "none"  # none | int8_ef
    seed: int = 0
