"""Fault-tolerant checkpointing with elastic resharding restore.

Layout (one directory per step, atomically renamed into place):

    ckpt_dir/
      step_000100/
        MANIFEST.json        # treedef paths, shapes, dtypes, step, meta
        <leaf-path>.npy      # one file per pytree leaf (process 0 here;
                             # multi-host would write per-process shards)
      step_000200/ ...
      LATEST                 # text file naming the newest valid step dir

Restore accepts *different* shardings than those saved with — the
elastic-restart path: after a node failure shrinks the mesh, leaves are
device_put onto the new mesh's shardings.  Atomicity: a step directory
is written under a tmp name and renamed only after MANIFEST.json is
fsync'd, so a crash mid-save never corrupts LATEST.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import numpy as np

import jax


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save_checkpoint(directory: str, step: int, state: Any,
                    meta: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=directory)
    try:
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        manifest = {"step": step, "meta": meta or {}, "leaves": []}
        for path, leaf in leaves:
            name = _leaf_name(path)
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        mpath = os.path.join(tmp, "MANIFEST.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    latest = os.path.join(directory, "LATEST")
    with open(latest + ".tmp", "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest + ".tmp", latest)
    return final


def latest_step_dir(directory: str) -> Optional[str]:
    latest = os.path.join(directory, "LATEST")
    if os.path.exists(latest):
        with open(latest) as f:
            cand = os.path.join(directory, f.read().strip())
        if os.path.exists(os.path.join(cand, "MANIFEST.json")):
            return cand
    # fall back to scanning (LATEST lost in a crash)
    steps = sorted(
        d for d in os.listdir(directory) if re.fullmatch(r"step_\d+", d)
    ) if os.path.isdir(directory) else []
    for d in reversed(steps):
        if os.path.exists(os.path.join(directory, d, "MANIFEST.json")):
            return os.path.join(directory, d)
    return None


def restore_checkpoint(directory: str, target: Any,
                       shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``target`` (pytree of anything with
    .shape/.dtype).  ``shardings`` (same structure) enables elastic
    resharding onto a new mesh.  Returns (state, step, meta)."""
    step_dir = latest_step_dir(directory)
    if step_dir is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    with open(os.path.join(step_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)
    dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    paths = jax.tree_util.tree_flatten_with_path(target)[0]
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out_leaves = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        name = _leaf_name(path)
        arr = np.load(os.path.join(step_dir, name + ".npy"))
        if arr.dtype.kind == "V":  # ml_dtypes (bf16/...) round-trip as void
            arr = arr.view(jax.numpy.dtype(dtypes[name]))
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != target {expect}")
        if sh is not None:
            out_leaves.append(jax.device_put(arr, sh))
        else:
            out_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree.structure(target)
    return (jax.tree.unflatten(tree, out_leaves), manifest["step"],
            manifest.get("meta", {}))


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    save_async: bool = False

    def __post_init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: Any, meta: Optional[dict] = None) -> None:
        if self.save_async:
            self.wait()
            # snapshot to host before handing to the thread
            host_state = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), state)
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_state, meta),
                daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, state, meta)

    def _save_and_gc(self, step, state, meta):
        try:
            save_checkpoint(self.directory, step, state, meta)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.directory)
            if re.fullmatch(r"step_\d+", d))
        for d in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    def restore(self, target: Any, shardings: Any = None):
        return restore_checkpoint(self.directory, target, shardings)

    def has_checkpoint(self) -> bool:
        return (os.path.isdir(self.directory)
                and latest_step_dir(self.directory) is not None)
