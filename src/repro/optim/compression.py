"""Gradient compression with error feedback for cross-pod DP reduce.

int8 symmetric quantisation per-tensor with an error-feedback buffer
(1-bit-Adam-family trick): e' = g + e - deQ(Q(g + e)); the quantised
values are what crosses the slow inter-pod links.  Used by the train
step when ``TrainConfig.grad_compression == "int8_ef"``: intra-pod
reduction stays fp32 (fast ICI), the pod-axis reduction runs on the
compressed representation inside a shard_map.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.mesh import POD_AXIS


def compress_int8_ef(grads: Any, err: Any) -> tuple[Any, Any, Any]:
    """Returns (q_grads int8, scales, new_err)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, x - deq

    flat, tree = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]),
            jax.tree.unflatten(tree, [o[2] for o in out]))


def decompress_int8(q: Any, scales: Any) -> Any:
    return jax.tree.map(
        lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def pod_allreduce_compressed(grads: Any, err: Any) -> tuple[Any, Any]:
    """Inside a shard_map block that is manual over POD_AXIS: mean-reduce
    grads across pods in int8 (int32 accumulation), with error feedback.

    Bandwidth on the pod links: 1 byte/element (+1 scalar) vs 4.
    """
    q, scales, new_err = compress_int8_ef(grads, err)
    npods = (jax.lax.axis_size(POD_AXIS) if hasattr(jax.lax, "axis_size")
             else jax.lax.psum(1, POD_AXIS))  # jax<0.6 lacks lax.axis_size

    def reduce_one(qq, s):
        tot = jax.lax.psum(qq.astype(jnp.int32), POD_AXIS)
        s_max = jax.lax.pmax(s, POD_AXIS)   # conservative shared scale
        return tot.astype(jnp.float32) * s_max / npods

    mean = jax.tree.map(reduce_one, q, scales)
    return mean, new_err
