from repro.optim.adamw import AdamW, OptState, cosine_schedule
from repro.optim.compression import compress_int8_ef, decompress_int8
