"""AdamW from scratch (no optax) with fp32 moments and ZeRO-1 sharding.

Moments live in fp32 regardless of param dtype.  With ``zero1`` the
moment PartitionSpecs additionally shard the largest divisible dim over
the ``data`` axis — XLA then turns the DP grad all-reduce into
reduce-scatter + (param) all-gather, the ZeRO-1 communication pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.model_config import TrainConfig
from repro.parallel.mesh import DATA_AXIS


@dataclasses.dataclass
class AdamW:
    cfg: TrainConfig

    def init(self, params: Any) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: Any, state: dict, params: Any,
               lr: jax.Array) -> tuple[Any, dict]:
        c = self.cfg
        step = state["step"] + 1
        b1, b2 = c.beta1, c.beta2

        # global-norm clip in fp32
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))

        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + 1e-8)
            if c.weight_decay and p.ndim >= 2:   # no decay on norms/scalars
                delta = delta + c.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        flat_g, tree = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(tree, [o[0] for o in out])
        new_m = jax.tree.unflatten(tree, [o[1] for o in out])
        new_v = jax.tree.unflatten(tree, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step,
                       "gnorm": gnorm}

    # --------------------------------------------------------- shardings
    def state_specs(self, param_specs: Any, param_shapes: Any,
                    dp_size: int) -> dict:
        """Moment specs: param spec (+ ZeRO-1 data-axis sharding)."""
        def zspec(spec: P, shape) -> P:
            if not self.cfg.zero1 or dp_size <= 1:
                return spec
            parts = list(spec) + [None] * (len(shape.shape) - len(spec))
            for i, (dim, cur) in enumerate(zip(shape.shape, parts)):
                if cur is None and dim % dp_size == 0 and dim >= dp_size:
                    parts[i] = DATA_AXIS
                    return P(*parts)
            return spec

        return {
            "m": jax.tree.map(zspec, param_specs, param_shapes),
            "v": jax.tree.map(zspec, param_specs, param_shapes),
            "step": P(),
        }


OptState = dict


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
