"""Distribution layer: mesh construction, logical-axis sharding rules."""
from repro.parallel.mesh import MeshSpec, make_mesh, batch_axes, model_axis
from repro.parallel.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    spec_for,
    pad_to_multiple,
    padded_size,
)
