"""Small JAX-version compatibility shims."""
from __future__ import annotations

import contextlib

import jax


def use_mesh(mesh):
    """Context manager putting ``mesh`` in scope (None -> no-op)."""
    if mesh is None:
        return contextlib.nullcontext()
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return jax.sharding.set_mesh(mesh)     # jax>=0.8: dual global/ctx-manager


def make_mesh(shape, axes):
    """jax.make_mesh with GSPMD-auto axis types (silences the 0.9 change)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except TypeError:  # older jax without axis_types
        return jax.make_mesh(shape, axes)
