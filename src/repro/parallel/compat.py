"""Small JAX-version compatibility shims.

The repo supports jax 0.4.x (no ``AxisType``, no top-level ``shard_map``,
``Mesh`` is its own context manager) through jax 0.9 (GSPMD-auto axis
types, ``jax.sharding.use_mesh`` / ``set_mesh``, ``check_vma``).
"""
from __future__ import annotations

import contextlib
import inspect

import jax

try:  # JAX >= 0.6 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover — version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

# jax >= 0.7 renamed check_rep -> check_vma
_SHARD_MAP_PARAMS = set(inspect.signature(_shard_map).parameters)
_REP_KW = "check_vma" if "check_vma" in _SHARD_MAP_PARAMS else "check_rep"


def shard_map(fn, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map (maps ``check_vma`` to old ``check_rep``)."""
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_REP_KW: check_vma})


def use_mesh(mesh):
    """Context manager putting ``mesh`` in scope (None -> no-op)."""
    if mesh is None:
        return contextlib.nullcontext()
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)  # jax>=0.8: dual global/ctx-manager
    return mesh                     # jax<=0.5: Mesh is a context manager


def peak_memory_bytes(mem) -> int:
    """Peak bytes from a CompiledMemoryStats; jax<0.5 has no
    ``peak_memory_in_bytes`` field, so approximate it from the parts."""
    peak = getattr(mem, "peak_memory_in_bytes", 0)
    if peak:
        return peak
    return (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)


def make_mesh(shape, axes):
    """jax.make_mesh with GSPMD-auto axis types (silences the 0.9 change)."""
    if hasattr(jax.sharding, "AxisType"):
        try:
            return jax.make_mesh(
                shape, axes,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
        except TypeError:  # jax with AxisType but no axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)
