"""Mesh construction for single-pod and multi-pod topologies.

The production meshes (assignment):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Functions only — importing this module never touches jax device state.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.parallel.compat import make_mesh as _compat_make_mesh, use_mesh  # noqa: F401

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Logical description of a mesh, independent of physical devices."""

    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]

    @property
    def dp_size(self) -> int:
        """Total data-parallel degree (pod x data)."""
        return self.axis_size(POD_AXIS) * self.axis_size(DATA_AXIS)

    @property
    def tp_size(self) -> int:
        return self.axis_size(MODEL_AXIS)


SINGLE_POD = MeshSpec(shape=(16, 16), axes=(DATA_AXIS, MODEL_AXIS))
MULTI_POD = MeshSpec(shape=(2, 16, 16), axes=(POD_AXIS, DATA_AXIS, MODEL_AXIS))


def make_mesh(spec: MeshSpec) -> jax.sharding.Mesh:
    """Build a jax Mesh for ``spec`` from the currently visible devices."""
    return _compat_make_mesh(spec.shape, spec.axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (uses however many devices are visible)."""
    if pod:
        return _compat_make_mesh((pod, data, model),
                                 (POD_AXIS, DATA_AXIS, MODEL_AXIS))
    return _compat_make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))


def batch_axes(mesh_or_spec) -> tuple[str, ...]:
    """Mesh axes over which the global batch is sharded."""
    axes = mesh_or_spec.axes if hasattr(mesh_or_spec, "axes") else mesh_or_spec.axis_names
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in axes)


def model_axis(mesh_or_spec) -> str:
    return MODEL_AXIS
