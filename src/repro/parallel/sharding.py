"""Logical-axis sharding rules.

Every model parameter / activation dimension carries a *logical* name
("batch", "vocab", "heads", ...).  ``spec_for`` maps a tuple of logical
names to a ``PartitionSpec`` through a rules table, so the whole sharding
layout of the framework is one dictionary that the perf loop can rewrite.

Divisibility helpers implement the Megatron-style padding used for
awkward head/vocab counts (qwen 40 heads, smollm 9/15 heads, granite
vocab 49155): dimensions are padded up to a multiple of the shard count,
padded slices are zero-initialised and contribute exactly zero to the
forward/backward (masked at init; see models/common.py).
"""
from __future__ import annotations

import dataclasses

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS

# Logical dimension names -> mesh axes (None = replicated).
# "batch" shards over both the pod and data axes (pure DP across pods).
DEFAULT_RULES: dict[str, object] = {
    "batch": (POD_AXIS, DATA_AXIS),
    "seq": None,                  # training activations: seq replicated
    "cache_seq": MODEL_AXIS,      # KV cache sequence dim: sharded over TP
    "embed": None,                # residual stream replicated across TP
    "vocab": MODEL_AXIS,
    "heads": MODEL_AXIS,          # query heads (padded to a multiple of TP)
    "kv_heads": None,             # default replicate; set per-arch if divisible
    "head_dim": None,
    "mlp": MODEL_AXIS,            # d_ff
    "experts": MODEL_AXIS,        # expert parallelism
    "expert_mlp": None,           # per-expert d_ff (already split by EP)
    "layers": None,               # scan-stacked layer dim
    "ssm_inner": MODEL_AXIS,      # mamba d_inner
    "ssm_heads": MODEL_AXIS,
    "ssm_state": None,
    "conv_kernel": None,
    "codebooks": None,
    "zero1": DATA_AXIS,           # optimizer-state extra sharding (ZeRO-1)
}


@dataclasses.dataclass
class ShardingRules:
    rules: dict[str, object]

    def spec(self, *logical: str | None) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
            else:
                parts.append(self.rules.get(name))
        return P(*parts)


def spec_for(*logical: str | None, rules: dict | None = None) -> P:
    return ShardingRules(rules or DEFAULT_RULES).spec(*logical)


def prune_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't exist in ``mesh`` (e.g. 'pod' on a
    single-pod mesh) so one rules table serves every topology."""
    names = set(mesh.axis_names)
    parts = []
    for p in spec:
        if p is None:
            parts.append(None)
        elif isinstance(p, tuple):
            kept = tuple(a for a in p if a in names)
            parts.append(kept if kept else None)
        else:
            parts.append(p if p in names else None)
    return P(*parts)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, prune_spec(spec, mesh))


def named_tree(mesh: Mesh, tree):
    """Pytree of PartitionSpecs -> pytree of (pruned) NamedShardings."""
    import jax

    return jax.tree.map(lambda s: named(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def pad_to_multiple(value: int, multiple: int) -> int:
    """Round ``value`` up to a multiple of ``multiple``."""
    if multiple <= 1:
        return value
    return ((value + multiple - 1) // multiple) * multiple


def padded_size(value: int, shards: int) -> int:
    """Shard-divisible size for ``value`` over ``shards`` shards."""
    return pad_to_multiple(value, shards)


def divisible(value: int, shards: int) -> bool:
    return shards >= 1 and value % shards == 0
