"""Grouped (per-expert) matmul — Pallas TPU kernel for the MoE ACCEL path.

Computes ``out[e] = x[e] @ w[e]`` over capacity-padded expert buffers
with a per-expert valid-row count (``group_sizes``): rows past the group
size are masked to zero so dropped-token slots cost no accuracy (they
still cost flops — the buffers are rectangular, which is what the MXU
wants; MegaBlocks-style block-sparsity is a further step recorded in
EXPERIMENTS.md §Perf).

Grid ``(E, C/bc, F/bf, D/bd)`` with a VMEM fp32 accumulator carried over
the innermost (contraction) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, gs_ref, o_ref, acc_scr, *, block_c: int,
                nd: int):
    di = pl.program_id(3)
    ci = pl.program_id(1)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)          # (bc, bd)
    w = w_ref[0].astype(jnp.float32)          # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    rows = ci * block_c + jax.lax.broadcasted_iota(jnp.int32, acc_scr.shape, 0)

    @pl.when(di == nd - 1)
    def _finish():
        valid = rows < gs_ref[0]
        o_ref[0] = jnp.where(valid, acc_scr[...], 0.0).astype(o_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   block_c: int = 128, block_f: int = 128, block_d: int = 256,
                   interpret: bool = False) -> jax.Array:
    """x: (E, C, D); w: (E, D, F); group_sizes: (E,) int32 -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    nc, nf, nd = C // block_c, F // block_f, D // block_d

    kernel = functools.partial(_gmm_kernel, block_c=block_c, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
            pl.BlockSpec((1,), lambda e, ci, fi, di: (e,)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w, group_sizes.astype(jnp.int32))
