"""Chunk-prefill attention at an offset into a paged KV pool — Pallas TPU.

``paged_gqa_prefill`` is the multi-token sibling of
``gqa_decode.paged_gqa_decode``: one grid step per (row, kv-head,
logical-block) streams a PHYSICAL pool block through VMEM via the
scalar-prefetched block table, masked to the resident prefix
``[0, offset)``, with online-softmax accumulators in scratch for the
whole chunk's query rows at once.  The chunk's own K/V (positions
``[offset, length)``) is passed explicitly and folded on the final
block step with the causal intra-chunk mask — flash-style chunk
self-attention fused with the masked pool read, so chunked prefill is
a genuinely different ACCEL build instead of the XLA gather fallback.

Query rows arrive flattened (chunk token major, GQA group rank minor):
row ``r`` is chunk token ``r // group`` at group rank ``r % group``.
Bucket-padding chunk columns (``>= length - offset``) and pool
positions ``>= offset`` are masked to NEG_INF; their contribution
washes out exactly in the final correction (``exp(NEG_INF - m)``
underflows to 0.0), the same argument the decode kernel and the
bucketed dense prefill rely on.

``paged_gqa_prefill_int8`` streams an int8 pool plus its parallel
per-token f32 scale planes through the same block table and
dequantises in VMEM.  Oracles: ``ref.paged_prefill_attention_ref`` /
``ref.paged_prefill_attention_int8_ref``.

This kernel IS the speculative-decode verify kernel: verifying k
drafted tokens against the target model is chunk prefill at offset
with W = k (the "chunk" is the drafted span, the pool holds the
committed prefix).  ``ops.paged_gqa_verify`` / ``paged_gqa_verify_int8``
re-export the same body under a distinct name so the runtime registers
verify as its own HOST/ACCEL binary; the oracles above cover both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_accumulate(q_ref, off_ref, len_ref, kn_ref, vn_ref, o_ref,
                        m_scr, l_scr, acc_scr, k, v, *, block_size: int,
                        nbt: int, scale: float, group: int):
    """Shared online-softmax body of the paged prefill kernels.

    ``k``/``v`` are this grid step's already-dequantised (block_size, hd)
    f32 planes, exactly as in ``gqa_decode._paged_accumulate`` — the f32
    and int8 variants differ ONLY in the dequantise step.  The scratch
    accumulators carry one (W*group, …) online softmax for the whole
    chunk; the final block step folds the chunk's causal self-attention.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (WG, hd)
    off = off_ref[b]                                  # pool valid on [0, off)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    s = jnp.where(kpos < off, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nbt - 1)
    def _finish():
        # fold the chunk itself: positions [offset, length), causal
        kn = kn_ref[0, :, 0].astype(jnp.float32)      # (W, hd)
        vn = vn_ref[0, :, 0].astype(jnp.float32)
        W = kn.shape[0]
        WG = q.shape[0]
        s_cur = jax.lax.dot_general(q, kn, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
        qi = jax.lax.broadcasted_iota(jnp.int32, (WG, W), 0) // group
        kj = jax.lax.broadcasted_iota(jnp.int32, (WG, W), 1)
        n_real = len_ref[b] - off                     # real chunk width
        s_cur = jnp.where((kj <= qi) & (kj < n_real), s_cur, NEG_INF)
        m_prev = m_scr[...]
        m_fin = jnp.maximum(m_prev, jnp.max(s_cur, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_fin)
        p_cur = jnp.exp(s_cur - m_fin)
        l_fin = l_scr[...] * corr + jnp.sum(p_cur, axis=-1, keepdims=True)
        acc = acc_scr[...] * corr + jax.lax.dot_general(
            p_cur, vn, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0, 0] = (acc / jnp.maximum(l_fin, 1e-20)).astype(o_ref.dtype)


def _prefill_kernel(tbl_ref, off_ref, len_ref, q_ref, k_ref, v_ref, kn_ref,
                    vn_ref, o_ref, m_scr, l_scr, acc_scr, *, block_size: int,
                    nbt: int, scale: float, group: int):
    del tbl_ref
    _prefill_accumulate(q_ref, off_ref, len_ref, kn_ref, vn_ref, o_ref,
                        m_scr, l_scr, acc_scr,
                        k_ref[0, :, 0].astype(jnp.float32),
                        v_ref[0, :, 0].astype(jnp.float32),
                        block_size=block_size, nbt=nbt, scale=scale,
                        group=group)


def _prefill_int8_kernel(tbl_ref, off_ref, len_ref, q_ref, k_ref, ks_ref,
                         v_ref, vs_ref, kn_ref, vn_ref, o_ref, m_scr, l_scr,
                         acc_scr, *, block_size: int, nbt: int, scale: float,
                         group: int):
    """Int8-dequantising variant: block + (block_size, 1) f32 scale plane
    stream through the SAME block-table index map; dequantisation is one
    broadcast multiply in VMEM.  The chunk's ``kn``/``vn`` stay full
    precision (they are quantised only when scattered into the pool)."""
    del tbl_ref
    _prefill_accumulate(q_ref, off_ref, len_ref, kn_ref, vn_ref, o_ref,
                        m_scr, l_scr, acc_scr,
                        k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0],
                        v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0],
                        block_size=block_size, nbt=nbt, scale=scale,
                        group=group)


def paged_gqa_prefill(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      k_new: jax.Array, v_new: jax.Array, tables: jax.Array,
                      offsets: jax.Array, lengths: jax.Array, *, group: int,
                      interpret: bool = False) -> jax.Array:
    """q: (B, W*G, …) chunk queries flattened (token major, group-rank
    minor) and grouped per kv head as (B, KV, W*G, hd);
    k_pages/v_pages: (NP, BS, KV, hd) physical block pool; k_new/v_new:
    (B, W, KV, hd) the chunk's own K/V; tables: (B, NBT) int32 physical
    block ids; offsets/lengths: (B,) int32.

    Attends each chunk query over pool positions [0, offsets[b]) plus
    the chunk's causally-preceding real columns ([offset, length) in
    absolute positions).  offsets == 0 reduces to plain causal chunk
    self-attention (no pool read survives the final correction), so the
    first chunk of an uncached prompt is well-defined.
    """
    B, KV, WG, hd = q.shape
    W = k_new.shape[1]
    block_size = k_pages.shape[1]
    nbt = tables.shape[1]
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_prefill_kernel, block_size=block_size,
                               nbt=nbt, scale=scale, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                # tables, offsets, lengths
        grid=(B, KV, nbt),
        in_specs=[
            pl.BlockSpec((1, 1, WG, hd),
                         lambda b, h, j, t, o, n: (b, h, 0, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda b, h, j, t, o, n: (t[b, j], 0, h, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda b, h, j, t, o, n: (t[b, j], 0, h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h, j, t, o, n: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h, j, t, o, n: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, WG, hd),
                               lambda b, h, j, t, o, n: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((WG, 1), jnp.float32),
            pltpu.VMEM((WG, 1), jnp.float32),
            pltpu.VMEM((WG, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, WG, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), offsets.astype(jnp.int32),
      lengths.astype(jnp.int32), q, k_pages, v_pages, k_new, v_new)


def paged_gqa_prefill_int8(q: jax.Array, k_pages: jax.Array,
                           k_scale: jax.Array, v_pages: jax.Array,
                           v_scale: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, tables: jax.Array,
                           offsets: jax.Array, lengths: jax.Array, *,
                           group: int, interpret: bool = False) -> jax.Array:
    """``paged_gqa_prefill`` over an int8 pool with per-token scales.

    k_pages/v_pages: (NP, BS, KV, hd) int8; k_scale/v_scale:
    (NP, BS, KV, 1) f32 symmetric per-(token, kv-head) scales.  Scale
    planes ride the SAME scalar-prefetched block table as the int8
    blocks; q and the chunk's k_new/v_new stay full precision.
    """
    B, KV, WG, hd = q.shape
    W = k_new.shape[1]
    block_size = k_pages.shape[1]
    nbt = tables.shape[1]
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_prefill_int8_kernel, block_size=block_size,
                               nbt=nbt, scale=scale, group=group)
    page_spec = pl.BlockSpec((1, block_size, 1, hd),
                             lambda b, h, j, t, o, n: (t[b, j], 0, h, 0))
    scale_spec = pl.BlockSpec((1, block_size, 1, 1),
                              lambda b, h, j, t, o, n: (t[b, j], 0, h, 0))
    tok_spec = pl.BlockSpec((1, W, 1, hd),
                            lambda b, h, j, t, o, n: (b, 0, h, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                # tables, offsets, lengths
        grid=(B, KV, nbt),
        in_specs=[
            pl.BlockSpec((1, 1, WG, hd),
                         lambda b, h, j, t, o, n: (b, h, 0, 0)),
            page_spec, scale_spec, page_spec, scale_spec,
            tok_spec, tok_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, WG, hd),
                               lambda b, h, j, t, o, n: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((WG, 1), jnp.float32),
            pltpu.VMEM((WG, 1), jnp.float32),
            pltpu.VMEM((WG, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, WG, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), offsets.astype(jnp.int32),
      lengths.astype(jnp.int32), q, k_pages, k_scale.astype(jnp.float32),
      v_pages, v_scale.astype(jnp.float32), k_new, v_new)
