"""Hamming-distance matrix for KNN digit recognition — Pallas TPU kernel.

The paper's DigitRec benchmark (Rosetta [FPGA'18]) is K-nearest-
neighbours over 196-bit digit bitvectors with Hamming distance — the
function Xar-Trek offloads to the FPGA.  The TPU adaptation keeps the
bit-packed layout (uint32 words) and computes the full test x train
distance matrix with XOR + popcount in VMEM tiles; the cheap top-k over
train items stays on the host side of the function boundary (ops.py),
matching the paper's self-contained-function migration model.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(t_ref, r_ref, o_ref):
    t = t_ref[...]                            # (bt, W) uint32
    r = r_ref[...]                            # (bn, W) uint32
    x = jax.lax.population_count(t[:, None, :] ^ r[None, :, :])
    o_ref[...] = jnp.sum(x.astype(jnp.int32), axis=-1)


def hamming_distances(test: jax.Array, train: jax.Array, *,
                      block_t: int = 128, block_n: int = 512,
                      interpret: bool = False) -> jax.Array:
    """test: (Nt, W) uint32; train: (Nn, W) uint32 -> (Nt, Nn) int32."""
    Nt, W = test.shape
    Nn = train.shape[0]
    block_t = min(block_t, Nt)
    block_n = min(block_n, Nn)
    assert Nt % block_t == 0 and Nn % block_n == 0
    return pl.pallas_call(
        _hamming_kernel,
        grid=(Nt // block_t, Nn // block_n),
        in_specs=[
            pl.BlockSpec((block_t, W), lambda ti, ni: (ti, 0)),
            pl.BlockSpec((block_n, W), lambda ti, ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, block_n), lambda ti, ni: (ti, ni)),
        out_shape=jax.ShapeDtypeStruct((Nt, Nn), jnp.int32),
        interpret=interpret,
    )(test, train)
