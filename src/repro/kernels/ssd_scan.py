"""Chunked SSD (Mamba2) scan — Pallas TPU kernel.

Grid ``(batch*heads, chunks)`` with the chunk axis innermost/sequential;
the running inter-chunk state (headdim x dstate) lives in VMEM scratch
and is carried across chunk steps — the TPU analogue of the Mamba2
"state passing" CUDA kernel.  Per chunk we compute the intra-chunk
semiseparable (quadratic) term on the MXU and the state contribution,
then update the carried state.

Inputs are pre-projected/pre-conv'd (x, dt, B, C) per head; the oracle
is ``ref.ssd_ref`` (== models.ssm.ssd_chunked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, sfin_ref,
                state_scr, *, chunk: int, nchunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (l, p)
    dt = dt_ref[0].astype(jnp.float32)        # (l, 1)
    A = a_ref[0, 0]                           # scalar decay rate (negative)
    Bm = b_ref[0].astype(jnp.float32)         # (l, n)
    Cm = c_ref[0].astype(jnp.float32)         # (l, n)

    xd = x * dt                               # dt-discretised input
    a = A * dt[:, 0]                          # (l,) log-decay per step
    cs = jnp.cumsum(a)                        # inclusive

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(li >= lj, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(Lmat * scores, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # previous-state contribution: C_i . S_prev * exp(cs_i)
    s_prev = state_scr[...]                   # (p, n)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cm, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S = S * exp(cs_last) + sum_i exp(cs_last - cs_i) x_i B_i^T
    decay_out = jnp.exp(cs[-1] - cs)          # (l,)
    new_state = s_prev * jnp.exp(cs[-1]) + jax.lax.dot_general(
        xd * decay_out[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = new_state

    o_ref[0] = y.astype(o_ref.dtype)

    @pl.when(ci == nchunks - 1)
    def _finish():
        sfin_ref[0] = new_state.astype(sfin_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (BH, S, P); dt: (BH, S); A: (BH,); Bm/Cm: (BH, S, N).

    Returns (y: (BH, S, P), final_state: (BH, P, N)).
    BH = batch * heads (B/C broadcast over heads is done by the wrapper).
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nchunks=nc)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], A[:, None], Bm, Cm)
    return y, sfin
