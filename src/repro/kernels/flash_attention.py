"""Fused causal flash attention — Pallas TPU kernel.

Canonical TPU pattern: 3-D grid ``(batch*heads, q_blocks, kv_blocks)``
with the kv dimension innermost (TPU grids iterate the last axis
sequentially), online-softmax accumulators in VMEM scratch, output
written on the final kv step.  BlockSpecs tile Q/K/V into
``(1, block, head_dim)`` VMEM windows; ``head_dim`` is MXU-lane aligned
by the ops wrapper (pads to 128 when needed).

This is the ACCEL ("hardware kernel") implementation of the attention
function in Xar-Trek terms; the oracle is ``ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, kv_blocks: int,
                  causal: bool, kv_len: int, q_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos + (kv_len - q_len), s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False) -> jax.Array:
    """q: (BH, S, hd); k, v: (BH, T, hd) -> (BH, S, hd)."""
    BH, S, hd = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    nq, nk = S // block_q, T // block_k
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        kv_blocks=nk, causal=causal, kv_len=T, q_len=S)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
