"""Fused RMSNorm — Pallas TPU kernel.

Row-tiled: grid over row blocks, each block normalises (block_rows, d)
in VMEM with an fp32 reduction.  Simple but real: this is the smallest
"hardware kernel" in the bank and doubles as the KernelBank smoke
workload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 128, interpret: bool = False) -> jax.Array:
    """x: (R, d); w: (d,) -> (R, d)."""
    R, d = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0, (R, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, w)
