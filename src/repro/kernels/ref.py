"""Pure-jnp oracles for every Pallas kernel (the HOST implementations).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; these
are also the "software function" targets the Xar-Trek scheduler falls
back to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (BH, S, hd); k, v: (BH, T, hd)."""
    BH, S, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        s = jnp.where(kpos <= qpos + (T - S), s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)


def ssd_ref(x, dt, A, Bm, Cm, *, chunk: int = 64):
    """Straightforward per-(batch*head)-row scan oracle (no chunking).

    x: (BH,S,P); dt: (BH,S); A: (BH,); Bm/Cm: (BH,S,N).
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def row(xr, dtr, Ar, Br, Cr):
        def step(state, inp):
            x_t, dt_t, B_t, C_t = inp
            a = jnp.exp(Ar * dt_t)
            state = state * a + jnp.outer(x_t, B_t) * dt_t
            y = state @ C_t
            return state, y

        s0 = jnp.zeros((P, N), jnp.float32)
        state, ys = jax.lax.scan(
            step, s0, (xr.astype(jnp.float32), dtr.astype(jnp.float32),
                       Br.astype(jnp.float32), Cr.astype(jnp.float32)))
        return ys, state

    y, state = jax.vmap(row)(x, dt, A, Bm, Cm)
    return y.astype(x.dtype), state


def grouped_matmul_ref(x, w, group_sizes):
    """x: (E,C,D); w: (E,D,F); rows >= group_sizes[e] are zeroed."""
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    C = x.shape[1]
    valid = jnp.arange(C)[None, :, None] < group_sizes[:, None, None]
    return jnp.where(valid, out, 0.0).astype(x.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def hamming_ref(test, train):
    """test: (Nt,W) uint32; train: (Nn,W) uint32 -> (Nt,Nn) int32."""
    x = jax.lax.population_count(test[:, None, :] ^ train[None, :, :])
    return jnp.sum(x.astype(jnp.int32), axis=-1)


def window_scores_ref(img, feats, *, win: int = 24, stride: int = 4):
    """img: (H,W); feats: (F, win*win) -> (ny, nx, F)."""
    H, W = img.shape
    ny = (H - win) // stride + 1
    nx = (W - win) // stride + 1
    idx_y = jnp.arange(ny) * stride
    idx_x = jnp.arange(nx) * stride
    patches = jax.vmap(lambda y: jax.vmap(lambda x: jax.lax.dynamic_slice(
        img, (y, x), (win, win)))(idx_x))(idx_y)       # (ny,nx,win,win)
    flat = patches.reshape(ny, nx, win * win).astype(jnp.float32)
    return jnp.einsum("yxp,fp->yxf", flat, feats.astype(jnp.float32))


def decode_attention_ref(q, k_cache, v_cache, index):
    """q: (BH,1,hd); caches: (BH,Smax,hd); attends over [0, index]."""
    import numpy as np
    BH, _, hd = q.shape
    Smax = k_cache.shape[1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q, k_cache).astype(jnp.float32) * scale
    mask = jnp.arange(Smax)[None, None, :] <= index
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v_cache)


def paged_decode_attention_ref(q, k_pages, v_pages, k_new, v_new, tables,
                               lengths):
    """Gather-then-attend oracle for the paged decode kernel.

    q: (B,KV,G,hd); k_pages/v_pages: (NP,BS,KV,hd); k_new/v_new:
    (B,KV,1,hd); tables: (B,NBT) int32; lengths: (B,) int32.  Attends
    over pool positions [0, lengths[b]) plus the explicit new token —
    the materialised-gather computation the kernel replaces.
    """
    B, KV, G, hd = q.shape
    BS = k_pages.shape[1]
    NBT = tables.shape[1]
    scale = 1.0 / np.sqrt(hd)
    kc = jnp.take(k_pages, tables, axis=0).reshape(B, NBT * BS, KV, hd)
    vc = jnp.take(v_pages, tables, axis=0).reshape(B, NBT * BS, KV, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", q, kc).astype(jnp.float32) * scale
    mask = jnp.arange(NBT * BS)[None, None, None, :] < \
        lengths[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    s_cur = jnp.einsum("bhgd,bhqd->bhgq", q, k_new).astype(jnp.float32) * scale
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), s_cur)
    p = jnp.exp(s - m)
    p_cur = jnp.exp(s_cur - m)
    denom = jnp.sum(p, axis=-1, keepdims=True) + p_cur
    out = jnp.einsum("bhgk,bkhd->bhgd", (p / denom).astype(q.dtype), vc)
    return out + (p_cur / denom).astype(q.dtype) * v_new


def paged_prefill_attention_ref(q, k_pages, v_pages, k_new, v_new, tables,
                                offsets, lengths, *, group=1):
    """Gather-then-attend oracle for the paged chunk-prefill kernel.

    q: (B, KV, W*G, hd) chunk queries flattened token-major /
    group-rank-minor (row r = chunk token ``r // group``);
    k_pages/v_pages: (NP,BS,KV,hd); k_new/v_new: (B,W,KV,hd) the chunk's
    own K/V; tables: (B,NBT) int32; offsets/lengths: (B,) int32.  Each
    query attends pool positions [0, offsets[b]) plus the chunk's
    causally-preceding real columns (``< lengths[b] - offsets[b]``) —
    the materialised-gather computation the kernel replaces.
    """
    B, KV, WG, hd = q.shape
    W = k_new.shape[1]
    BS = k_pages.shape[1]
    NBT = tables.shape[1]
    T = NBT * BS
    scale = 1.0 / np.sqrt(hd)
    kc = jnp.take(k_pages, tables, axis=0).reshape(B, T, KV, hd)
    vc = jnp.take(v_pages, tables, axis=0).reshape(B, T, KV, hd)
    s_ctx = jnp.einsum("bhrd,bthd->bhrt", q, kc).astype(jnp.float32) * scale
    ctx_valid = jnp.arange(T)[None, :] < offsets[:, None]       # (B, T)
    s_ctx = jnp.where(ctx_valid[:, None, None, :], s_ctx, -1e30)
    kn = k_new.transpose(0, 2, 1, 3)                            # (B,KV,W,hd)
    vn = v_new.transpose(0, 2, 1, 3)
    s_self = jnp.einsum("bhrd,bhjd->bhrj", q, kn).astype(jnp.float32) * scale
    qi = jnp.arange(WG)[:, None] // group
    kj = jnp.arange(W)[None, :]
    n_real = (lengths - offsets)[:, None, None, None]           # (B,1,1,1)
    self_valid = (kj <= qi) & (kj < n_real)                     # (B,1,WG,W)
    s_self = jnp.where(self_valid, s_self, -1e30)
    p = jax.nn.softmax(jnp.concatenate([s_ctx, s_self], axis=-1), axis=-1)
    out = jnp.einsum("bhrt,bthd->bhrd", p[..., :T].astype(q.dtype), vc)
    return out + jnp.einsum("bhrj,bhjd->bhrd",
                            p[..., T:].astype(q.dtype), vn)


def paged_prefill_attention_int8_ref(q, k_pages, k_scale, v_pages, v_scale,
                                     k_new, v_new, tables, offsets, lengths,
                                     *, group=1):
    """Oracle for the int8-dequantising paged chunk-prefill kernel:
    dequantises the WHOLE pool to f32 up front, then runs the shared
    gather-then-attend reference.  k_new/v_new stay full precision."""
    kp = k_pages.astype(jnp.float32) * k_scale.astype(jnp.float32)
    vp = v_pages.astype(jnp.float32) * v_scale.astype(jnp.float32)
    return paged_prefill_attention_ref(q, kp.astype(q.dtype),
                                       vp.astype(q.dtype), k_new, v_new,
                                       tables, offsets, lengths, group=group)


def paged_decode_attention_int8_ref(q, k_pages, k_scale, v_pages, v_scale,
                                    k_new, v_new, tables, lengths):
    """Oracle for the int8-dequantising paged decode kernel.

    k_pages/v_pages: (NP,BS,KV,hd) int8 with symmetric per-(token,
    kv-head) f32 scales k_scale/v_scale (NP,BS,KV,1).  Dequantises the
    WHOLE pool to f32 up front — the materialised computation the
    kernel's streamed in-VMEM multiply replaces — then runs the shared
    gather-then-attend reference.  k_new/v_new stay full precision.
    """
    kp = k_pages.astype(jnp.float32) * k_scale.astype(jnp.float32)
    vp = v_pages.astype(jnp.float32) * v_scale.astype(jnp.float32)
    return paged_decode_attention_ref(q, kp.astype(q.dtype),
                                      vp.astype(q.dtype), k_new, v_new,
                                      tables, lengths)
