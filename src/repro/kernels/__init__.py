"""Pallas TPU kernels for the ACCEL target + pure-jnp oracles.

One module per kernel (flash_attention, gqa_decode — including the
block-table-aware paged decode and its int8-dequantising variant,
rmsnorm, moe_gmm, ssd_scan, ...), `ops.py` for the jit-wrapped
model-facing entry points (GQA grouping, lane padding, interpret-mode
resolution via `REPRO_PALLAS_INTERPRET`), and `ref.py` for the
reference oracles every kernel is tested against.  On CPU-only hosts
the kernels run in `interpret=True` mode, so CI exercises the same
code paths without TPU hardware.
"""
