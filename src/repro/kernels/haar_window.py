"""Sliding-window classifier scoring — Pallas TPU kernel.

TPU adaptation of the paper's FaceDet benchmark (Rosetta Viola-Jones):
the FPGA pipeline evaluates a feature cascade over every sliding window,
holding the image in on-chip BRAM (the paper credits exactly this for
the FPGA win at 640x480).  The TPU analogue keeps the *whole image
resident in VMEM* (300 KB-1.2 MB << 16 MB) and turns window scoring
into MXU matmuls: each grid step gathers a tile of windows (im2col in
VMEM via dynamic_slice) and scores them against all feature templates
at once.  The cascade's early-exit becomes a post-hoc threshold on the
host side of the function boundary — uniform MXU work beats the skipped-
window savings of the FPGA pipeline (hardware-adaptation delta,
DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _window_kernel(img_ref, w_ref, o_ref, *, win: int, stride: int,
                   block_wy: int, block_wx: int):
    gy = pl.program_id(0)
    gx = pl.program_id(1)
    img = img_ref[...].astype(jnp.float32)    # full image in VMEM
    w = w_ref[...].astype(jnp.float32)        # (F, win*win)
    F = w.shape[0]
    y0 = gy * block_wy * stride
    x0 = gx * block_wx * stride
    rows = []
    for wy in range(block_wy):
        for wx in range(block_wx):
            patch = jax.lax.dynamic_slice(
                img, (y0 + wy * stride, x0 + wx * stride), (win, win))
            rows.append(patch.reshape(win * win))
    patches = jnp.stack(rows)                  # (block_wy*block_wx, win*win)
    scores = jax.lax.dot_general(patches, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    o_ref[...] = scores.reshape(block_wy, block_wx, F)


def window_scores(img: jax.Array, feats: jax.Array, *, win: int = 24,
                  stride: int = 4, block_wy: int = 4, block_wx: int = 4,
                  interpret: bool = False) -> jax.Array:
    """img: (H, W) f32; feats: (F, win*win) -> (ny, nx, F) scores."""
    H, W = img.shape
    F = feats.shape[0]
    ny = (H - win) // stride + 1
    nx = (W - win) // stride + 1

    def largest_divisor(n: int, at_most: int) -> int:
        for b in range(min(at_most, n), 0, -1):
            if n % b == 0:
                return b
        return 1

    block_wy = largest_divisor(ny, block_wy)
    block_wx = largest_divisor(nx, block_wx)

    kernel = functools.partial(_window_kernel, win=win, stride=stride,
                               block_wy=block_wy, block_wx=block_wx)
    return pl.pallas_call(
        kernel,
        grid=(ny // block_wy, nx // block_wx),
        in_specs=[
            pl.BlockSpec((H, W), lambda gy, gx: (0, 0)),
            pl.BlockSpec((F, feats.shape[1]), lambda gy, gx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_wy, block_wx, F),
                               lambda gy, gx: (gy, gx, 0)),
        out_shape=jax.ShapeDtypeStruct((ny, nx, F), jnp.float32),
        interpret=interpret,
    )(img, feats)
