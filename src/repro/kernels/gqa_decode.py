"""Single-token decode attention over a long KV cache — Pallas TPU kernel.

Flash-decoding style: grid ``(batch*heads, kv_blocks)`` streams the
cache through VMEM with online-softmax accumulators in scratch (one
q-row per program), masked at the live length.  This is the ACCEL
variant of the decode hot function (the serve-path analogue of the
paper's hardware kernel); oracle: ``ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, idx_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, kv_blocks: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (1, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    live = idx_ref[0]                                 # attend over [0, live]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(kpos <= live, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def gqa_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               index: jax.Array, *, block_k: int = 512,
               interpret: bool = False) -> jax.Array:
    """q: (BH, 1, hd); k_cache/v_cache: (BH, Smax, hd); index: () int32.

    Attends over cache positions [0, index].  BH = batch * q-heads with
    the cache already head-expanded by the ops wrapper.
    """
    BH, _, hd = q.shape
    Smax = k_cache.shape[1]
    block_k = min(block_k, Smax)
    assert Smax % block_k == 0, (Smax, block_k)
    nk = Smax // block_k
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               kv_blocks=nk, scale=scale)
    idx = jnp.broadcast_to(index.astype(jnp.int32), (1,))
    return pl.pallas_call(
        kernel,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1,), lambda b, ki: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, idx)
