"""Single-token decode attention over a long KV cache — Pallas TPU kernels.

Flash-decoding style: ``gqa_decode`` (grid ``(batch*heads, kv_blocks)``)
streams a dense cache through VMEM with online-softmax accumulators in
scratch (one q-row per program), masked at the live length.

``paged_gqa_decode`` is the block-table-aware variant for the paged
(vLLM-style) KV pool: the block table and per-row live lengths ride in
as scalar-prefetch operands, so each grid step's BlockSpec index map
dereferences ``table[b, j]`` and DMAs that PHYSICAL block from the pool
— the kernel walks a row's blocks in logical order without ever
materialising the gathered per-row cache.  The current token's K/V is
passed explicitly and folded into the online softmax on the final block
(write-then-attend: the pool contributes positions ``< length`` only).

``paged_gqa_decode_int8`` streams an int8 pool plus its parallel
per-token f32 scale planes through the same block table and
dequantises in-kernel (one broadcast multiply in VMEM) — the pool is
never materialised at full precision.

These are the ACCEL variants of the decode hot function (the serve-path
analogue of the paper's hardware kernel); oracles:
``ref.decode_attention_ref`` / ``ref.paged_decode_attention_ref`` /
``ref.paged_decode_attention_int8_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, idx_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, kv_blocks: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (1, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    live = idx_ref[0]                                 # attend over [0, live]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(kpos <= live, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def gqa_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               index: jax.Array, *, block_k: int = 512,
               interpret: bool = False) -> jax.Array:
    """q: (BH, 1, hd); k_cache/v_cache: (BH, Smax, hd); index: () int32
    shared position or (BH,) per-row positions.

    Attends over cache positions [0, index].  BH = batch * q-heads with
    the cache already head-expanded by the ops wrapper.
    """
    BH, _, hd = q.shape
    Smax = k_cache.shape[1]
    block_k = min(block_k, Smax)
    assert Smax % block_k == 0, (Smax, block_k)
    nk = Smax // block_k
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               kv_blocks=nk, scale=scale)
    if index.ndim:                      # ragged: one live length per row
        idx = index.astype(jnp.int32)
        idx_spec = pl.BlockSpec((1,), lambda b, ki: (b,))
    else:
        idx = jnp.broadcast_to(index.astype(jnp.int32), (1,))
        idx_spec = pl.BlockSpec((1,), lambda b, ki: (0,))
    return pl.pallas_call(
        kernel,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki: (b, ki, 0)),
            idx_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, idx)


# ------------------------------------------------------------ paged variant

def _paged_accumulate(q_ref, len_ref, kn_ref, vn_ref, o_ref, m_scr, l_scr,
                      acc_scr, k, v, *, block_size: int, nbt: int,
                      scale: float):
    """Shared online-softmax body of the paged decode kernels.

    ``k``/``v`` are this grid step's already-dequantised (block_size, hd)
    f32 planes — the f32 kernel passes the block verbatim, the int8
    kernel multiplies the streamed scale plane in first.  Keeping one
    body guarantees the two variants differ ONLY in the dequantise step.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    live = len_ref[b]                                 # pool valid on [0, live)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    s = jnp.where(kpos < live, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nbt - 1)
    def _finish():
        # fold the current token (position ``live``, not yet in the pool)
        kn = kn_ref[0, 0].astype(jnp.float32)         # (1, hd)
        vn = vn_ref[0, 0].astype(jnp.float32)
        s_cur = jax.lax.dot_general(q, kn, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
        m_prev = m_scr[...]
        m_fin = jnp.maximum(m_prev, s_cur)
        corr = jnp.exp(m_prev - m_fin)
        p_cur = jnp.exp(s_cur - m_fin)
        l_fin = l_scr[...] * corr + p_cur
        acc = acc_scr[...] * corr + p_cur * vn
        o_ref[0, 0] = (acc / jnp.maximum(l_fin, 1e-20)).astype(o_ref.dtype)


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, block_size: int,
                  nbt: int, scale: float):
    """One (row, kv-head, logical-block) grid step.

    The BlockSpec index map already resolved ``tbl_ref[b, j]`` to the
    physical block, so ``k_ref``/``v_ref`` hold that block's
    (block_size, hd) plane; the kernel only masks and accumulates.
    """
    del tbl_ref
    _paged_accumulate(q_ref, len_ref, kn_ref, vn_ref, o_ref, m_scr, l_scr,
                      acc_scr,
                      k_ref[0, :, 0].astype(jnp.float32),
                      v_ref[0, :, 0].astype(jnp.float32),
                      block_size=block_size, nbt=nbt, scale=scale)


def _paged_int8_kernel(tbl_ref, len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                       kn_ref, vn_ref, o_ref, m_scr, l_scr, acc_scr, *,
                       block_size: int, nbt: int, scale: float):
    """Int8-dequantising variant: the pool blocks arrive as int8 with a
    parallel (block_size, 1) f32 scale plane streamed through the SAME
    block-table index map; dequantisation is one broadcast multiply in
    VMEM, then the shared online-softmax body runs unchanged.  The
    current token's ``kn``/``vn`` stay full precision (not yet pooled).
    """
    del tbl_ref
    _paged_accumulate(q_ref, len_ref, kn_ref, vn_ref, o_ref, m_scr, l_scr,
                      acc_scr,
                      k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0],
                      v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0],
                      block_size=block_size, nbt=nbt, scale=scale)


def paged_gqa_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     k_new: jax.Array, v_new: jax.Array, tables: jax.Array,
                     lengths: jax.Array, *, interpret: bool = False
                     ) -> jax.Array:
    """q: (B, KV, G, hd) query heads grouped per kv head;
    k_pages/v_pages: (NP, BS, KV, hd) physical block pool;
    k_new/v_new: (B, KV, 1, hd) current token; tables: (B, NBT) int32
    physical block ids; lengths: (B,) int32 valid pool positions.

    Attends over pool positions [0, lengths[b]) plus the explicit
    current token.  Rows with length 0 reduce to softmax over the new
    token alone (out = v_new), so inactive serve rows are well-defined.
    """
    B, KV, G, hd = q.shape
    block_size = k_pages.shape[1]
    nbt = tables.shape[1]
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_paged_kernel, block_size=block_size,
                               nbt=nbt, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # tables, lengths
        grid=(B, KV, nbt),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, t, n: (b, h, 0, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda b, h, j, t, n: (t[b, j], 0, h, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda b, h, j, t, n: (t[b, j], 0, h, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j, t, n: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j, t, n: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, t, n: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages, k_new, v_new)


def paged_gqa_decode_int8(q: jax.Array, k_pages: jax.Array,
                          k_scale: jax.Array, v_pages: jax.Array,
                          v_scale: jax.Array, k_new: jax.Array,
                          v_new: jax.Array, tables: jax.Array,
                          lengths: jax.Array, *, interpret: bool = False
                          ) -> jax.Array:
    """``paged_gqa_decode`` over an int8 pool with per-token scales.

    k_pages/v_pages: (NP, BS, KV, hd) int8; k_scale/v_scale:
    (NP, BS, KV, 1) f32 — symmetric per-(token, kv-head) scales written
    alongside each quantised token.  The scale planes ride the SAME
    scalar-prefetched block table as the int8 blocks, so each grid step
    DMAs one (BS, hd) int8 plane plus its (BS, 1) scales and
    dequantises in VMEM — no materialised f32 pool anywhere.  q and the
    current token's k_new/v_new stay full precision.
    """
    B, KV, G, hd = q.shape
    block_size = k_pages.shape[1]
    nbt = tables.shape[1]
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_paged_int8_kernel, block_size=block_size,
                               nbt=nbt, scale=scale)
    page_spec = pl.BlockSpec((1, block_size, 1, hd),
                             lambda b, h, j, t, n: (t[b, j], 0, h, 0))
    scale_spec = pl.BlockSpec((1, block_size, 1, 1),
                              lambda b, h, j, t, n: (t[b, j], 0, h, 0))
    tok_spec = pl.BlockSpec((1, 1, 1, hd), lambda b, h, j, t, n: (b, h, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # tables, lengths
        grid=(B, KV, nbt),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, t, n: (b, h, 0, 0)),
            page_spec, scale_spec, page_spec, scale_spec,
            tok_spec, tok_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, t, n: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, k_scale.astype(jnp.float32),
      v_pages, v_scale.astype(jnp.float32), k_new, v_new)
