"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels compile natively; on this CPU-only container they
execute in ``interpret=True`` mode (Python evaluation of the kernel
body) for correctness validation.  The wrappers also do the model-facing
plumbing: GQA head expansion, head_dim padding to MXU lanes, flattening
(B, S, H, hd) <-> (BH, S, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import gqa_decode as _gd
from repro.kernels import haar_window as _hw
from repro.kernels import knn_digits as _knn
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssd_scan as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag) -> bool:
    if flag is None:
        return not on_tpu()
    return flag


def _pad_lanes(x: jax.Array, axis: int, multiple: int = 128) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "kv_index"))
def flash_attention(q, k, v, *, kv_index: tuple | None = None,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool | None = None):
    """Model-facing fused attention.  q: (B,S,Hp,hd); k/v: (B,T,KV,hd)."""
    B, S, Hp, hd = q.shape
    T = k.shape[1]
    if kv_index is not None:
        idx = np.asarray(kv_index)
        k = k[:, :, idx, :]
        v = v[:, :, idx, :]
    qf = _pad_lanes(q.transpose(0, 2, 1, 3).reshape(B * Hp, S, hd), -1)
    kf = _pad_lanes(k.transpose(0, 2, 1, 3).reshape(B * Hp, T, hd), -1)
    vf = _pad_lanes(v.transpose(0, 2, 1, 3).reshape(B * Hp, T, hd), -1)
    # zero-padded value lanes produce zero outputs; padded key lanes add 0 to
    # scores; but the softmax scale must use the REAL hd (cast the factor:
    # a numpy scalar would promote bf16 inputs to f32):
    scale_fix = jnp.asarray(np.sqrt(qf.shape[-1] / hd), qf.dtype)
    out = _fa.flash_attention(qf * scale_fix, kf, vf, causal=causal,
                              block_q=block_q, block_k=block_k,
                              interpret=_interpret(interpret))
    out = out[..., :hd].reshape(B, Hp, S, hd).transpose(0, 2, 1, 3)
    return out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
             interpret: bool | None = None):
    """Model-facing SSD.  x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).

    Returns (y: (B,S,H,P), state: (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H)
    Bf = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cf = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    y, state = _ssd.ssd_scan(xf, dtf, Af, Bf, Cf, chunk=chunk,
                             interpret=_interpret(interpret))
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, state.reshape(B, H, P, N)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def grouped_matmul(x, w, group_sizes, *, block_c: int = 128,
                   block_f: int = 128, block_d: int = 256,
                   interpret: bool | None = None):
    return _gmm.grouped_matmul(x, w, group_sizes, block_c=block_c,
                               block_f=block_f, block_d=block_d,
                               interpret=_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 128,
            interpret: bool | None = None):
    """x: (..., d) -> normalised, arbitrary leading dims."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    R = flat.shape[0]
    br = block_rows
    while R % br:
        br //= 2
    out = _rms.rmsnorm(flat, w, eps=eps, block_rows=max(br, 1),
                       interpret=_interpret(interpret))
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def knn_digits(test, train, train_labels, *, k: int = 3,
               interpret: bool | None = None):
    """Full DigitRec function: distance kernel + host-side top-k vote.

    test: (Nt, W) uint32; train: (Nn, W) uint32; train_labels: (Nn,) int32.
    Returns predicted labels (Nt,) int32.
    """
    d = _knn.hamming_distances(test, train, interpret=_interpret(interpret))
    _, idx = jax.lax.top_k(-d, k)                     # k smallest distances
    votes = train_labels[idx]                          # (Nt, k)
    counts = jax.vmap(lambda v: jnp.bincount(v, length=10))(votes)
    return jnp.argmax(counts, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("win", "stride", "interpret"))
def window_scores(img, feats, *, win: int = 24, stride: int = 4,
                  interpret: bool | None = None):
    return _hw.window_scores(img, feats, win=win, stride=stride,
                             interpret=_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_k", "interpret",
                                             "kv_index"))
def gqa_decode(q, k_cache, v_cache, index, *, kv_index: tuple | None = None,
               block_k: int = 512, interpret: bool | None = None):
    """Model-facing decode attention.  q: (B,1,Hp,hd);
    k_cache/v_cache: (B,Smax,KV,hd); index: () int32."""
    B, _, Hp, hd = q.shape
    Smax = k_cache.shape[1]
    if kv_index is not None:
        idx = np.asarray(kv_index)
        k_cache = k_cache[:, :, idx, :]
        v_cache = v_cache[:, :, idx, :]
    qf = _pad_lanes(q.transpose(0, 2, 1, 3).reshape(B * Hp, 1, hd), -1)
    kf = _pad_lanes(k_cache.transpose(0, 2, 1, 3).reshape(B * Hp, Smax, hd), -1)
    vf = _pad_lanes(v_cache.transpose(0, 2, 1, 3).reshape(B * Hp, Smax, hd), -1)
    scale_fix = jnp.asarray(np.sqrt(qf.shape[-1] / hd), qf.dtype)
    out = _gd.gqa_decode(qf * scale_fix, kf, vf, index, block_k=block_k,
                         interpret=_interpret(interpret))
    return out[..., :hd].reshape(B, Hp, 1, hd).transpose(0, 2, 1, 3)
