"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels compile natively; on CPU-only hosts (CI runners,
this container) they execute in ``interpret=True`` mode (Python
evaluation of the kernel body) for correctness validation.  Every
wrapper derives its default ``interpret=`` from backend detection
(``on_tpu()``), overridable via ``REPRO_PALLAS_INTERPRET``:

  * unset / ``auto`` — interpret unless running on TPU (the default);
  * ``1`` / ``true``  — force interpret mode everywhere;
  * ``0`` / ``false`` — force native compilation (debugging lowering
    on CPU, or pinning native mode on TPU).

The wrappers also do the model-facing plumbing: GQA head expansion /
grouping, head_dim padding to MXU lanes, flattening
(B, S, H, hd) <-> (BH, S, hd).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import gqa_decode as _gd
from repro.kernels import gqa_prefill as _gp
from repro.kernels import haar_window as _hw
from repro.kernels import knn_digits as _knn
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssd_scan as _ssd

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag) -> bool:
    if flag is not None:
        return flag
    env = os.environ.get(INTERPRET_ENV, "").strip().lower()
    if env in ("1", "true", "on", "yes"):
        return True
    if env in ("0", "false", "off", "no"):
        return False
    return not on_tpu()


def _with_env_interpret(jitted):
    """Resolve the ``interpret`` default BEFORE jit dispatch.

    ``interpret`` is a static argname on every wrapper, so it must reach
    the jit boundary as a concrete bool: resolving the env/backend
    default inside the traced body would bake the first resolution into
    the cached executable and silently ignore a later
    ``REPRO_PALLAS_INTERPRET`` change (the cache keys on the static
    ``None``, not on the resolved value).
    """
    @functools.wraps(jitted)
    def call(*args, interpret=None, **kwargs):
        return jitted(*args, interpret=_interpret(interpret), **kwargs)
    return call


def _pad_lanes(x: jax.Array, axis: int, multiple: int = 128) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "kv_index"))
def flash_attention(q, k, v, *, kv_index: tuple | None = None,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool | None = None):
    """Model-facing fused attention.  q: (B,S,Hp,hd); k/v: (B,T,KV,hd)."""
    B, S, Hp, hd = q.shape
    T = k.shape[1]
    if kv_index is not None:
        idx = np.asarray(kv_index)
        k = k[:, :, idx, :]
        v = v[:, :, idx, :]
    qf = _pad_lanes(q.transpose(0, 2, 1, 3).reshape(B * Hp, S, hd), -1)
    kf = _pad_lanes(k.transpose(0, 2, 1, 3).reshape(B * Hp, T, hd), -1)
    vf = _pad_lanes(v.transpose(0, 2, 1, 3).reshape(B * Hp, T, hd), -1)
    # zero-padded value lanes produce zero outputs; padded key lanes add 0 to
    # scores; but the softmax scale must use the REAL hd (cast the factor:
    # a numpy scalar would promote bf16 inputs to f32):
    scale_fix = jnp.asarray(np.sqrt(qf.shape[-1] / hd), qf.dtype)
    out = _fa.flash_attention(qf * scale_fix, kf, vf, causal=causal,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    out = out[..., :hd].reshape(B, Hp, S, hd).transpose(0, 2, 1, 3)
    return out


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
             interpret: bool | None = None):
    """Model-facing SSD.  x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).

    Returns (y: (B,S,H,P), state: (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H)
    Bf = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cf = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    y, state = _ssd.ssd_scan(xf, dtf, Af, Bf, Cf, chunk=chunk,
                             interpret=interpret)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, state.reshape(B, H, P, N)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def grouped_matmul(x, w, group_sizes, *, block_c: int = 128,
                   block_f: int = 128, block_d: int = 256,
                   interpret: bool | None = None):
    return _gmm.grouped_matmul(x, w, group_sizes, block_c=block_c,
                               block_f=block_f, block_d=block_d,
                               interpret=interpret)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 128,
            interpret: bool | None = None):
    """x: (..., d) -> normalised, arbitrary leading dims."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    R = flat.shape[0]
    br = block_rows
    while R % br:
        br //= 2
    out = _rms.rmsnorm(flat, w, eps=eps, block_rows=max(br, 1),
                       interpret=interpret)
    return out.reshape(shape)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def knn_digits(test, train, train_labels, *, k: int = 3,
               interpret: bool | None = None):
    """Full DigitRec function: distance kernel + host-side top-k vote.

    test: (Nt, W) uint32; train: (Nn, W) uint32; train_labels: (Nn,) int32.
    Returns predicted labels (Nt,) int32.
    """
    d = _knn.hamming_distances(test, train, interpret=interpret)
    _, idx = jax.lax.top_k(-d, k)                     # k smallest distances
    votes = train_labels[idx]                          # (Nt, k)
    counts = jax.vmap(lambda v: jnp.bincount(v, length=10))(votes)
    return jnp.argmax(counts, axis=-1).astype(jnp.int32)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("win", "stride", "interpret"))
def window_scores(img, feats, *, win: int = 24, stride: int = 4,
                  interpret: bool | None = None):
    return _hw.window_scores(img, feats, win=win, stride=stride,
                             interpret=interpret)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("block_k", "interpret",
                                             "kv_index"))
def gqa_decode(q, k_cache, v_cache, index, *, kv_index: tuple | None = None,
               block_k: int = 512, interpret: bool | None = None):
    """Model-facing decode attention.  q: (B,1,Hp,hd);
    k_cache/v_cache: (B,Smax,KV,hd); index: () int32 shared position or
    (B,)/(B,1,1,1) ragged per-row positions (attends [0, index])."""
    B, _, Hp, hd = q.shape
    Smax = k_cache.shape[1]
    if kv_index is not None:
        idx = np.asarray(kv_index)
        k_cache = k_cache[:, :, idx, :]
        v_cache = v_cache[:, :, idx, :]
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hp, 1, hd)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Hp, Smax, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Hp, Smax, hd)
    if not interpret and hd % 128:
        # native TPU lanes only: interpret mode (CI) skips the
        # full-cache pad copy, like the paged path below
        qf, kf, vf = (_pad_lanes(a, -1) for a in (qf, kf, vf))
        qf = qf * jnp.asarray(np.sqrt(qf.shape[-1] / hd), qf.dtype)
    if index.ndim:                      # per-row -> per-(row, head)
        index = jnp.repeat(index.astype(jnp.int32).reshape(B), Hp)
    out = _gd.gqa_decode(qf, kf, vf, index, block_k=block_k,
                         interpret=interpret)
    return out[..., :hd].reshape(B, Hp, 1, hd).transpose(0, 2, 1, 3)


# --------------------------------------------------- paged / ragged decode

def _kv_grouping(Hp: int, KV: int, kv_index: tuple | None):
    """Static grouping of query heads by the kv head they attend.

    Returns (kvmap, pos, qhead_for, G): query head h reads kv head
    ``kvmap[h]`` at group rank ``pos[h]``; group slot (kv, g) holds
    query head ``qhead_for[kv, g]``.  Handles non-uniform groups (TP
    head padding clamps extra query heads onto the last kv head) by
    sizing G to the largest group; surplus slots repeat head 0 and are
    simply never read back by the (kvmap, pos) ungather.
    """
    kvmap = (np.arange(Hp) if kv_index is None
             else np.asarray(kv_index, np.int32))
    counts = np.bincount(kvmap, minlength=KV)
    G = max(int(counts.max()), 1)
    qhead_for = np.zeros((KV, G), np.int32)
    pos = np.zeros(Hp, np.int32)
    fill = np.zeros(KV, np.int32)
    for h, kv in enumerate(kvmap):
        qhead_for[kv, fill[kv]] = h
        pos[h] = fill[kv]
        fill[kv] += 1
    return kvmap, pos, qhead_for, G


def _paged_decode_common(q, k_pages, v_pages, k_new, v_new, tables, index,
                         kv_index, interpret, k_scale=None, v_scale=None):
    B, _, Hp, hd = q.shape
    KV = k_pages.shape[2]
    kvmap, pos, qhead_for, _ = _kv_grouping(Hp, KV, kv_index)
    # both public wrappers are @_with_env_interpret-decorated, so the
    # flag is already a concrete bool here (env resolution must stay
    # outside the traced body — see _with_env_interpret)
    interp = interpret
    qg = q[:, 0][:, qhead_for]                  # (B, KV, G, hd)
    kn = k_new.transpose(0, 2, 1, 3)            # (B, KV, 1, hd)
    vn = v_new.transpose(0, 2, 1, 3)
    hdp = k_pages.shape[-1]
    if hdp != hd:
        # pool allocated lane-aligned (init_paged_kv_cache(lane_align=)):
        # only the per-token operands need padding to the pool's width —
        # the whole-pool copy below never fires on an aligned pool
        qg, kn, vn = (_pad_lanes(a, -1, multiple=hdp)
                      for a in (qg, kn, vn))
    if not interp and qg.shape[-1] % 128:
        # legacy unaligned pool on native TPU lanes: pad hd — this pads
        # the WHOLE pool per call; production TPU deployments should
        # allocate the pool lane-aligned so this branch never fires
        # (zero int8 lanes dequantise to exact 0, so the scale planes
        # themselves never need padding — their trailing dim is 1)
        qg, kn, vn = (_pad_lanes(a, -1) for a in (qg, kn, vn))
        k_pages = _pad_lanes(k_pages, -1)
        v_pages = _pad_lanes(v_pages, -1)
    if qg.shape[-1] != hd:
        # padded key lanes add 0 to scores, but the softmax scale must
        # still use the REAL hd: pre-scale q by sqrt(hd_final / hd)
        # (cast — a numpy scalar would promote bf16 inputs to f32)
        qg = qg * jnp.asarray(np.sqrt(qg.shape[-1] / hd), qg.dtype)
    idx = index.astype(jnp.int32)
    idx = jnp.broadcast_to(idx.reshape(-1) if idx.ndim else idx, (B,))
    if k_scale is not None:
        out = _gd.paged_gqa_decode_int8(qg, k_pages, k_scale, v_pages,
                                        v_scale, kn, vn,
                                        tables.astype(jnp.int32), idx,
                                        interpret=interp)
    else:
        out = _gd.paged_gqa_decode(qg, k_pages, v_pages, kn, vn,
                                   tables.astype(jnp.int32), idx,
                                   interpret=interp)
    return out[:, kvmap, pos][..., :hd][:, None]     # (B, 1, Hp, hd)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "interpret"))
def paged_gqa_decode(q, k_pages, v_pages, k_new, v_new, tables, index, *,
                     kv_index: tuple | None = None,
                     interpret: bool | None = None):
    """Model-facing paged decode attention over a block-pool KV cache.

    q: (B,1,Hp,hd); k_pages/v_pages: (NP,BS,KV,hd) physical pool;
    k_new/v_new: (B,1,KV,hd) current token; tables: (B,NBT) int32
    physical block ids; index: (B,) int32 per-row write positions.
    The kernel streams each row's blocks in logical order via the
    scalar-prefetched table — no materialised per-row gathered cache.
    """
    return _paged_decode_common(q, k_pages, v_pages, k_new, v_new,
                                tables, index, kv_index, interpret)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "interpret"))
def paged_gqa_decode_int8(q, k_pages, k_scale, v_pages, v_scale, k_new,
                          v_new, tables, index, *,
                          kv_index: tuple | None = None,
                          interpret: bool | None = None):
    """Model-facing paged decode over an int8 block pool with scales.

    Same ABI as ``paged_gqa_decode`` plus the parallel scale pools:
    k_pages/v_pages are (NP,BS,KV,hd) int8 and k_scale/v_scale are
    (NP,BS,KV,1) f32 symmetric per-(token, kv-head) scales.  The kernel
    streams block AND scale planes through the scalar-prefetched table
    and dequantises in VMEM — int8 no longer falls back to XLA math.
    """
    return _paged_decode_common(q, k_pages, v_pages, k_new, v_new,
                                tables, index, kv_index, interpret,
                                k_scale=k_scale, v_scale=v_scale)


# ------------------------------------------------------- paged prefill

def _paged_prefill_common(q, k_pages, v_pages, k_new, v_new, tables, offset,
                          length, kv_index, interpret, k_scale=None,
                          v_scale=None):
    """Shared plumbing of the chunk-prefill wrappers: GQA grouping of the
    W-token chunk queries (token-major / group-rank-minor flattening, the
    layout the kernel's ``r // group`` causal mask expects), lane
    padding, and the ungather back to (B, W, Hp, hd)."""
    B, W, Hp, hd = q.shape
    KV = k_pages.shape[2]
    kvmap, pos, qhead_for, G = _kv_grouping(Hp, KV, kv_index)
    interp = interpret
    qg = q[:, :, qhead_for]                     # (B, W, KV, G, hd)
    qg = qg.transpose(0, 2, 1, 3, 4).reshape(B, KV, W * G, hd)
    kn, vn = k_new, v_new                       # (B, W, KV, hd) verbatim
    hdp = k_pages.shape[-1]
    if hdp != hd:
        # lane-aligned pool: pad only the per-token operands (cheap)
        qg, kn, vn = (_pad_lanes(a, -1, multiple=hdp)
                      for a in (qg, kn, vn))
    if not interp and qg.shape[-1] % 128:
        # legacy unaligned pool on native TPU lanes (see decode path)
        qg, kn, vn = (_pad_lanes(a, -1) for a in (qg, kn, vn))
        k_pages = _pad_lanes(k_pages, -1)
        v_pages = _pad_lanes(v_pages, -1)
    if qg.shape[-1] != hd:
        qg = qg * jnp.asarray(np.sqrt(qg.shape[-1] / hd), qg.dtype)
    off = offset.astype(jnp.int32)
    off = jnp.broadcast_to(off.reshape(-1) if off.ndim else off, (B,))
    ln = length.astype(jnp.int32)
    ln = jnp.broadcast_to(ln.reshape(-1) if ln.ndim else ln, (B,))
    if k_scale is not None:
        out = _gp.paged_gqa_prefill_int8(qg, k_pages, k_scale, v_pages,
                                         v_scale, kn, vn,
                                         tables.astype(jnp.int32), off, ln,
                                         group=G, interpret=interp)
    else:
        out = _gp.paged_gqa_prefill(qg, k_pages, v_pages, kn, vn,
                                    tables.astype(jnp.int32), off, ln,
                                    group=G, interpret=interp)
    out = out.reshape(B, KV, W, G, out.shape[-1]).transpose(0, 2, 1, 3, 4)
    return out[:, :, kvmap, pos][..., :hd]           # (B, W, Hp, hd)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "interpret"))
def paged_gqa_prefill(q, k_pages, v_pages, k_new, v_new, tables, offset,
                      length, *, kv_index: tuple | None = None,
                      interpret: bool | None = None):
    """Model-facing chunk-prefill attention over a block-pool KV cache.

    q: (B,W,Hp,hd) chunk queries at absolute positions ``offset + j``;
    k_pages/v_pages: (NP,BS,KV,hd) physical pool; k_new/v_new:
    (B,W,KV,hd) the chunk's own K/V; tables: (B,NBT) int32 physical
    block ids; offset/length: (B,) int32.  The kernel streams each
    row's pool blocks masked to [0, offset) and folds the chunk's
    causal self-attention on the final block step — no materialised
    gather.
    """
    return _paged_prefill_common(q, k_pages, v_pages, k_new, v_new,
                                 tables, offset, length, kv_index, interpret)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "interpret"))
def paged_gqa_prefill_int8(q, k_pages, k_scale, v_pages, v_scale, k_new,
                           v_new, tables, offset, length, *,
                           kv_index: tuple | None = None,
                           interpret: bool | None = None):
    """Model-facing chunk prefill over an int8 block pool with scales.

    Same ABI as ``paged_gqa_prefill`` plus the parallel scale pools
    (k_scale/v_scale (NP,BS,KV,1) f32); blocks and scale planes stream
    through the scalar-prefetched table and dequantise in VMEM.
    """
    return _paged_prefill_common(q, k_pages, v_pages, k_new, v_new,
                                 tables, offset, length, kv_index, interpret,
                                 k_scale=k_scale, v_scale=v_scale)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "interpret"))
def paged_gqa_verify(q, k_pages, v_pages, k_new, v_new, tables, offset,
                     length, *, kv_index: tuple | None = None,
                     interpret: bool | None = None):
    """Model-facing speculative-decode VERIFY attention.

    The verify pass of speculative decoding scores a slot's k drafted
    tokens in one batched step; its attention math is EXACTLY chunk
    prefill at offset (the chunk is the drafted span, the pool holds the
    committed prefix), so this delegates to the same kernel body as
    ``paged_gqa_prefill``.  It exists as a separately-named wrapper so
    the serve engine can register verify as a DISTINCT HOST/ACCEL
    binary in the Xar-Trek runtime — migration decisions and
    ``summary()`` call accounting see draft and verify independently.
    ABI identical to ``paged_gqa_prefill``.
    """
    return _paged_prefill_common(q, k_pages, v_pages, k_new, v_new,
                                 tables, offset, length, kv_index, interpret)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "interpret"))
def paged_gqa_verify_int8(q, k_pages, k_scale, v_pages, v_scale, k_new,
                          v_new, tables, offset, length, *,
                          kv_index: tuple | None = None,
                          interpret: bool | None = None):
    """Speculative-decode verify over an int8 block pool with scales.

    Same ABI as ``paged_gqa_prefill_int8`` (see ``paged_gqa_verify`` for
    why verify gets its own wrapper): blocks and scale planes stream
    through the scalar-prefetched table and dequantise in VMEM, so
    ACCEL verify over a quantised pool is a real Pallas build.
    """
    return _paged_prefill_common(q, k_pages, v_pages, k_new, v_new,
                                 tables, offset, length, kv_index, interpret,
                                 k_scale=k_scale, v_scale=v_scale)


@_with_env_interpret
@functools.partial(jax.jit, static_argnames=("kv_index", "block_k",
                                             "interpret"))
def gqa_decode_ragged(q, k_cache, v_cache, index, k_new, v_new, *,
                      kv_index: tuple | None = None, block_k: int = 128,
                      interpret: bool | None = None):
    """Ragged-index dense-cache decode via the paged kernel.

    q: (B,1,Hp,hd); k_cache/v_cache: (B,Smax,KV,hd); index: () or (B,)
    int32 valid-position counts (cache rows hold [0, index) plus the
    explicit k_new/v_new (B,1,KV,hd) current token).  The dense cache is
    VIEWED as B*nb physical blocks with an identity block table — a
    reshape, not a copy — so one kernel serves dense and paged decode.
    """
    B, _, Hp, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    bs = min(block_k, Smax)
    while Smax % bs:
        bs //= 2
    nb = Smax // bs
    kp = k_cache.reshape(B * nb, bs, KV, hd)
    vp = v_cache.reshape(B * nb, bs, KV, hd)
    tables = (jnp.arange(B, dtype=jnp.int32)[:, None] * nb
              + jnp.arange(nb, dtype=jnp.int32)[None, :])
    return _paged_decode_common(q, kp, vp, k_new, v_new, tables, index,
                                kv_index, interpret)
