"""Request admission for continuous-batching serving.

A ``GenerationRequest`` (see ``serve.api``) is one generation job
(prompt + sampling spec + token budget) with an arrival time; the
``RequestQueue`` is the multi-tenant arrival stream of the paper's
Figure-6 throughput experiment — requests become visible to the engine
only once the serving clock passes their ``arrival_s``, and are
admitted FIFO among the arrived.

The queue is thread-safe so a driver thread can keep submitting while
the engine loop drains (the single-process analogue of the paper's
socket-connected applications).

The v1 ``Request`` shim is GONE (callers migrated in PR 4/5):
constructing it raises with a pointer at ``GenerationRequest``.
"""
from __future__ import annotations

import heapq
import itertools
import random
import threading
from typing import Iterable, Optional

from repro.serve.api import GenerationRequest


class Request:
    """Removed v1 request type (was a deprecation shim until PR 5).

    Kept importable so stale callers fail with an actionable error
    instead of an ImportError far from the fix.
    """

    def __init__(self, *args, **kwargs):
        raise TypeError(
            "serve.Request was removed; construct "
            "serve.GenerationRequest(prompt, max_new_tokens=..., "
            "sampling=SamplingParams(...)) instead")


class RequestQueue:
    """Arrival-time-ordered FIFO of pending requests."""

    def __init__(self, requests: Iterable[GenerationRequest] = ()):
        self._lock = threading.Lock()
        self._heap: list[tuple[float, int, GenerationRequest]] = []
        self._seq = itertools.count()     # FIFO tie-break among same-time
        self._front = itertools.count(start=-1, step=-1)
        for r in requests:
            self.submit(r)

    def submit(self, request: GenerationRequest) -> int:
        with self._lock:
            heapq.heappush(self._heap,
                           (request.arrival_s, next(self._seq), request))
        return request.req_id

    def requeue(self, request: GenerationRequest) -> int:
        """Put a popped request back at the FRONT of its arrival cohort
        (engine backpressure: admission was attempted but capacity — e.g.
        the KV block pool — was not available, or the request was
        preempted and must resume before newer work)."""
        with self._lock:
            heapq.heappush(self._heap,
                           (request.arrival_s, next(self._front), request))
        return request.req_id

    def pop_arrived(self, now: float) -> Optional[GenerationRequest]:
        """Earliest-arrived request with arrival_s <= now, else None."""
        with self._lock:
            if self._heap and self._heap[0][0] <= now:
                return heapq.heappop(self._heap)[2]
            return None

    def remove(self, req_id: int) -> Optional[GenerationRequest]:
        """Pull a pending request out of the queue (abort before
        admission).  Returns it, or None if not queued."""
        with self._lock:
            for i, (_, _, r) in enumerate(self._heap):
                if r.req_id == req_id:
                    entry = self._heap.pop(i)
                    heapq.heapify(self._heap)
                    return entry[2]
            return None

    def next_arrival(self) -> Optional[float]:
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def arrived_len(self, now: float) -> int:
        """Requests with ``arrival_s <= now`` — the queue depth that is
        actually LOAD.  ``len(queue)`` is the whole arrival heap, which
        for a pre-scheduled stream (e.g. Poisson benchmark arrivals)
        counts requests that do not exist yet; publishing that as
        ``LoadSignals.queue_depth`` inflated ``x86_load`` and tripped
        queue-depth policy thresholds before any real pressure."""
        with self._lock:
            return sum(1 for a, _, _ in self._heap if a <= now)

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)


def poisson_arrivals(n: int, rate_per_s: float,
                     rng: random.Random | int = 0) -> list[float]:
    """n arrival times of a Poisson process with the given rate (exp(rate)
    inter-arrival gaps) — the Figure-6 style multi-tenant stream."""
    if not isinstance(rng, random.Random):
        rng = random.Random(rng)
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate_per_s)
        out.append(t)
    return out
