"""Request admission for continuous-batching serving.

A ``Request`` is one generation job (prompt + token budget) with an
arrival time; the ``RequestQueue`` is the multi-tenant arrival stream of
the paper's Figure-6 throughput experiment — requests become visible to
the engine only once the serving clock passes their ``arrival_s``, and
are admitted FIFO among the arrived.

The queue is thread-safe so a driver thread can keep submitting while
the engine loop drains (the single-process analogue of the paper's
socket-connected applications).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
import threading
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation job.  ``prompt``: (S,) int32 token ids.

    ``stop_tokens``: generation ends the step any of these ids is
    emitted (the stop token is included in the output), freeing the
    request's slot — and, under paging, its KV blocks — immediately
    instead of running out the full ``max_new_tokens`` budget.
    """

    prompt: np.ndarray
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    stop_tokens: tuple = ()
    req_id: int = dataclasses.field(
        default_factory=itertools.count().__next__)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.stop_tokens = tuple(int(t) for t in (self.stop_tokens or ()))

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def stops(self, token: int) -> bool:
        return token in self.stop_tokens


class RequestQueue:
    """Arrival-time-ordered FIFO of pending requests."""

    def __init__(self, requests: Iterable[Request] = ()):
        self._lock = threading.Lock()
        self._heap: list[tuple[float, int, Request]] = []
        self._seq = itertools.count()     # FIFO tie-break among same-time
        self._front = itertools.count(start=-1, step=-1)
        for r in requests:
            self.submit(r)

    def submit(self, request: Request) -> int:
        with self._lock:
            heapq.heappush(self._heap,
                           (request.arrival_s, next(self._seq), request))
        return request.req_id

    def requeue(self, request: Request) -> int:
        """Put a popped request back at the FRONT of its arrival cohort
        (engine backpressure: admission was attempted but capacity — e.g.
        the KV block pool — was not available, or the request was
        preempted and must resume before newer work)."""
        with self._lock:
            heapq.heappush(self._heap,
                           (request.arrival_s, next(self._front), request))
        return request.req_id

    def pop_arrived(self, now: float) -> Optional[Request]:
        """Earliest-arrived request with arrival_s <= now, else None."""
        with self._lock:
            if self._heap and self._heap[0][0] <= now:
                return heapq.heappop(self._heap)[2]
            return None

    def next_arrival(self) -> Optional[float]:
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)


def poisson_arrivals(n: int, rate_per_s: float,
                     rng: random.Random | int = 0) -> list[float]:
    """n arrival times of a Poisson process with the given rate (exp(rate)
    inter-arrival gaps) — the Figure-6 style multi-tenant stream."""
    if not isinstance(rng, random.Random):
        rng = random.Random(rng)
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate_per_s)
        out.append(t)
    return out
