"""Serve API v2: the application-facing request/response types.

Xar-Trek serves socket-connected applications whose functions migrate
between targets at run time; the application-facing contract must stay
stable while the backend moves.  These types ARE that contract for the
serving front-end:

* ``SamplingParams`` — the per-request decoding spec (temperature /
  top-k / top-p / seed).  ``temperature == 0.0`` (the default) is
  greedy argmax, byte-identical to the pre-v2 engines.  Sampling runs
  *inside* the jitted decode step (see ``models/sampling.py``) with a
  per-row PRNG key built as ``fold_in(PRNGKey(seed), position)``, so a
  seeded request reproduces the same tokens on the HOST (XLA) and ACCEL
  (Pallas) builds, under mid-stream migration, and across
  preempt/resume.

* ``GenerationRequest`` — one generation job: prompt + SamplingParams +
  stop/budget + arrival time.  Supersedes the v1 ``Request``
  (``serve.scheduler.Request`` remains as a thin deprecated shim).

* ``RequestOutput`` — the finished result: tokens, a finish reason
  (``stop`` | ``length`` | ``aborted``) and per-request latency
  metrics (queue wait, TTFT, TPOT).

* ``RequestHandle`` — returned by ``ContinuousBatchingEngine.submit``:
  a streaming surface over one in-flight request.  Tokens can be
  consumed as they are emitted (blocking iterator, or an ``on_token``
  callback fired from the engine loop), ``result()`` blocks for the
  final ``RequestOutput``, and ``abort()`` cancels the request
  mid-stream (its slot and KV blocks free immediately).
"""
from __future__ import annotations

import dataclasses
import itertools
import queue as queue_lib
import threading
import time
from typing import Callable, Optional

import numpy as np

FINISH_STOP = "stop"          # a stop token was emitted
FINISH_LENGTH = "length"      # max_new_tokens budget exhausted
FINISH_ABORTED = "aborted"    # caller cancelled mid-stream
FINISH_REASONS = (FINISH_STOP, FINISH_LENGTH, FINISH_ABORTED)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding spec.

    ``temperature == 0.0`` (default) is exact greedy argmax — the
    sampled path is bypassed entirely, so greedy outputs are
    byte-identical to the pre-sampling engines.  ``top_k <= 0`` and
    ``top_p >= 1.0`` disable the respective filters.  ``seed`` fully
    determines the draw for a given token position: the in-graph key is
    ``fold_in(PRNGKey(seed), absolute_position)``, independent of slot
    index, batch composition, backend, and preemption history.

    ``logprobs=True`` surfaces each emitted token's logprob in
    ``RequestOutput.logprobs`` (and streams it via the handle's
    ``logprobs`` list).  The logprob is the chosen token's log-mass
    under the RAW model distribution (before temperature/top-k/top-p),
    so it is well-defined for greedy requests too and identical on
    every backend.  The jitted step always computes it — opting in
    changes what is *returned to the caller*, never the compile
    signature.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    logprobs: bool = False

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off): {self.top_k}")
        if not isinstance(self.seed, (int, np.integer)):
            raise ValueError(f"seed must be an int: {self.seed!r}")
        if not isinstance(self.logprobs, bool):
            raise ValueError(f"logprobs must be a bool: {self.logprobs!r}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


@dataclasses.dataclass
class GenerationRequest:
    """One generation job.  ``prompt``: (S,) int32 token ids.

    ``stop_tokens``: generation ends the step any of these ids is
    emitted (the stop token is included in the output), freeing the
    request's slot — and, under paging, its KV blocks — immediately
    instead of running out the full ``max_new_tokens`` budget.

    ``sampling`` is the per-request decoding spec; the default is
    greedy (temperature 0.0).
    """

    prompt: np.ndarray
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    stop_tokens: tuple = ()
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    req_id: int = dataclasses.field(
        default_factory=itertools.count().__next__)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.stop_tokens = tuple(int(t) for t in (self.stop_tokens or ()))
        if self.sampling is None:
            self.sampling = SamplingParams()

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def stops(self, token: int) -> bool:
        return token in self.stop_tokens


@dataclasses.dataclass
class RequestOutput:
    """Finished (or aborted) request: tokens + finish reason + metrics.

    ``queue_wait_s``: submission/arrival to first admission (slot + KV
    capacity granted).  ``ttft_s``: arrival to first emitted token
    (includes the queue wait and the prefill).  ``tpot_s``: mean
    inter-token time over the decode steps (0 for single-token
    outputs).  Aborted requests carry whatever tokens were generated
    before the abort.

    ``logprobs``: (n_generated,) f32 chosen-token logprobs, aligned
    with ``tokens``, when the request set ``SamplingParams.logprobs``;
    ``None`` otherwise.
    """

    req_id: int
    tokens: np.ndarray                  # (n_generated,) int32
    finish_reason: str                  # stop | length | aborted
    queue_wait_s: float = 0.0
    ttft_s: float = 0.0
    tpot_s: float = 0.0
    logprobs: Optional[np.ndarray] = None

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.logprobs is not None:
            self.logprobs = np.asarray(self.logprobs,
                                       np.float32).reshape(-1)
            if self.logprobs.shape != self.tokens.shape:
                raise ValueError(
                    f"logprobs/tokens length mismatch: "
                    f"{self.logprobs.shape} vs {self.tokens.shape}")
        if self.finish_reason not in FINISH_REASONS:
            raise ValueError(f"finish_reason must be one of {FINISH_REASONS}:"
                             f" {self.finish_reason!r}")

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])


_SENTINEL = object()


class RequestHandle:
    """Streaming view of one submitted request.

    The engine pushes tokens into the handle the step they are sampled;
    consumers either iterate (``for tok in handle`` — blocks until the
    next token or end-of-stream; run the engine loop in another thread)
    or attach an ``on_token`` callback (fired synchronously from the
    engine loop).  ``result()`` blocks until the final
    ``RequestOutput``; ``abort()`` cancels the request mid-stream.

    Tokens survive preemption: a preempted-and-resumed request replays
    its stashed tokens into the slot, and the handle's already-pushed
    count ensures nothing is re-emitted.

    A handle can also be REMOTE: the engine serving the request lives in
    another OS process and streams line-JSON events over the process
    cluster's result plane.  ``apply_event`` rehydrates the handle from
    those events — per-token events carry an absolute index so replays
    (a dead worker's requests resumed on a survivor re-emit their
    stashed prefix) dedup instead of double-pushing, and the finish
    event closes the stream.  ``_engine`` then only needs an
    ``abort(req_id)`` method, which the front-end proxies to the
    owning worker.
    """

    def __init__(self, request: GenerationRequest, engine=None,
                 on_token: Optional[Callable[[int], None]] = None):
        self.request = request
        self.req_id = request.req_id
        self.on_token = on_token
        self.tokens: list[int] = []          # emitted so far
        self.logprobs: list[float] = []      # aligned with tokens
        self._engine = engine
        self._stream: queue_lib.Queue = queue_lib.Queue()
        self._done = threading.Event()
        self._output: Optional[RequestOutput] = None
        # latency bookkeeping (engine-loop clock, seconds)
        self.t_admit: Optional[float] = None
        self.t_first_token: Optional[float] = None
        self.t_last_token: Optional[float] = None
        # wall-clock submit time (monotonic): remote handles compute
        # TTFT/TPOT parent-side from event receipt times against this,
        # so the reported numbers include IPC, routing and any
        # failure-re-route delay — the honest end-to-end latency
        self.t_created: float = time.monotonic()

    # ------------------------------------------------------ engine side
    def _push(self, token: int, now: float,
              logprob: float = 0.0) -> None:
        if self.t_first_token is None:
            self.t_first_token = now
        self.t_last_token = now
        self.tokens.append(token)
        self.logprobs.append(float(logprob))
        self._stream.put(token)
        if self.on_token is not None:
            self.on_token(token)

    def _finish(self, finish_reason: str, now: float) -> RequestOutput:
        n = len(self.tokens)
        t_first = self.t_first_token
        t_last = self.t_last_token if self.t_last_token is not None else now
        arrival = self.request.arrival_s
        self._output = RequestOutput(
            req_id=self.req_id,
            tokens=np.asarray(self.tokens, np.int32),
            logprobs=(np.asarray(self.logprobs, np.float32)
                      if self.request.sampling.logprobs else None),
            finish_reason=finish_reason,
            queue_wait_s=max((self.t_admit if self.t_admit is not None
                              else now) - arrival, 0.0),
            ttft_s=max((t_first if t_first is not None else now) - arrival,
                       0.0),
            tpot_s=((t_last - t_first) / (n - 1)
                    if n > 1 and t_first is not None else 0.0),
        )
        self._done.set()
        self._stream.put(_SENTINEL)
        return self._output

    # ------------------------------------------------- remote (IPC) side
    def apply_event(self, ev: dict) -> None:
        """Rehydrate from one result-plane event (process cluster).

        ``token`` events: ``{"ev": "token", "i": abs_index, "t": token,
        "lp": logprob}`` — an index below the already-pushed count is a
        replay (resume-by-re-prefill after a worker failure re-emits
        the stashed prefix) and is dropped, which is exactly what makes
        re-routed streams byte-identical instead of duplicated.

        ``finish`` events carry the authoritative token/logprob lists
        (any tokens that beat the per-token events to the wire are
        pushed first), the finish reason and the WORKER-side queue
        wait; TTFT/TPOT are computed here from parent-side receipt
        times against ``t_created``."""
        if self._done.is_set():
            return                       # late event after abort/finish
        kind = ev.get("ev")
        now = time.monotonic()
        if kind == "token":
            if int(ev["i"]) < len(self.tokens):
                return                   # replayed prefix: already seen
            self._push(int(ev["t"]), now, float(ev.get("lp", 0.0)))
        elif kind == "finish":
            toks = [int(t) for t in ev.get("tokens", ())]
            lps = [float(x) for x in ev.get("logprobs", ())]
            if len(lps) != len(toks):
                lps = [0.0] * len(toks)
            for i in range(len(self.tokens), len(toks)):
                self._push(toks[i], now, lps[i])
            n = len(self.tokens)
            t_first = self.t_first_token
            t_last = (self.t_last_token
                      if self.t_last_token is not None else now)
            self._output = RequestOutput(
                req_id=self.req_id,
                tokens=np.asarray(self.tokens, np.int32),
                logprobs=(np.asarray(self.logprobs, np.float32)
                          if self.request.sampling.logprobs else None),
                finish_reason=ev["finish_reason"],
                queue_wait_s=float(ev.get("queue_wait_s", 0.0)),
                ttft_s=max((t_first if t_first is not None else now)
                           - self.t_created, 0.0),
                tpot_s=((t_last - t_first) / (n - 1)
                        if n > 1 and t_first is not None else 0.0),
            )
            self._done.set()
            self._stream.put(_SENTINEL)
        else:
            raise ValueError(f"unknown result-plane event {kind!r}")

    # ------------------------------------------------------ caller side
    @property
    def finished(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestOutput:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.req_id} not finished "
                               f"within {timeout}s")
        return self._output

    def abort(self) -> bool:
        if self._engine is None:
            return False
        return self._engine.abort(self.req_id)

    def __iter__(self):
        while True:
            tok = self._stream.get()
            if tok is _SENTINEL:
                # re-arm so a second iteration over a finished handle
                # terminates instead of blocking forever
                self._stream.put(_SENTINEL)
                return
            yield tok
