"""Per-request KV-cache slot management for continuous batching.

The engine owns one batched KV cache of fixed width ``max_slots`` (the
decode batch) and length ``max_seq``.  Each in-flight request occupies
one row ("slot"): admission writes its prefilled KV into the row,
decode steps advance the row's position independently of its
neighbours, and completion frees the row for the next arrival.

Stale KV beyond a slot's current position is never cleared: decode is
write-then-attend (the new token's KV lands at ``pos`` before any later
step reads it) and attention masks positions beyond ``pos``, so a fresh
request only ever reads positions its own prefill/decode wrote.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serve.scheduler import Request


@dataclasses.dataclass
class Slot:
    """One occupied row of the batched KV cache."""

    index: int                 # row in the batched cache
    request: Request
    pos: int                   # next cache write position (= tokens cached)
    last_token: int            # token to feed at the next decode step
    tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.request.max_new_tokens


class SlotManager:
    """Free-list of cache rows + the per-step index/token vectors."""

    def __init__(self, max_slots: int, max_seq: int):
        self.max_slots = max_slots
        self.max_seq = max_seq
        self._free: list[int] = list(range(max_slots))[::-1]  # pop() -> 0 first
        self.active: dict[int, Slot] = {}
        self.stats = {"admitted": 0, "released": 0, "peak_active": 0}
        self.slot_uses = [0] * max_slots

    def has_free(self) -> bool:
        return bool(self._free)

    def validate(self, request: Request) -> Request:
        """Reject a request that cannot fit one cache row (the engine
        calls this at submission so callers fail fast, before a prefill
        or a slot is spent on it)."""
        if request.prompt_len + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {request.req_id} needs "
                f"{request.prompt_len + request.max_new_tokens} positions, "
                f"cache rows hold {self.max_seq}")
        return request

    def admit(self, request: Request, first_token: int) -> Slot:
        """Claim a row for ``request`` whose prefill emitted ``first_token``."""
        if not self._free:
            raise RuntimeError("no free slot")
        self.validate(request)
        idx = self._free.pop()
        slot = Slot(index=idx, request=request, pos=request.prompt_len,
                    last_token=first_token, tokens=[first_token])
        self.active[idx] = slot
        self.slot_uses[idx] += 1
        self.stats["admitted"] += 1
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        len(self.active))
        return slot

    def release(self, slot: Slot) -> None:
        del self.active[slot.index]
        self._free.append(slot.index)
        self.stats["released"] += 1

    # ------------------------------------------------- per-step vectors
    def token_vector(self) -> np.ndarray:
        """(max_slots, 1) int32: each active slot's pending token."""
        toks = np.zeros((self.max_slots, 1), np.int32)
        for idx, slot in self.active.items():
            toks[idx, 0] = slot.last_token
        return toks

    def index_vector(self) -> np.ndarray:
        """(max_slots,) int32 per-row cache positions.  Inactive rows pin
        to 0: their junk write lands below any future request's prefill,
        which overwrites it (see module docstring)."""
        idx = np.zeros((self.max_slots,), np.int32)
        for i, slot in self.active.items():
            idx[i] = slot.pos
        return idx

    def active_slots(self) -> list[Slot]:
        return [self.active[i] for i in sorted(self.active)]
