"""Per-request KV-cache slot management for continuous batching.

Two cache layouts share one slot abstraction:

* ``SlotManager`` (dense): the engine owns one batched KV cache of
  fixed width ``max_slots`` (the decode batch) and length ``max_seq``.
  Each in-flight request occupies one row ("slot"): admission writes
  its prefilled KV into the row, decode steps advance the row's
  position independently of its neighbours, and completion frees the
  row for the next arrival.  A request reserves the FULL row for its
  lifetime, so capacity = max_slots regardless of actual lengths.

* ``PagedSlotManager`` (paged, vLLM-style): the cache is a pool of
  fixed-size blocks of ``block_size`` positions; each slot holds a
  *block table* mapping its logical positions [j*bs, (j+1)*bs) to a
  physical block.  Admission only needs the prompt's blocks, decode
  allocates one block at a time on demand, so capacity is bounded by
  the POOL (total positions in flight), not by rows x max_seq — short
  requests no longer reserve space they never use.

Stale KV beyond a slot's current position is never cleared: decode is
write-then-attend (the new token's KV lands at ``pos`` before any later
step reads it) and attention masks positions beyond ``pos``, so a fresh
request only ever reads positions its own prefill/decode wrote.  Under
paging, physical block 0 is reserved as the junk block: inactive decode
rows carry an all-zero block table and position 0, so their masked
writes land in block 0 and can never corrupt a live request's blocks.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.serve.api import GenerationRequest


@dataclasses.dataclass
class Slot:
    """One occupied row of the batched decode.

    Besides the KV-addressing state, a slot carries the request's RNG
    state implicitly (the sampling seed rides in ``request.sampling``;
    the per-token key is a pure function of (seed, position), so
    nothing mutable needs checkpointing across preemption) and the
    metrics timestamps of its CURRENT occupancy (engine-loop clock;
    the streaming handle keeps the across-preemption aggregate).
    """

    index: int                 # row in the batched cache / decode batch
    request: GenerationRequest
    pos: int                   # next cache write position (= tokens cached)
    last_token: int            # token to feed at the next decode step
    tokens: list[int] = dataclasses.field(default_factory=list)
    logprobs: list[float] = dataclasses.field(default_factory=list)
    blocks: list[int] = dataclasses.field(default_factory=list)  # paged only
    seq: int = 0               # admission order (preemption picks youngest)
    t_admit: float = 0.0       # when this occupancy was admitted
    t_last_token: float = 0.0  # when its latest token was sampled

    @property
    def done(self) -> bool:
        if len(self.tokens) >= self.request.max_new_tokens:
            return True
        return bool(self.tokens) and self.request.stops(self.tokens[-1])


class SlotManager:
    """Free-list of cache rows + the per-step index/token vectors."""

    def __init__(self, max_slots: int, max_seq: int):
        self.max_slots = max_slots
        self.max_seq = max_seq
        self._free: list[int] = list(range(max_slots))[::-1]  # pop() -> 0 first
        self.active: dict[int, Slot] = {}
        self._stats = {"admitted": 0, "released": 0, "peak_active": 0}
        self.slot_uses = [0] * max_slots
        self._seq = itertools.count()

    @property
    def stats(self) -> dict:
        """Counters plus live fragmentation accounting: ``reserved_positions``
        is what the active requests HOLD (dense: a full row each),
        ``used_positions`` what they have actually written — the gap is
        the waste paging exists to reclaim."""
        out = dict(self._stats)
        out.update(self.fragmentation())
        return out

    def fragmentation(self) -> dict:
        reserved = len(self.active) * self.max_seq
        used = sum(s.pos for s in self.active.values())
        return {"reserved_positions": reserved, "used_positions": used,
                "frag_positions": reserved - used}

    def has_free(self) -> bool:
        return bool(self._free)

    def validate(self, request: GenerationRequest) -> GenerationRequest:
        """Reject a request that cannot fit one cache row (the engine
        calls this at submission so callers fail fast, before a prefill
        or a slot is spent on it)."""
        if request.prompt_len + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {request.req_id} needs "
                f"{request.prompt_len + request.max_new_tokens} positions, "
                f"cache rows hold {self.max_seq}")
        return request

    def admit(self, request: GenerationRequest, first_token: int, *,
              blocks: list[int] | None = None,
              tokens: list[int] | None = None,
              logprobs: list[float] | None = None,
              first_logprob: float = 0.0,
              pos: int | None = None) -> Slot:
        """Claim a row for ``request`` whose prefill emitted ``first_token``
        (with chosen-token logprob ``first_logprob``).  ``tokens`` /
        ``logprobs`` / ``pos`` override the fresh-admission defaults when
        a preempted request resumes with generation already under way."""
        if not self._free:
            raise RuntimeError("no free slot")
        self.validate(request)
        idx = self._free.pop()
        slot = Slot(index=idx, request=request,
                    pos=request.prompt_len if pos is None else pos,
                    last_token=first_token,
                    tokens=[first_token] if tokens is None else list(tokens),
                    logprobs=([first_logprob] if logprobs is None
                              else list(logprobs)),
                    blocks=blocks or [], seq=next(self._seq))
        self.active[idx] = slot
        self.slot_uses[idx] += 1
        self._stats["admitted"] += 1
        self._stats["peak_active"] = max(self._stats["peak_active"],
                                         len(self.active))
        return slot

    def release(self, slot: Slot) -> None:
        del self.active[slot.index]
        self._free.append(slot.index)
        self._stats["released"] += 1

    # ------------------------------------------------- per-step vectors
    def token_vector(self) -> np.ndarray:
        """(max_slots, 1) int32: each active slot's pending token."""
        toks = np.zeros((self.max_slots, 1), np.int32)
        for idx, slot in self.active.items():
            toks[idx, 0] = slot.last_token
        return toks

    def index_vector(self) -> np.ndarray:
        """(max_slots,) int32 per-row cache positions.  Inactive rows pin
        to 0: their junk write lands below any future request's prefill
        (dense) or in the reserved junk block 0 (paged), which nothing
        ever reads (see module docstring)."""
        idx = np.zeros((self.max_slots,), np.int32)
        for i, slot in self.active.items():
            idx[i] = slot.pos
        return idx

    def sampling_vectors(self) -> dict[str, np.ndarray]:
        """(max_slots,)-vector sampling leaves for the in-graph sampler
        (models/sampling.sample_tokens): each active row carries its
        request's spec; inactive rows pin to greedy/neutral values (their
        sampled junk token is never read).  Always the same shapes and
        dtypes, so the decode step's compile signature is static across
        any request mix."""
        temp = np.zeros((self.max_slots,), np.float32)
        top_k = np.zeros((self.max_slots,), np.int32)
        top_p = np.ones((self.max_slots,), np.float32)
        seed = np.zeros((self.max_slots,), np.int32)
        for i, slot in self.active.items():
            sp = slot.request.sampling
            temp[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
            seed[i] = sp.seed
        return {"temperature": temp, "top_k": top_k, "top_p": top_p,
                "seed": seed}

    def active_slots(self) -> list[Slot]:
        return [self.active[i] for i in sorted(self.active)]


# ------------------------------------------------------------ paged layout

class BlockPool:
    """Free-list of fixed-size KV blocks.

    Manages physical block ids ``1..num_blocks``; id 0 is the reserved
    junk block (inactive decode rows write there — never allocated, never
    read).  The backing cache array therefore has ``num_blocks + 1``
    physical blocks; ``num_blocks * block_size`` is the usable capacity.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of one position")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(1, num_blocks + 1))[::-1]
        self.stats = {"allocated": 0, "freed": 0, "peak_in_use": 0}

    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: need {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self.stats["allocated"] += n
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                        self.blocks_in_use())
        return out

    def free(self, blocks: list[int]) -> None:
        self._free.extend(blocks)
        self.stats["freed"] += len(blocks)


class PagedSlotManager(SlotManager):
    """SlotManager over a BlockPool instead of full cache rows.

    ``max_seq`` bounds the block-TABLE width (the attention span a slot
    can reach, ``table_width * block_size`` positions); pass ``None`` to
    let a single request grow to the whole pool.  Admission and growth
    are pool-level: a request is admitted when its PROMPT blocks (plus a
    one-block watermark so in-flight slots can still grow) are free, and
    decode allocates one block at a time on demand — the engine preempts
    the youngest slot if the pool runs dry mid-decode.
    """

    def __init__(self, max_slots: int, block_size: int, num_blocks: int,
                 max_seq: int | None = None):
        self.pool = BlockPool(num_blocks, block_size)
        self.block_size = block_size
        if max_seq is None:
            self.table_width = num_blocks
        else:
            self.table_width = -(-max_seq // block_size)
        super().__init__(max_slots, self.table_width * block_size)
        self._stats["preempted"] = 0

    def blocks_for(self, n_positions: int) -> int:
        return -(-n_positions // self.block_size)

    def fragmentation(self) -> dict:
        """Internal fragmentation only: held blocks vs. written positions.
        (There is no external fragmentation — any free block serves any
        slot, tables need not be physically contiguous.)"""
        reserved = self.pool.blocks_in_use() * self.block_size
        used = sum(s.pos for s in self.active.values())
        return {"reserved_positions": reserved, "used_positions": used,
                "frag_positions": reserved - used}

    def validate(self, request: GenerationRequest) -> GenerationRequest:
        """Pool-level bound: the request's worst-case block count must fit
        the pool and the block table (NOT a per-row max_seq reservation —
        blocks are only taken as generation actually reaches them)."""
        total = self.blocks_for(request.prompt_len + request.max_new_tokens)
        limit = min(self.pool.num_blocks, self.table_width)
        if total > limit:
            raise ValueError(
                f"request {request.req_id} needs {total} blocks "
                f"({request.prompt_len + request.max_new_tokens} positions "
                f"/ {self.block_size}), pool+table allow {limit}")
        return request

    def can_admit(self, prefill_len: int, request: GenerationRequest) -> bool:
        """Block-exhaustion backpressure: admit when the prefill's blocks
        plus a one-block growth watermark are free.  Capped at the
        request's worst-case total so a pool-sized request is still
        admissible on an idle pool (no livelock)."""
        need = min(self.blocks_for(prefill_len) + 1,
                   self.blocks_for(request.prompt_len
                                   + request.max_new_tokens))
        return self.pool.free_blocks() >= need

    def needs_block(self, slot: Slot) -> bool:
        """True when the next decode write (at ``slot.pos``) falls in a
        block the slot does not hold yet."""
        return slot.pos // self.block_size >= len(slot.blocks)

    def release(self, slot: Slot) -> None:
        super().release(slot)
        self.pool.free(slot.blocks)
        slot.blocks = []

    def preempt(self, slot: Slot) -> None:
        """Release a slot mid-generation (pool pressure).  The engine
        stashes the generated tokens and requeues the request; resume
        re-prefills prompt+generated, so greedy output is unchanged."""
        self.release(slot)
        self._stats["preempted"] += 1
        self._stats["admitted"] -= 1     # resume will re-admit
        self._stats["released"] -= 1

    def block_table(self) -> np.ndarray:
        """(max_slots, table_width) int32 physical block ids.  Unassigned
        entries are 0 = the junk block: gathered but always masked (they
        only cover positions >= the slot's pos), and the only writes that
        target them are inactive rows' (index 0, table row 0)."""
        table = np.zeros((self.max_slots, self.table_width), np.int32)
        for i, slot in self.active.items():
            table[i, :len(slot.blocks)] = slot.blocks
        return table
