"""Per-request KV-cache slot management for continuous batching.

Two cache layouts share one slot abstraction:

* ``SlotManager`` (dense): the engine owns one batched KV cache of
  fixed width ``max_slots`` (the decode batch) and length ``max_seq``.
  Each in-flight request occupies one row ("slot"): admission writes
  its prefilled KV into the row, decode steps advance the row's
  position independently of its neighbours, and completion frees the
  row for the next arrival.  A request reserves the FULL row for its
  lifetime, so capacity = max_slots regardless of actual lengths.

* ``PagedSlotManager`` (paged, vLLM-style): the cache is a pool of
  fixed-size blocks of ``block_size`` positions; each slot holds a
  *block table* mapping its logical positions [j*bs, (j+1)*bs) to a
  physical block.  Admission only needs the prompt's blocks, decode
  allocates one block at a time on demand, so capacity is bounded by
  the POOL (total positions in flight), not by rows x max_seq — short
  requests no longer reserve space they never use.

Stale KV beyond a slot's current position is never cleared: decode is
write-then-attend (the new token's KV lands at ``pos`` before any later
step reads it) and attention masks positions beyond ``pos``, so a fresh
request only ever reads positions its own prefill/decode wrote.  Under
paging, physical block 0 is reserved as the junk block: inactive decode
rows carry an all-zero block table and position 0, so their masked
writes land in block 0 and can never corrupt a live request's blocks.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json

import numpy as np

from repro.serve.api import GenerationRequest


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype by name, covering the ml_dtypes extended floats
    (bfloat16 etc.) that ``np.dtype`` alone does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclasses.dataclass
class KVSpan:
    """A finished prefill's KV, lifted out of one engine's block pool.

    This is the disaggregation handoff unit: a prefill-role engine
    fills scratch blocks, gathers them into per-leaf ``(L, nblk,
    block_size, ...)`` arrays IN POOL DTYPE (int8 pools ship quantised
    codes + scale planes untouched, so the handoff adds zero rounding),
    and a decode-role engine scatters them into its own pool and starts
    decoding from ``first_token``.  ``to_bytes``/``from_bytes`` give a
    self-describing wire format (one JSON header line — scalars +
    per-leaf name/shape/dtype — then the raw leaf bytes) for the TCP
    control plane.
    """

    prompt: np.ndarray                 # (S,) int32
    first_token: int
    first_logprob: float
    block_size: int
    kv: dict[str, np.ndarray]          # leaf -> (L, nblk, block_size, ...)

    def to_bytes(self) -> bytes:
        prompt = np.ascontiguousarray(self.prompt, np.int32)
        names = sorted(self.kv)
        header = {
            "first_token": int(self.first_token),
            "first_logprob": float(self.first_logprob),
            "block_size": int(self.block_size),
            "prompt_len": int(prompt.shape[0]),
            "leaves": [[k, list(self.kv[k].shape), self.kv[k].dtype.name]
                       for k in names],
        }
        parts = [json.dumps(header).encode() + b"\n", prompt.tobytes()]
        parts += [np.ascontiguousarray(self.kv[k]).tobytes() for k in names]
        return b"".join(parts)

    @staticmethod
    def from_bytes(data: bytes) -> "KVSpan":
        nl = data.index(b"\n")
        header = json.loads(data[:nl].decode())
        off = nl + 1
        S = header["prompt_len"]
        prompt = np.frombuffer(data, np.int32, count=S, offset=off).copy()
        off += prompt.nbytes
        kv = {}
        for name, shape, dtype_name in header["leaves"]:
            dt = _np_dtype(dtype_name)
            n = int(np.prod(shape))
            kv[name] = np.frombuffer(
                data, dt, count=n, offset=off).reshape(shape).copy()
            off += n * dt.itemsize
        return KVSpan(prompt=prompt, first_token=header["first_token"],
                      first_logprob=header["first_logprob"],
                      block_size=header["block_size"], kv=kv)


def chain_hashes(tokens, block_size: int) -> list[int]:
    """Content hash chain over the FULL blocks of a token sequence.

    ``out[j] = hash((out[j-1], tuple(tokens[j*bs:(j+1)*bs])))`` — block j's
    key commits to every token before it, so two sequences share block j's
    hash iff they agree on the whole prefix ``[0, (j+1)*bs)``.  Only full
    blocks are keyed: a partial tail block is private by construction
    (its content is still growing).  This is the prefix-cache index key
    (vLLM-style hash-chain block keying)."""
    out: list[int] = []
    h = None
    for j in range(len(tokens) // block_size):
        blk = tuple(int(t) for t in tokens[j * block_size:(j + 1) * block_size])
        h = hash((h, blk))
        out.append(h)
    return out


@dataclasses.dataclass
class PartialPrefill:
    """Chunked-prefill progress of a slot that is not decode-ready yet.

    ``feed`` is the FULL admission feed (prompt, plus replayed generated
    tokens when a decode-preempted request resumes by re-prefill); the
    slot's ``pos`` tracks how many of its positions have KV in the pool.
    ``resume`` carries the stashed (tokens, logprobs) of that earlier
    decode preemption, if any — it must survive a SECOND preemption that
    lands mid-prefill, so the engine re-stashes it from here rather
    than from the slot's (still empty) token list.
    """

    feed: np.ndarray
    resume: tuple[list[int], list[float]] | None = None


@dataclasses.dataclass
class Slot:
    """One occupied row of the batched decode.

    Besides the KV-addressing state, a slot carries the request's RNG
    state implicitly (the sampling seed rides in ``request.sampling``;
    the per-token key is a pure function of (seed, position), so
    nothing mutable needs checkpointing across preemption) and the
    metrics timestamps of its CURRENT occupancy (engine-loop clock;
    the streaming handle keeps the across-preemption aggregate).

    A slot admitted under chunked prefill starts with ``prefill`` set
    (and ``tokens`` empty): it holds its blocks and is visible to
    preemption, but every per-step decode vector presents it as an
    inactive row until the final chunk samples its first token and
    clears ``prefill``.
    """

    index: int                 # row in the batched cache / decode batch
    request: GenerationRequest
    pos: int                   # next cache write position (= tokens cached)
    last_token: int            # token to feed at the next decode step
    tokens: list[int] = dataclasses.field(default_factory=list)
    logprobs: list[float] = dataclasses.field(default_factory=list)
    blocks: list[int] = dataclasses.field(default_factory=list)  # paged only
    # hash-chain keys of this slot's FULL blocks that are registered in
    # the pool's prefix index (paged + prefix_cache only); always a
    # prefix of ``blocks`` — the partial tail block is never keyed
    block_hashes: list[int] = dataclasses.field(default_factory=list)
    seq: int = 0               # admission order (preemption picks youngest)
    t_admit: float = 0.0       # when this occupancy was admitted
    t_last_token: float = 0.0  # when its latest token was sampled
    prefill: PartialPrefill | None = None   # chunked prefill in progress

    @property
    def prefilling(self) -> bool:
        return self.prefill is not None

    @property
    def done(self) -> bool:
        if self.prefilling:
            return False
        if len(self.tokens) >= self.request.max_new_tokens:
            return True
        return bool(self.tokens) and self.request.stops(self.tokens[-1])


class SlotManager:
    """Free-list of cache rows + the per-step index/token vectors."""

    def __init__(self, max_slots: int, max_seq: int):
        self.max_slots = max_slots
        self.max_seq = max_seq
        self._free: list[int] = list(range(max_slots))[::-1]  # pop() -> 0 first
        self.active: dict[int, Slot] = {}
        self._stats = {"admitted": 0, "released": 0, "peak_active": 0}
        self.slot_uses = [0] * max_slots
        self._seq = itertools.count()

    @property
    def stats(self) -> dict:
        """Counters plus live fragmentation accounting: ``reserved_positions``
        is what the active requests HOLD (dense: a full row each),
        ``used_positions`` what they have actually written — the gap is
        the waste paging exists to reclaim."""
        out = dict(self._stats)
        out.update(self.fragmentation())
        return out

    def fragmentation(self) -> dict:
        reserved = len(self.active) * self.max_seq
        used = sum(s.pos for s in self.active.values())
        return {"reserved_positions": reserved, "used_positions": used,
                "frag_positions": reserved - used}

    def has_free(self) -> bool:
        return bool(self._free)

    def validate(self, request: GenerationRequest) -> GenerationRequest:
        """Reject a request that cannot fit one cache row (the engine
        calls this at submission so callers fail fast, before a prefill
        or a slot is spent on it)."""
        if request.prompt_len + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {request.req_id} needs "
                f"{request.prompt_len + request.max_new_tokens} positions, "
                f"cache rows hold {self.max_seq}")
        return request

    def admit(self, request: GenerationRequest, first_token: int, *,
              blocks: list[int] | None = None,
              tokens: list[int] | None = None,
              logprobs: list[float] | None = None,
              first_logprob: float = 0.0,
              pos: int | None = None) -> Slot:
        """Claim a row for ``request`` whose prefill emitted ``first_token``
        (with chosen-token logprob ``first_logprob``).  ``tokens`` /
        ``logprobs`` / ``pos`` override the fresh-admission defaults when
        a preempted request resumes with generation already under way."""
        if not self._free:
            raise RuntimeError("no free slot")
        self.validate(request)
        idx = self._free.pop()
        slot = Slot(index=idx, request=request,
                    pos=request.prompt_len if pos is None else pos,
                    last_token=first_token,
                    tokens=[first_token] if tokens is None else list(tokens),
                    logprobs=([first_logprob] if logprobs is None
                              else list(logprobs)),
                    blocks=blocks or [], seq=next(self._seq))
        self.active[idx] = slot
        self.slot_uses[idx] += 1
        self._stats["admitted"] += 1
        self._stats["peak_active"] = max(self._stats["peak_active"],
                                         len(self.active))
        return slot

    def release(self, slot: Slot) -> None:
        del self.active[slot.index]
        self._free.append(slot.index)
        self._stats["released"] += 1

    # ------------------------------------------------- per-step vectors
    # Slots still mid-chunked-prefill present as INACTIVE rows in every
    # decode-step vector (token/index 0, greedy sampling, zero table):
    # their junk decode write lands in the junk block, and their real
    # state advances only through the chunk-prefill path.

    def token_vector(self) -> np.ndarray:
        """(max_slots, 1) int32: each active slot's pending token."""
        toks = np.zeros((self.max_slots, 1), np.int32)
        for idx, slot in self.active.items():
            if slot.prefilling:
                continue
            toks[idx, 0] = slot.last_token
        return toks

    def index_vector(self) -> np.ndarray:
        """(max_slots,) int32 per-row cache positions.  Inactive rows pin
        to 0: their junk write lands below any future request's prefill
        (dense) or in the reserved junk block 0 (paged), which nothing
        ever reads (see module docstring)."""
        idx = np.zeros((self.max_slots,), np.int32)
        for i, slot in self.active.items():
            if slot.prefilling:
                continue
            idx[i] = slot.pos
        return idx

    def sampling_vectors(self) -> dict[str, np.ndarray]:
        """(max_slots,)-vector sampling leaves for the in-graph sampler
        (models/sampling.sample_tokens): each active row carries its
        request's spec; inactive rows pin to greedy/neutral values (their
        sampled junk token is never read).  Always the same shapes and
        dtypes, so the decode step's compile signature is static across
        any request mix."""
        temp = np.zeros((self.max_slots,), np.float32)
        top_k = np.zeros((self.max_slots,), np.int32)
        top_p = np.ones((self.max_slots,), np.float32)
        seed = np.zeros((self.max_slots,), np.int32)
        for i, slot in self.active.items():
            if slot.prefilling:
                continue
            sp = slot.request.sampling
            temp[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
            seed[i] = sp.seed
        return {"temperature": temp, "top_k": top_k, "top_p": top_p,
                "seed": seed}

    def active_slots(self) -> list[Slot]:
        """Decode-ready slots (rows mid-chunked-prefill are excluded —
        the decode step must not append tokens to them)."""
        return [self.active[i] for i in sorted(self.active)
                if not self.active[i].prefilling]

    def prefilling_slots(self) -> list[Slot]:
        """Slots with a chunked prefill in flight, admission order."""
        return [self.active[i] for i in sorted(self.active)
                if self.active[i].prefilling]


# ------------------------------------------------------------ paged layout

class BlockPool:
    """Reference-counted free-list of fixed-size KV blocks.

    Manages physical block ids ``1..num_blocks``; id 0 is the reserved
    junk block (inactive decode rows write there — never allocated, never
    read).  The backing cache array therefore has ``num_blocks + 1``
    physical blocks; ``num_blocks * block_size`` is the usable capacity.

    Every allocated block carries a refcount: ``alloc`` hands out blocks
    at refcount 1, ``ref`` adds a holder (prefix sharing), and ``free``
    drops one reference per listed block.  A block whose refcount hits 0
    returns to the free list UNLESS it is registered in the content-hash
    index — then it parks in the CACHED set: its KV stays resident and a
    later ``match`` on its hash revives it for free, but it is evictable
    (LRU, least-recently-cached first) whenever ``alloc`` outruns the
    free list.  ``free_blocks()`` therefore counts free + cached: cached
    blocks are allocatable capacity, just lazily reclaimed.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of one position")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(1, num_blocks + 1))[::-1]
        self.refcount: dict[int, int] = {}
        self._hash_of: dict[int, int] = {}   # block id -> chain hash
        self._by_hash: dict[int, int] = {}   # chain hash -> block id
        self._cached: collections.OrderedDict[int, None] = \
            collections.OrderedDict()        # refcount-0 registered, LRU order
        self.stats = {"allocated": 0, "freed": 0, "peak_in_use": 0,
                      "cache_hits": 0, "evicted": 0}

    def free_blocks(self) -> int:
        """Allocatable blocks: the free list plus refcount-0 cached
        blocks (their KV is kept opportunistically; eviction is free)."""
        return len(self._free) + len(self._cached)

    def blocks_in_use(self) -> int:
        """Referenced (refcount >= 1) blocks — PHYSICAL, i.e. a block
        shared by N slots counts once."""
        return self.num_blocks - self.free_blocks()

    def cached_blocks(self) -> int:
        return len(self._cached)

    def is_cached(self, block: int) -> bool:
        """True for a refcount-0 block parked in the cached set (a match
        would revive it — consuming allocatable capacity — rather than
        share a live block for free)."""
        return block in self._cached

    def alloc(self, n: int = 1) -> list[int]:
        if n > self.free_blocks():
            raise RuntimeError(
                f"block pool exhausted: need {n}, have {self.free_blocks()}")
        out = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:
                # evict the least-recently-cached block: drop its hash
                # (its KV is about to be overwritten by the new owner)
                b, _ = self._cached.popitem(last=False)
                self._unregister(b)
                self.stats["evicted"] += 1
            self.refcount[b] = 1
            out.append(b)
        self.stats["allocated"] += n
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                        self.blocks_in_use())
        return out

    def free(self, blocks: list[int]) -> None:
        """Drop ONE reference per listed block.  A block reaching
        refcount 0 returns to the free list, or to the cached set when
        its content hash is registered (prefix cache keeps the KV warm).
        Raises ValueError on the reserved junk block 0, out-of-range
        ids, and double-frees — silent acceptance of those used to
        corrupt the free list (the same id handed to two slots)."""
        freed = 0
        for b in blocks:
            b = int(b)
            if b == 0:
                raise ValueError("cannot free the reserved junk block 0")
            if not 1 <= b <= self.num_blocks:
                raise ValueError(
                    f"block id {b} out of range 1..{self.num_blocks}")
            if self.refcount.get(b, 0) < 1:
                raise ValueError(f"double free of block {b} "
                                 "(refcount already 0)")
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                del self.refcount[b]
                freed += 1
                if b in self._hash_of:
                    self._cached[b] = None     # newest at the MRU end
                else:
                    self._free.append(b)
        self.stats["freed"] += freed

    def ref(self, block: int) -> None:
        """Add a holder to an allocated block (prefix sharing)."""
        if self.refcount.get(block, 0) < 1:
            raise ValueError(f"block {block} is not allocated")
        self.refcount[block] += 1

    # --------------------------------------------------- prefix hash index
    def lookup(self, h: int) -> int | None:
        """Block registered under hash ``h`` (live or cached), or None."""
        return self._by_hash.get(h)

    def match(self, h: int) -> int | None:
        """Claim a reference on the block registered under ``h``: a live
        shared block gains a holder, a cached one is revived (counted as
        an allocation — it leaves allocatable capacity).  None on miss."""
        b = self._by_hash.get(h)
        if b is None:
            return None
        if b in self._cached:
            del self._cached[b]
            self.refcount[b] = 1
            self.stats["allocated"] += 1
            self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                            self.blocks_in_use())
        else:
            self.refcount[b] += 1
        self.stats["cache_hits"] += 1
        return b

    def is_registered(self, block: int) -> bool:
        return block in self._hash_of

    def register(self, block: int, h: int) -> bool:
        """Key ``block`` under chain hash ``h``.  First writer wins: if
        ``h`` is already taken (two requests with the same prefix filled
        private blocks concurrently) or the block already has a hash,
        this is a no-op returning False — the duplicate block stays
        unregistered and is reclaimed normally when freed."""
        if h in self._by_hash or block in self._hash_of:
            return False
        self._hash_of[block] = h
        self._by_hash[h] = block
        return True

    def unregister(self, block: int) -> None:
        """Drop a block's hash-index entry (sole-owner in-place rewrite:
        the content is about to change, so the key would be stale).  An
        unregistered cached block is unreachable, so it goes straight
        back to the free list."""
        self._unregister(block)
        if block in self._cached:
            del self._cached[block]
            self._free.append(block)

    def _unregister(self, block: int) -> None:
        h = self._hash_of.pop(block, None)
        if h is not None:
            self._by_hash.pop(h, None)


class PagedSlotManager(SlotManager):
    """SlotManager over a BlockPool instead of full cache rows.

    ``max_seq`` bounds the block-TABLE width (the attention span a slot
    can reach, ``table_width * block_size`` positions); pass ``None`` to
    let a single request grow to the whole pool.  Admission and growth
    are pool-level: a request is admitted when its PROMPT blocks (plus a
    one-block growth watermark so in-flight slots can still grow) are
    free, and decode allocates one block at a time on demand — the
    engine preempts the youngest slot if the pool runs dry mid-decode.

    With ``prefix_cache=True``, admission first matches the prompt's
    full-block prefix against the pool's hash-chain index
    (``chain_hashes``): matched blocks are SHARED (refcounted) across
    slots, only the uncached tail is allocated, and the engine skips
    re-prefill of the matched span.  Shared blocks are immutable to
    their sharers — any write is gated by ``ensure_writable`` which
    forks the block copy-on-write first.
    """

    def __init__(self, max_slots: int, block_size: int, num_blocks: int,
                 max_seq: int | None = None, prefix_cache: bool = False):
        self.pool = BlockPool(num_blocks, block_size)
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        if max_seq is None:
            self.table_width = num_blocks
        else:
            self.table_width = -(-max_seq // block_size)
        super().__init__(max_slots, self.table_width * block_size)
        self._stats["preempted"] = 0
        self._stats["cow_forks"] = 0
        self._stats["prefix_block_hits"] = 0

    def blocks_for(self, n_positions: int) -> int:
        return -(-n_positions // self.block_size)

    def fragmentation(self) -> dict:
        """Internal fragmentation only: held blocks vs. written positions.
        (There is no external fragmentation — any free block serves any
        slot, tables need not be physically contiguous.)

        Blocks are counted PHYSICALLY (deduped): a block shared by N
        slots contributes block_size positions once to
        ``reserved_positions``, and its extra N-1 logical appearances
        are reported as ``shared_positions`` — the naive per-slot sum
        used to double-count them once prefix sharing landed.  Slot
        positions (``used_positions``) stay logical, so
        ``frag_positions = reserved + shared - used`` remains the true
        held-but-unwritten gap and degenerates to the old
        ``reserved - used`` when nothing is shared."""
        physical = self.pool.blocks_in_use()
        logical = sum(len(s.blocks) for s in self.active.values())
        reserved = physical * self.block_size
        shared = (logical - physical) * self.block_size
        used = sum(s.pos for s in self.active.values())
        return {"reserved_positions": reserved, "used_positions": used,
                "shared_positions": shared,
                "frag_positions": reserved + shared - used,
                "cached_blocks": self.pool.cached_blocks()}

    def validate(self, request: GenerationRequest) -> GenerationRequest:
        """Pool-level bound: the request's worst-case block count must fit
        the pool and the block table (NOT a per-row max_seq reservation —
        blocks are only taken as generation actually reaches them)."""
        total = self.blocks_for(request.prompt_len + request.max_new_tokens)
        limit = min(self.pool.num_blocks, self.table_width)
        if total > limit:
            raise ValueError(
                f"request {request.req_id} needs {total} blocks "
                f"({request.prompt_len + request.max_new_tokens} positions "
                f"/ {self.block_size}), pool+table allow {limit}")
        return request

    def can_admit(self, prefill_len: int, request: GenerationRequest,
                  feed=None) -> bool:
        """Block-exhaustion backpressure: admit when the prefill's NEW
        blocks plus a one-block growth watermark are free.  Capped at
        the request's worst-case total so a pool-sized request is still
        admissible on an idle pool (no livelock).

        With the prefix cache on, pass the actual ``feed`` tokens: the
        accounting is exact — LIVE matched blocks (another slot holds
        them) cost nothing, matched blocks in the cached set will be
        REVIVED (each consumes one unit of allocatable capacity, since
        ``free_blocks()`` still counts them), the uncached span needs
        fresh blocks, and a fully-cached feed whose tail block is live
        needs one more for the copy-on-write fork (a revived tail is
        sole-owned and rewritten in place instead)."""
        total = self.blocks_for(request.prompt_len + request.max_new_tokens)
        if feed is not None and self.prefix_cache:
            prefill_len = len(feed)
            revived = live = 0
            tail_live = False
            for h in chain_hashes(feed, self.block_size):
                b = self.pool.lookup(h)
                if b is None:
                    break
                if self.pool.is_cached(b):
                    revived += 1
                    tail_live = False
                else:
                    live += 1
                    tail_live = True
            matched = revived + live
            fresh = self.blocks_for(prefill_len) - matched
            fully_cached = matched * self.block_size >= prefill_len
            fork = 1 if (fully_cached and tail_live) else 0
            need_cap = fresh + revived + fork
            need = min(need_cap + 1, max(need_cap, total - live))
            return self.pool.free_blocks() >= need
        need = min(self.blocks_for(prefill_len) + 1, total)
        return self.pool.free_blocks() >= need

    def needs_block(self, slot: Slot) -> bool:
        """True when the next decode write (at ``slot.pos``) falls in a
        block the slot does not hold yet."""
        return slot.pos // self.block_size >= len(slot.blocks)

    def fanout_blocks(self, slot: Slot, n_positions: int) -> int:
        """Blocks the slot must ADD so positions
        ``[pos, pos + n_positions)`` are all backed — the speculative
        fan-out reservation: a spec round may write up to k candidate
        positions of KV before knowing how many survive verification.
        The engine allocates these onto ``slot.blocks`` BEFORE the
        round; rejected tails simply leave the last block(s) partly
        unwritten (stale-beyond-pos, masked like any other), so
        rollback never frees — and can never corrupt — shared or
        prefix-cached blocks."""
        return max(self.blocks_for(slot.pos + n_positions)
                   - len(slot.blocks), 0)

    # ------------------------------------------------------ prefix caching
    def matchable_blocks(self, tokens) -> int:
        """Non-mutating probe: how many consecutive full blocks of
        ``tokens`` are resident in the hash index right now."""
        if not self.prefix_cache:
            return 0
        n = 0
        for h in chain_hashes(tokens, self.block_size):
            if self.pool.lookup(h) is None:
                break
            n += 1
        return n

    def match_prefix(self, tokens) -> tuple[list[int], list[int]]:
        """Claim the longest cached full-block prefix of ``tokens``:
        each hit takes a reference on the physical block (reviving it
        from the cached set if no live slot holds it).  Returns
        (blocks, hashes); stops at the first miss — hash-chain keying
        means later blocks cannot match once one misses."""
        blocks: list[int] = []
        hashes: list[int] = []
        if not self.prefix_cache:
            return blocks, hashes
        for h in chain_hashes(tokens, self.block_size):
            b = self.pool.match(h)
            if b is None:
                break
            blocks.append(b)
            hashes.append(h)
        self._stats["prefix_block_hits"] += len(blocks)
        return blocks, hashes

    def ensure_writable(self, blocks: list[int],
                        blk_idx: int) -> tuple[list[int], tuple | None]:
        """Copy-on-write gate before any KV write into ``blocks[blk_idx]``.

        Shared (refcount > 1): allocate a private block, hand back our
        reference on the shared one, and return the updated table plus a
        ``(src, dst)`` physical copy pair — the CALLER must copy the
        pool data (the manager only does accounting).  Sole-owner but
        hash-registered: the content is about to diverge from its key,
        so drop the index entry and write in place.  Private: no-op."""
        b = blocks[blk_idx]
        if self.pool.refcount.get(b, 0) > 1:
            [new] = self.pool.alloc(1)
            self.pool.free([b])          # our ref only; sharers keep it
            blocks = list(blocks)
            blocks[blk_idx] = new
            self._stats["cow_forks"] += 1
            return blocks, (b, new)
        if self.pool.is_registered(b):
            self.pool.unregister(b)
        return blocks, None

    def register_full_blocks(self, slot: Slot, kv_tokens) -> None:
        """Extend ``slot.block_hashes`` over newly-FULL blocks and key
        them in the pool's hash index.  ``kv_tokens`` is the token
        sequence whose KV the slot's blocks hold (prompt + generated so
        far); called after admission's scatter and whenever decode fills
        a block.  First-writer-wins on hash collisions with concurrent
        private fills (``BlockPool.register``)."""
        if not self.prefix_cache:
            return
        bs = self.block_size
        n_full = min(len(kv_tokens) // bs, len(slot.blocks))
        h = slot.block_hashes[-1] if slot.block_hashes else None
        for j in range(len(slot.block_hashes), n_full):
            blk = tuple(int(t) for t in kv_tokens[j * bs:(j + 1) * bs])
            h = hash((h, blk))
            slot.block_hashes.append(h)
            self.pool.register(slot.blocks[j], h)

    def release(self, slot: Slot) -> None:
        super().release(slot)
        self.pool.free(slot.blocks)
        slot.blocks = []
        slot.block_hashes = []

    def preempt(self, slot: Slot) -> None:
        """Release a slot mid-generation (pool pressure).  The engine
        stashes the generated tokens and requeues the request; resume
        re-prefills prompt+generated, so greedy output is unchanged."""
        self.release(slot)
        self._stats["preempted"] += 1
        self._stats["admitted"] -= 1     # resume will re-admit
        self._stats["released"] -= 1

    def block_table(self) -> np.ndarray:
        """(max_slots, table_width) int32 physical block ids.  Unassigned
        entries are 0 = the junk block: gathered but always masked (they
        only cover positions >= the slot's pos), and the only writes that
        target them are inactive rows' (index 0, table row 0)."""
        table = np.zeros((self.max_slots, self.table_width), np.int32)
        for i, slot in self.active.items():
            if slot.prefilling:
                continue
            table[i, :len(slot.blocks)] = slot.blocks
        return table
