"""Speculative decoding support: draft-model plumbing + acceptance rule.

Speculative decoding runs TWO cooperating functions per scheduler round
instead of one: a cheap DRAFT model proposes k tokens per slot via k
chained decode steps (one fused dispatch —
``Model.decode_draft``), and the TARGET model verifies all k in ONE
batched multi-token step (``Model.decode_verify``, whose attention is
chunk-prefill-at-offset over the paged pool).  In Xar-Trek terms this
is the first workload where the runtime keeps two registered binaries
BUSY AT ONCE on different targets — the headline configuration is
draft-on-HOST / verify-on-ACCEL, with the scheduling policy free to
migrate either and to shrink the draft length k under load
(``SchedulingPolicy.draft_len``).

Correctness contract (the repo's standing invariant): the verify pass
samples every candidate position with the exact ``fold_in(seed,
position)`` key sequential decode would use, and the engine emits the
longest drafted prefix that MATCHES verify's own samples plus verify's
first divergent token.  Emitted tokens are therefore *verify's* tokens,
always — the draft only decides how many arrive per dispatch.  GREEDY
output is byte-identical to non-speculative greedy on every target,
across migration and preempt/resume (argmax is insensitive to the
~1-ulp reduction-order differences between the decode and verify
attention paths).  Seeded SAMPLED output is byte-identical across
targets / migration / preempt-resume for a FIXED spec configuration
(every comparand commits verify's draws under the same positional
keys); against non-speculative sampling it agrees except where those
ulp-level logit differences flip a draw sitting exactly on a
categorical threshold — greedy is the identity the acceptance rule
guarantees unconditionally.

The DRAFT model here is a layer-truncated share of the target: the
first ``num_layers`` layer slices of the target's stacked parameters
plus its embedding/head (``share_draft_params``), under a config with a
full-precision dense KV scratch cache (``draft_model_config``).  That
keeps the subsystem dependency-free (no second checkpoint), makes the
draft a genuinely cheaper function of the SAME weights, and gives
benchmarks a dial: ``zero_top_layers`` zeroes the target's top layers
(each zeroed layer is an exact residual identity — every contribution
is multiplied to 0.0 before being added), making the truncated draft
*exactly* equal to the target so the acceptance rate approaches 1 and
the speedup bound ~k-per-2-dispatches is observable on random weights.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax

from repro.configs.model_config import ModelConfig


def draft_model_config(cfg: ModelConfig,
                       num_layers: int | None = None) -> ModelConfig:
    """Config for the layer-truncated draft of ``cfg``.

    ``num_layers`` defaults to half the target depth (min 1).  The
    draft's KV cache is a throwaway dense scratch, so it always stores
    full precision (``kv_cache_dtype = dtype``) regardless of the
    target's pool dtype: a lossy draft cache would only lower the
    acceptance rate, never improve anything — and a dense int8 cache
    would pin the ACCEL draft build to XLA math (see models/transformer
    decode), whereas the f32/bf16 dense path is a real Pallas
    flash-decode build.
    """
    depth = (max(1, cfg.num_layers // 2) if num_layers is None
             else num_layers)
    if not 1 <= depth <= cfg.num_layers:
        raise ValueError(
            f"draft depth {depth} outside 1..{cfg.num_layers}")
    return dataclasses.replace(
        cfg, name=cfg.name + "-draft", num_layers=depth,
        kv_cache_dtype=cfg.dtype)


def share_draft_params(params: dict, num_layers: int) -> dict:
    """Draft parameters as views of the target's: slice the first
    ``num_layers`` entries of every stacked layer leaf and share the
    embedding / final norm / head verbatim.  No copy of the big leaves
    is made until jax stages them (and then only the slices)."""
    out = dict(params)
    out["layers"] = jax.tree_util.tree_map(
        lambda x: x[:num_layers], params["layers"])
    return out


def zero_top_layers(params: dict, keep: int) -> dict:
    """Zero every layer-stacked leaf at layer index >= ``keep``.

    A fully-zeroed transformer layer is an EXACT residual identity:
    ln1 = 0 makes the attention input 0, wq/wk/wv = 0 make q/k/v 0, so
    the attention output is 0 before wo even applies; ln2 = 0 and zero
    MLP weights make the MLP branch 0; both residual adds contribute
    exact +0.0.  A target checkpoint passed through this with
    ``keep = draft depth`` therefore computes the identical function
    to its ``share_draft_params`` draft — the benchmark's near-1
    acceptance configuration on random weights.
    """
    leaf = jax.tree_util.tree_leaves(params["layers"])[0]
    L = leaf.shape[0]
    mask = np.arange(L) < keep

    def z(x):
        m = mask.reshape((L,) + (1,) * (x.ndim - 1))
        return x * m.astype(x.dtype)

    out = dict(params)
    out["layers"] = jax.tree_util.tree_map(z, params["layers"])
    return out


def acceptance_lengths(drafts: np.ndarray, verify: np.ndarray,
                       n_valid: np.ndarray) -> list[int]:
    """Per-row emit counts under longest-accepted-prefix acceptance.

    ``drafts`` (B, W-1): drafted tokens d_1..d_{W-1} (column j proposes
    the token at committed-position + j + 1).  ``verify`` (B, W):
    verify's own samples g_1..g_W (g_{j+1} sampled from the target's
    logits at the same position).  ``n_valid`` (B,): how many fed
    columns were real for the row (<= W; 0 marks an inactive row).

    Row b accepts the longest prefix a with ``drafts[b, i] ==
    verify[b, i]`` for all i < a (a <= n_valid - 1), then emits
    ``a + 1`` tokens: the a accepted ones plus verify's token at the
    first unconfirmed position — exactly the tokens sequential decode
    would have produced, which is the whole byte-identity argument.
    Inactive rows emit 0.
    """
    out = []
    for b in range(drafts.shape[0]):
        n = int(n_valid[b])
        if n <= 0:
            out.append(0)
            continue
        a = 0
        while a < n - 1 and int(drafts[b, a]) == int(verify[b, a]):
            a += 1
        out.append(a + 1)
    return out


@dataclasses.dataclass
class SpecDecoder:
    """Per-engine speculative-decoding state the serve engine composes.

    Holds the draft side (model / cfg / params / dense scratch cache)
    plus the per-slot draft-cache fingerprints: the draft cache row of
    slot i is valid for positions ``< pos`` iff ``fingerprints[i] ==
    (req_id, pos)`` — on mismatch (fresh admission, preempt/resume,
    rounds the slot sat out) the engine lazily re-prefills the row from
    the slot's committed tokens before drafting.  Positions at or past
    the fingerprint's ``pos`` may hold stale junk from earlier rounds;
    that is safe because chain step i at position ``pos + i`` only
    attends positions below itself, all either < pos (valid by the
    fingerprint) or written earlier in the same chain.
    """

    model: object
    cfg: ModelConfig
    params: dict
    cache: dict
    draft_len: int
    fingerprints: dict[int, tuple] = dataclasses.field(default_factory=dict)

    def valid_for(self, index: int, req_id: str, pos: int) -> bool:
        return self.fingerprints.get(index) == (req_id, pos)

    def mark(self, index: int, req_id: str, pos: int) -> None:
        self.fingerprints[index] = (req_id, pos)

    def invalidate(self, index: int) -> None:
        self.fingerprints.pop(index, None)
