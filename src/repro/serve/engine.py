"""Batched serving engine: prefill + decode with a managed KV cache.

The decode step is greedy (argmax) over the batch; generation runs
position-synchronised (all requests share the prompt length after left
padding is applied by the caller — a continuous-batching scheduler is a
further production feature, out of the paper's scope).

Xar-Trek integration: ``ServeEngine`` can dispatch its prefill/decode
steps through an XarTrekRuntime so the scheduler migrates them between
targets as load changes (the Figure-6 throughput experiment's analogue).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.model_config import ModelConfig
from repro.core.runtime import XarTrekRuntime
from repro.models.model import Model, build_model


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_generated)
    prefill_ms: float
    decode_ms: float

    @property
    def tokens_per_second(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return n / max((self.prefill_ms + self.decode_ms) / 1e3, 1e-9)


class ServeEngine:
    def __init__(self, cfg: ModelConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 params=None, seed: int = 0,
                 runtime: Optional[XarTrekRuntime] = None):
        self.cfg = cfg
        self.model = build_model(cfg, mesh)
        self.mesh = mesh
        self.runtime = runtime
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = params
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def _sample(self, logits: jax.Array) -> jax.Array:
        """Greedy over the last position.  logits: (B,1,V) or (B,1,K,V)."""
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def generate(self, prompts: jax.Array, max_new_tokens: int = 16,
                 patch_embeds: Optional[jax.Array] = None
                 ) -> GenerationResult:
        """prompts: (B, S) int32 (or (B, K, S) for audio)."""
        cfg = self.cfg
        audio = cfg.family == "audio" and cfg.num_codebooks > 1
        B = prompts.shape[0]
        S = prompts.shape[-1]
        max_seq = S + max_new_tokens

        batch = {"tokens": prompts}
        if patch_embeds is not None:
            batch["patch_embeds"] = patch_embeds

        t0 = time.perf_counter()
        if self.runtime is not None and "serve_prefill" in self.runtime.binaries:
            logits, cache = self.runtime.call("serve_prefill", self.params,
                                              batch)
        else:
            logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        # grow the cache to max_seq (prefill cache covers the prompt only)
        cache = self._grow_cache(cache, B, max_seq, S)

        out_tokens = []
        t0 = time.perf_counter()
        tok = self._sample(logits[:, -1:])               # (B,1) or (B,1,K)
        for i in range(max_new_tokens):
            out_tokens.append(np.asarray(tok).reshape(B, -1))
            dec_batch = {
                "tokens": (jnp.moveaxis(tok, -1, 1) if audio else tok),
                "index": jnp.int32(S + i),
            }
            if self.runtime is not None and "serve_decode" in self.runtime.binaries:
                logits, cache = self.runtime.call("serve_decode", self.params,
                                                  cache, dec_batch)
            else:
                logits, cache = self._decode(self.params, cache, dec_batch)
            tok = self._sample(logits[:, -1:])
        jax.block_until_ready(tok)
        decode_ms = (time.perf_counter() - t0) * 1e3
        return GenerationResult(np.stack(out_tokens, axis=1).squeeze(-1)
                                if not audio else np.stack(out_tokens, 1),
                                prefill_ms, decode_ms)

    def _grow_cache(self, cache: dict, batch: int, max_seq: int,
                    prompt_len: int) -> dict:
        full = self.model.init_cache(batch, max_seq)
        for k in full:
            if k in ("k", "v", "k_scale", "v_scale", "attn_k", "attn_v"):
                full[k] = jax.lax.dynamic_update_slice(
                    full[k], cache[k].astype(full[k].dtype),
                    (0,) * full[k].ndim)
            else:
                full[k] = cache[k].astype(full[k].dtype)
        return full
