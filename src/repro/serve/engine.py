"""Serving engines: synchronous batch and continuous batching.

``ServeEngine`` is the position-synchronised baseline: one batch of
equal-length (caller-left-padded) prompts runs prefill + max_new_tokens
decode steps in lockstep.

``ContinuousBatchingEngine`` serves a ragged arrival stream: requests
are admitted at arbitrary times into per-request KV-cache slots,
prefill of new arrivals interleaves with decode of in-flight ones, and
finished slots are evicted and reused immediately (no head-of-line
blocking on batch formation or on the batch's slowest request).

Serve API v2 (see ``serve.api``): requests are ``GenerationRequest``s
carrying a per-request ``SamplingParams``; ``submit`` returns a
streaming ``RequestHandle`` (iterate / ``on_token`` callback /
``result()``), ``abort`` cancels mid-stream, and completed requests
come back as ``RequestOutput`` (tokens + finish reason + queue-wait /
TTFT / TPOT metrics).  Sampling runs IN-GRAPH inside the jitted step
functions (``models/sampling.py``): the decode step takes (B,)
temperature/top_k/top_p/seed vectors and returns sampled tokens, so the
compile signature is static across any request mix and the sampling
math is identical on every backend.

Xar-Trek integration: both engines can dispatch every prefill/decode
step through an XarTrekRuntime so the scheduler (Algorithm 2) migrates
steps between HOST/AUX/ACCEL as load changes — the Figure-6 throughput
experiment's analogue, with the continuous engine playing the
multi-tenant arrival stream.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
import warnings
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.model_config import ModelConfig
from repro.core.function import MigratableFunction
from repro.core.policy import (
    LoadSignals, PinAccel, PinHost, SchedulingPolicy, ewma, resolve_policy,
)
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.models.model import build_model
from repro.models.sampling import sampling_leaves
from repro.serve.api import (
    FINISH_ABORTED, FINISH_LENGTH, FINISH_STOP, GenerationRequest,
    RequestHandle, RequestOutput, SamplingParams,
)
from repro.serve.batch import (
    KVSpan, PagedSlotManager, PartialPrefill, Slot, SlotManager,
)
from repro.serve.scheduler import RequestQueue
from repro.serve import spec as spec_lib

_BACKEND_DEPRECATION_WARNED = False
_ON_STEP_DEPRECATION_WARNED = False


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_generated)
    prefill_ms: float
    decode_ms: float

    @property
    def tokens_per_second(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return n / max((self.prefill_ms + self.decode_ms) / 1e3, 1e-9)


class ServeEngine:
    def __init__(self, cfg: ModelConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 params=None, seed: int = 0,
                 runtime: Optional[XarTrekRuntime] = None):
        self.cfg = cfg
        self.model = build_model(cfg, mesh)
        self.mesh = mesh
        self.runtime = runtime
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = params
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def _sample(self, logits: jax.Array) -> jax.Array:
        """Greedy over the last position.  logits: (B,1,V) or (B,1,K,V)."""
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def generate(self, prompts: jax.Array, max_new_tokens: int = 16,
                 patch_embeds: Optional[jax.Array] = None
                 ) -> GenerationResult:
        """prompts: (B, S) int32 (or (B, K, S) for audio)."""
        cfg = self.cfg
        audio = cfg.family == "audio" and cfg.num_codebooks > 1
        B = prompts.shape[0]
        S = prompts.shape[-1]
        max_seq = S + max_new_tokens

        batch = {"tokens": prompts}
        if patch_embeds is not None:
            batch["patch_embeds"] = patch_embeds

        t0 = time.perf_counter()
        if self.runtime is not None and "serve_prefill" in self.runtime.binaries:
            logits, cache = self.runtime.call("serve_prefill", self.params,
                                              batch)
        else:
            logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        # grow the cache to max_seq (prefill cache covers the prompt only)
        cache = self._grow_cache(cache, B, max_seq, S)

        out_tokens = []
        t0 = time.perf_counter()
        tok = self._sample(logits[:, -1:])               # (B,1) or (B,1,K)
        for i in range(max_new_tokens):
            out_tokens.append(np.asarray(tok).reshape(B, -1))
            dec_batch = {
                "tokens": (jnp.moveaxis(tok, -1, 1) if audio else tok),
                "index": jnp.int32(S + i),
            }
            if self.runtime is not None and "serve_decode" in self.runtime.binaries:
                logits, cache = self.runtime.call("serve_decode", self.params,
                                                  cache, dec_batch)
            else:
                logits, cache = self._decode(self.params, cache, dec_batch)
            tok = self._sample(logits[:, -1:])
        jax.block_until_ready(tok)
        decode_ms = (time.perf_counter() - t0) * 1e3
        return GenerationResult(np.stack(out_tokens, axis=1).squeeze(-1)
                                if not audio else np.stack(out_tokens, 1),
                                prefill_ms, decode_ms)

    def _grow_cache(self, cache: dict, batch: int, max_seq: int,
                    prompt_len: int) -> dict:
        full = self.model.init_cache(batch, max_seq)
        for k in full:
            if k in ("k", "v", "k_scale", "v_scale", "attn_k", "attn_v"):
                full[k] = jax.lax.dynamic_update_slice(
                    full[k], cache[k].astype(full[k].dtype),
                    (0,) * full[k].ndim)
            else:
                full[k] = cache[k].astype(full[k].dtype)
        return full


# ------------------------------------------------------ continuous batching

def prompt_bucket(n: int, min_bucket: int = 8) -> int:
    """Next power-of-two prefill width >= n (bounds recompiles to
    O(log max_prompt) shape buckets)."""
    b = min_bucket
    while b < n:
        b *= 2
    return b


def spec_verify_batch(vb: dict) -> dict:
    """Assemble the multi-token verify batch IN-GRAPH from the draft
    chain's device-resident output, so the round needs no host sync
    between the two dispatches.  ``vb`` carries ``drafts`` (B, W) as
    produced by the chain plus the SAME ``last``/``pos`` vectors the
    chain consumed; row i's verify feed is [last_i, d_1..d_{n-1}] at
    offset pos_i — bitwise the batch the host loop used to build, with
    inactive rows (n_valid == 0) masked to the junk self-attention at
    offset 0 whose writes land in junk block 0."""
    vb = dict(vb)
    drafts = vb.pop("drafts")
    last, pos = vb.pop("last"), vb.pop("pos")
    n = vb["n_valid"]
    W = drafts.shape[1]
    cols = jnp.arange(W, dtype=jnp.int32)[None, :]
    # last rides as the (B, 1) token_vector the CHAIN consumed — the
    # column concatenates directly, no per-round slice dispatch
    toks = jnp.concatenate([last, drafts[:, :W - 1]], axis=1)
    keep = (n[:, None] > 0) & (cols < n[:, None])
    vb["tokens"] = jnp.where(keep, toks, 0).astype(jnp.int32)
    vb["offset"] = jnp.where(n > 0, pos, 0).astype(jnp.int32)
    vb["length"] = jnp.where(n > 0, pos + n, W).astype(jnp.int32)
    return vb


# cache dtypes that represent every value of the compute dtype exactly
# (f32 is a strict superset of bf16/f16; same-dtype is trivially exact)
_KV_WIDENING = {("bfloat16", "float32"), ("float16", "float32")}


def kv_cache_lossless(cfg) -> bool:
    """True when storing compute-dtype KV in ``kv_cache_dtype`` is exact.

    The byte-identity invariant (greedy tokens bitwise equal cache-on vs
    cache-off, across HOST/ACCEL/migration/preempt-resume) holds only
    for lossless pools: a lossy pool makes cache-on read ROUNDED prefix
    KV where cache-off attends the in-flight full-precision values.
    int8 is always lossy; a narrower float pool (f32 compute over bf16
    cache) is too.
    """
    kv = cfg.kv_cache_dtype
    if kv == "int8":
        return False
    return kv == cfg.dtype or (cfg.dtype, kv) in _KV_WIDENING


class ContinuousBatchingEngine:
    """Slot-based continuous batching over one shared KV cache.

    ``max_slots`` is the decode width (rows of the batched cache);
    ``max_seq`` bounds prompt + generation length per slot.  Requests
    arrive through ``submit``/``run``; each engine loop iteration
    admits arrived requests into free slots (one bucketed prefill each)
    and then advances every in-flight request by one token (one ragged
    decode across all slots, per-row cache positions).

    **Serve API v2.**  ``submit(prompt_or_request, ...)`` returns a
    ``RequestHandle``: iterate it (from another thread while ``run()``
    drains) or attach ``on_token`` to stream tokens as they are
    sampled; ``handle.result()`` / the dict ``run()`` returns carry
    ``RequestOutput`` (tokens, finish_reason stop|length|aborted, and
    queue-wait/TTFT/TPOT metrics).  ``abort(req_id)`` cancels a queued
    or in-flight request: its slot — and, under paging, its KV blocks —
    free the same loop iteration.  The v1 surface (``serve()``,
    ``scheduler.Request``) is gone: both raise with a pointer at the
    v2 replacement.  ``SamplingParams(logprobs=True)`` additionally
    surfaces each token's chosen-token logprob in
    ``RequestOutput.logprobs``.

    **In-graph sampling.**  Each request's ``SamplingParams``
    (temperature/top_k/top_p/seed; temperature 0.0 = greedy) ride the
    step batch as (B,) vectors and the jitted step returns sampled
    tokens — one static decode signature for any request mix (no
    per-request recompiles), and byte-identical seeded outputs across
    HOST/ACCEL backends, forced mid-stream migration, and
    preempt/resume (the per-token PRNG key is
    ``fold_in(PRNGKey(seed), absolute_position)``; resume replays
    stashed tokens, so only the KV is rebuilt).

    With ``paged=True`` the dense per-slot rows are replaced by a
    vLLM-style block pool (``block_size`` positions per block,
    ``num_blocks`` usable blocks — default: the dense engine's memory
    footprint).  Admission needs only the prompt's blocks (plus a
    one-block watermark), decode allocates blocks on demand, and the
    youngest slot is preempted-and-resumed if the pool runs dry — so
    concurrency is bounded by tokens actually in flight, not by
    ``max_slots x max_seq`` reservations.  ``lane_align`` (default:
    auto — on for native TPU, off in interpret mode) pads the pool's
    head_dim to the TPU lane width at allocation so the ACCEL paged
    kernel never copies the pool to pad it per call.

    **Prefix caching** (``prefix_cache=True``, paged only): admission
    matches the feed's full-block prefix against the pool's hash-chain
    index (``serve/batch.chain_hashes``); matched blocks are SHARED
    (refcounted) and only the uncached span is prefetched — a chunked
    ``prefill_ctx`` attends over the cached context and the engine
    scatters just the chunk's KV into freshly-allocated private blocks.
    Any write into a shared block forks it copy-on-write first; blocks
    whose last reference drops park in an evictable cached set (LRU)
    instead of freeing, so a later request with the same prefix revives
    them for free.  Greedy output is byte-identical cache-on vs
    cache-off on both backends (the cached KV is bitwise what a fresh
    prefill would recompute, and masked junk positions contribute exact
    zeros) — PROVIDED the pool dtype is lossless w.r.t. compute
    (``kv_cache_lossless``).  A lossy pool (int8, or f32 compute over a
    bf16 pool) raises at construction unless
    ``allow_lossy_prefix_cache=True`` explicitly opts into
    tolerance-level agreement (serve/README.md documents the int8
    tolerance story).

    **Chunked prefill** (``prefill_tokens_per_step``, paged only): a
    long prompt no longer stalls every in-flight decode for its whole
    prefill.  Admission claims the slot and all its blocks but computes
    nothing; each loop iteration then spends a token budget on
    partially-prefilled slots via the prefill-at-offset path
    (``prefill_ctx_sampled``), interleaved with decode steps.  The
    budget is policy-tunable per step: a policy exposing
    ``prefill_budget(signals, default)`` (e.g. ``LatencyAwarePolicy``)
    sees live ``LoadSignals`` and may return None to finish
    monolithically.  Only the FINAL chunk's in-graph sample (drawn at
    position = prompt length, exactly the monolithic draw) is kept, so
    greedy output is byte-identical chunked vs monolithic.  A slot
    preempted mid-prefill frees private blocks, keeps prefix-matched
    ones refcounted, and resumes from its completed-chunk offset.

    **Disaggregation** (``prefill_to_span`` / ``submit_span``): a
    prefill-role engine runs the chunk loop into scratch blocks and
    lifts the KV out as a ``KVSpan`` (pool dtype, serializable); a
    decode-role engine rehydrates it into local blocks and admits the
    request decode-ready — ``serve/cluster.py`` routes the spans over
    the scheduler control plane.

    **Speculative decoding** (``spec_decode=True``, paged only): each
    scheduler round a cheap DRAFT model — a layer-truncated share of
    the target's own weights (``serve/spec.py``) with a dense
    full-precision scratch cache — proposes up to ``spec_draft_len``
    tokens per slot in ONE fused chained dispatch, and the target
    verifies all of them in ONE multi-token prefill-at-offset step.
    The engine emits the longest drafted prefix matching verify's own
    samples plus verify's first divergent token; since every emitted
    token is verify's sample under the same ``fold_in(seed, position)``
    key sequential decode uses, GREEDY output stays byte-identical to
    non-speculative serving on every target, and seeded-sampled output
    is byte-identical across targets/migration/resume for a fixed spec
    configuration (see serve/spec.py for the ulp caveat vs
    non-speculative sampling).  Up to k tokens per 2 dispatches
    replaces k dispatches.  Under a runtime the draft chain
    and verify register as DISTINCT binaries (``{fn_prefix}_draft`` /
    ``{fn_prefix}_verify``) so the policy can hold draft-on-HOST /
    verify-on-ACCEL simultaneously and migrate either; a policy
    exposing ``draft_len(signals, default)`` (``LatencyAwarePolicy``)
    shrinks k under queue pressure, 0 disabling speculation for the
    step.  Fan-out blocks reserved for candidate positions stay on the
    slot when drafts are rejected (never freed mid-round — see
    ``PagedSlotManager.fanout_blocks``), so rollback cannot corrupt
    shared prefix-cached blocks.

    A request whose ``stop_tokens`` fires finishes that step: its slot —
    and, under paging, its blocks — frees immediately for queued
    arrivals instead of idling out the ``max_new_tokens`` budget.

    With a ``runtime``, every prefill/decode dispatches through
    ``XarTrekRuntime.call`` under the names ``{fn_prefix}_prefill`` /
    ``{fn_prefix}_decode`` so the scheduling policy picks the target per
    step; the engine registers DISTINCT builds per step via
    ``MultiTargetBinary``: HOST is the XLA reference math and ACCEL
    routes the same ABI through the Pallas kernels (flash prefill;
    flash-decoding / paged-streaming decode) — a migration is a real
    kernel swap, not a label change.  Both are compiled eagerly at
    ``prepare()`` (``eager_accel=True``, the default) so the first
    migration never pays compile time inside the timed region; pass
    ``eager_accel=False`` to keep the paper's asynchronous
    FPGA-pre-configuration behaviour instead.  Unless the caller
    pre-registered its own variants.

    **Placement is a ``SchedulingPolicy``** (``core/policy``): pass
    ``policy=`` a policy instance or alias string.  ``PinHost`` /
    ``PinAccel`` pin the direct (no-runtime) path to the XLA / Pallas
    build; every other policy (``XarTrekHeuristic``,
    ``LatencyAwarePolicy``, custom) needs a ``runtime`` — the engine
    installs the policy on the runtime's scheduler server.  Paged int8
    KV runs a real ACCEL build (the int8-dequantising paged kernel);
    only DENSE int8 still pins its ACCEL variant to XLA math.

    **Signals.**  Each loop iteration the engine publishes a
    ``LoadSignals`` snapshot (queue depth, active slots, free-KV
    fraction, per-target recent decode ms, TTFT/TPOT p50) to the
    scheduler server — the policy input is real telemetry, not the
    synthetic process counter (which remains one merged source).  In a
    multi-engine cluster (``serve/cluster.py``) the server aggregates
    snapshots across engines, so co-tenant pressure migrates this
    engine's steps.

    Deprecated escape hatches (warn once per process, absorbed by the
    policy API): ``backend="host"/"accel"`` maps to
    ``policy=PinHost()/PinAccel()``; ``on_step`` (fires with the engine
    after each decode step) is superseded by scripted policies that
    decide from ``LoadSignals`` / their own decision counters.

    Row-independent attention families only: ssm/hybrid caches cannot
    seek per-row, and moe routing couples rows through the shared
    expert-capacity budget.
    """

    def __init__(self, cfg: ModelConfig, max_slots: int = 8,
                 max_seq: int = 128,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 params=None, seed: int = 0,
                 runtime: Optional[XarTrekRuntime] = None,
                 fn_prefix: str = "cb", min_bucket: int = 8,
                 paged: bool = False, block_size: int = 32,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 allow_lossy_prefix_cache: bool = False,
                 lane_align: Optional[bool] = None,
                 policy: Optional[SchedulingPolicy] = None,
                 backend: str = "auto", eager_accel: bool = True,
                 prefill_tokens_per_step: Optional[int] = None,
                 spec_decode: bool = False, spec_draft_len: int = 4,
                 spec_draft_layers: Optional[int] = None,
                 spec_draft_params=None, spec_draft_config=None,
                 on_step=None):
        global _BACKEND_DEPRECATION_WARNED, _ON_STEP_DEPRECATION_WARNED
        if cfg.family not in ("dense", "vlm"):
            # ssm/hybrid caches are position-synchronised; moe routing is
            # batch-coupled (capacity = f(batch tokens), so junk tokens
            # from inactive slots would steal expert capacity from real
            # requests and padded prefills would re-rank routing)
            raise NotImplementedError(
                f"continuous batching needs a per-row-seekable KV cache "
                f"and row-independent math; family {cfg.family!r} is not")
        if prefix_cache and not paged:
            raise ValueError("prefix_cache=True requires paged=True "
                             "(sharing happens at block granularity)")
        if prefix_cache and not allow_lossy_prefix_cache \
                and not kv_cache_lossless(cfg):
            raise ValueError(
                f"prefix_cache=True with lossy kv_cache_dtype="
                f"{cfg.kv_cache_dtype!r} (compute {cfg.dtype!r}) breaks "
                f"the byte-identity invariant: cache-on reads ROUNDED "
                f"prefix KV where cache-off attends full precision.  "
                f"Pass allow_lossy_prefix_cache=True to accept "
                f"tolerance-level (not bitwise) agreement — see "
                f"serve/README.md 'Prefix caching' for the int8 "
                f"tolerance story")
        if prefill_tokens_per_step is not None:
            if not paged:
                raise ValueError(
                    "prefill_tokens_per_step (chunked prefill) requires "
                    "paged=True: chunks scatter into pool blocks")
            if prefill_tokens_per_step < 1:
                raise ValueError("prefill_tokens_per_step must be >= 1")
        if spec_decode:
            if not paged:
                raise ValueError(
                    "spec_decode=True requires paged=True: the verify "
                    "step is a multi-token prefill-at-offset over the "
                    "paged pool, and the fan-out/rollback story lives "
                    "at block granularity")
            if spec_draft_len < 1:
                raise ValueError("spec_draft_len must be >= 1")
        if backend not in ("host", "accel", "auto"):
            raise ValueError(f"backend must be host|accel|auto: {backend!r}")
        if backend != "auto":
            if policy is not None:
                raise ValueError(
                    "pass either policy= or the deprecated backend=, "
                    "not both")
            if not _BACKEND_DEPRECATION_WARNED:
                _BACKEND_DEPRECATION_WARNED = True
                warnings.warn(
                    "ContinuousBatchingEngine(backend=...) is deprecated; "
                    "pass policy=PinHost()/PinAccel() (core.policy)",
                    DeprecationWarning, stacklevel=2)
            policy = PinHost() if backend == "host" else PinAccel()
        if on_step is not None and not _ON_STEP_DEPRECATION_WARNED:
            _ON_STEP_DEPRECATION_WARNED = True
            warnings.warn(
                "ContinuousBatchingEngine(on_step=...) is deprecated; "
                "use a scripted SchedulingPolicy (it sees LoadSignals "
                "every decision)", DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.model = build_model(cfg, mesh)
        self.mesh = mesh
        self.runtime = runtime
        self.min_bucket = min_bucket
        self.paged = paged
        self.prefix_cache = prefix_cache
        self.policy = resolve_policy(policy) if policy is not None else None
        # chunked prefill: on when the engine knob is set, or when the
        # installed policy carries its own budget (LatencyAwarePolicy's
        # prefill_tokens_per_step field) — the policy's prefill_budget
        # hook then tunes the per-step budget from live LoadSignals
        self.prefill_tokens_per_step = prefill_tokens_per_step
        _pols = (self.policy,
                 getattr(getattr(runtime, "server", None), "policy", None))
        self._chunking = paged and (
            prefill_tokens_per_step is not None
            or any(getattr(p, "prefill_tokens_per_step", None) is not None
                   for p in _pols))
        if (self.policy is not None and runtime is None
                and not isinstance(self.policy, (PinHost, PinAccel))):
            raise ValueError(
                f"policy {getattr(self.policy, 'name', self.policy)!r} "
                f"decides per step and needs a runtime=; only "
                f"PinHost/PinAccel can drive the direct path")
        if runtime is not None and self.policy is not None:
            runtime.server.policy = self.policy
        self.on_step = on_step
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = params
        self.queue = RequestQueue()
        if paged:
            # default pool = the dense engine's memory footprint
            # (max_slots full rows), but shared: short requests only take
            # the blocks they reach, so more of them fit concurrently
            self.block_size = block_size
            nb = num_blocks or max_slots * (-(-max_seq // block_size))
            self.slots: SlotManager = PagedSlotManager(
                max_slots, block_size, nb, max_seq=max_seq,
                prefix_cache=prefix_cache)
            self.cache = self.model.init_paged_cache(nb + 1, block_size,
                                                     lane_align=lane_align)
            # scatter a prefill's KV blocks into the pool at the slot's
            # physical block ids (one fused donated update, like the
            # dense row write below); jit specializes per block count
            def scatter(pool, part, phys):
                out = {}
                for k in pool:
                    p = part[k][:, 0]               # (L, S_bucket, KV, hd)
                    tgt = phys.shape[0] * block_size
                    if p.shape[1] > tgt:            # bucket overhangs
                        p = p[:, :tgt]
                    elif p.shape[1] < tgt:          # junk tail: positions
                        p = jnp.pad(                # >= length are masked
                            p, ((0, 0), (0, tgt - p.shape[1])) +
                            ((0, 0),) * (p.ndim - 2))
                    if p.shape[-1] != pool[k].shape[-1]:
                        # lane-aligned pool: zero-pad the head_dim tail
                        p = jnp.pad(p, ((0, 0),) * (p.ndim - 1)
                                    + ((0, pool[k].shape[-1] - p.shape[-1]),))
                    p = p.reshape(p.shape[0], phys.shape[0], block_size,
                                  *p.shape[2:])
                    out[k] = pool[k].at[:, phys].set(p.astype(pool[k].dtype))
                return out
            self._scatter = jax.jit(scatter, donate_argnums=(0,))

            # prefix-cache helpers: a chunked scatter that can START
            # mid-block (the COW-forked tail keeps its cached prefix, the
            # re-fed token lands at start_off inside it) and a physical
            # block copy (the COW fork itself).  Positions >= n_real are
            # bucket junk, redirected to the reserved junk block 0.
            def scatter_chunk(pool, part, phys, start_off, n_real):
                out = {}
                for k in pool:
                    p = part[k][:, 0]           # (L, W_bucket, KV, hd)
                    w = p.shape[1]
                    intra = start_off + jnp.arange(w)
                    valid = jnp.arange(w) < n_real
                    blk = jnp.where(valid, phys[intra // block_size], 0)
                    off = jnp.where(valid, intra % block_size, 0)
                    if p.shape[-1] != pool[k].shape[-1]:
                        p = jnp.pad(p, ((0, 0),) * (p.ndim - 1)
                                    + ((0, pool[k].shape[-1]
                                        - p.shape[-1]),))
                    out[k] = pool[k].at[:, blk, off].set(
                        p.astype(pool[k].dtype))
                return out

            def copy_block(pool, dst, src):
                return {k: pool[k].at[:, dst].set(pool[k][:, src])
                        for k in pool}

            # disaggregated-span rehydration: ONE compile for any span
            # length.  The generic _scatter above specializes on
            # phys.shape — one executable (and one full donated pool
            # pass through the compiler) PER DISTINCT BLOCK COUNT, so a
            # decode-role engine admitting spans of many prompt lengths
            # recompiled the whole scatter for each.  Here the span KV
            # is padded host-side to the table-width worst case and the
            # pad rows are masked to the reserved junk block 0, so every
            # admission reuses the same donated executable.
            def scatter_span(pool, part, phys, n_blocks):
                out = {}
                valid = jnp.arange(phys.shape[0]) < n_blocks
                blk = jnp.where(valid, phys, 0)
                for k in pool:
                    p = part[k]        # (L, table_width, block_size, ...)
                    if p.shape[-1] != pool[k].shape[-1]:
                        p = jnp.pad(p, ((0, 0),) * (p.ndim - 1)
                                    + ((0, pool[k].shape[-1]
                                        - p.shape[-1]),))
                    out[k] = pool[k].at[:, blk].set(p.astype(pool[k].dtype))
                return out

            self._scatter_chunk = jax.jit(scatter_chunk, donate_argnums=(0,))
            self._copy_block = jax.jit(copy_block, donate_argnums=(0,))
            self._scatter_span = jax.jit(scatter_span, donate_argnums=(0,))
        else:
            self.slots = SlotManager(max_slots, max_seq)
            self.cache = self.model.init_cache(max_slots, max_seq)
        # direct-path (no-runtime) step functions honour the pinned
        # policy; no policy (or PinHost) serves on HOST math.  Both
        # steps sample IN-GRAPH and return tokens, not logits.
        direct = "pallas" if isinstance(self.policy, PinAccel) else "xla"
        self._direct_impl = direct
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill_at_sampled(p, b, backend=direct))
        # donate the cache: without aliasing every token copies the full
        # (L, max_slots, max_seq, KV, hd) stack (see decode_attention)
        self._decode = jax.jit(
            lambda p, c, b: self.model.decode_sampled(p, c, b,
                                                      backend=direct),
            donate_argnums=(1,))
        self._needs_ctx = self.paged and (self.prefix_cache
                                          or self._chunking)
        if self.paged:
            # prefill-at-offset against the pool — the shared chunk path
            # of prefix-cache re-feed AND budgeted chunked prefill.  The
            # pool is NOT donated: matched blocks are shared, and the
            # chunk's KV is returned for an explicit scatter into the
            # slot's private blocks only.
            self._prefill_ctx = jax.jit(
                lambda p, c, b: self.model.prefill_ctx_sampled(
                    p, c, b, backend=direct))
        # one fused in-place write of a request's bucketed prefill KV into
        # its cache row (eager per-leaf updates would each materialize a
        # full copy of the whole batched cache)
        self._write_slot = jax.jit(
            lambda cache, part, row: {
                k: jax.lax.dynamic_update_slice(
                    cache[k], part[k].astype(cache[k].dtype),
                    (jnp.int32(0), row) + (jnp.int32(0),)
                    * (cache[k].ndim - 2))
                for k in cache},
            donate_argnums=(0,))
        # speculative decoding: the draft side is a layer-truncated
        # SHARE of the target (serve/spec.py) with its own dense
        # full-precision scratch cache, widened by draft_len so the
        # chain's last write never clamps at the row edge.  Draft chain
        # and verify are separate step functions — under a runtime they
        # register as DISTINCT migratable binaries ({prefix}_draft /
        # {prefix}_verify), so the policy can hold draft-on-HOST /
        # verify-on-ACCEL simultaneously and summary() accounts both.
        self.spec: Optional[spec_lib.SpecDecoder] = None
        if spec_decode:
            dcfg = (spec_draft_config if spec_draft_config is not None
                    else spec_lib.draft_model_config(cfg, spec_draft_layers))
            draft_model = build_model(dcfg, mesh)
            dparams = (spec_draft_params if spec_draft_params is not None
                       else spec_lib.share_draft_params(self.params,
                                                        dcfg.num_layers))
            dcache = draft_model.init_cache(max_slots,
                                            max_seq + spec_draft_len)
            self.spec = spec_lib.SpecDecoder(
                model=draft_model, cfg=dcfg, params=dparams, cache=dcache,
                draft_len=spec_draft_len)
            self._spec_width = max_seq + spec_draft_len
            # content-addressed host->device cache for the small
            # per-round batch vectors that rarely change between rounds
            # (sampling leaves, block table, n_valid) — see _spec_put
            self._spec_dev_cache: dict = {}
            # lazy draft-row rehydration (fresh admission / resume /
            # fingerprint miss) always runs on the direct path: it is
            # rare and off the per-round dispatch cadence, so it is not
            # a migration surface
            self._draft_prefill = jax.jit(
                lambda p, b: draft_model.prefill_at_sampled(
                    p, b, backend=direct))
            self._draft_chain = jax.jit(
                lambda p, c, b: draft_model.decode_draft(
                    p, c, b, backend=direct, max_steps=spec_draft_len),
                donate_argnums=(1,))
            # verify consumes the chain's DEVICE-resident drafts and
            # assembles its token/offset/length batch in-graph
            # (spec_verify_batch), so a round has exactly one host sync
            # — after verify — instead of one per dispatch
            self._verify = jax.jit(
                lambda p, c, b: self.model.decode_verify(
                    p, c, spec_verify_batch(b), backend=direct),
                donate_argnums=(1,))
        self._prefill_name = f"{fn_prefix}_prefill"
        self._prefill_ctx_name = f"{fn_prefix}_prefill_ctx"
        self._decode_name = f"{fn_prefix}_decode"
        self._draft_name = f"{fn_prefix}_draft"
        self._verify_name = f"{fn_prefix}_verify"
        self.engine_id = fn_prefix
        self.results: dict[int, RequestOutput] = {}
        # req_id -> (tokens, logprobs) generated before preemption
        self._resume: dict[int, tuple[list[int], list[float]]] = {}
        # req_id -> KVSpan handed off by a prefill-role engine
        # (disaggregation); admission rehydrates instead of prefilling
        self._spans: dict[int, "KVSpan"] = {}
        self._step_budget: Optional[int] = None
        self._handles: dict[int, RequestHandle] = {}
        # fired from _finalize with (handle, RequestOutput) the moment a
        # request finishes (any reason, including aborts) — the process
        # worker's result-plane emitter; None = no observer
        self.on_finish = None
        self._abort_pending: set[int] = set()
        self._abort_lock = threading.Lock()
        self._clock0: Optional[float] = None
        # serve telemetry for LoadSignals: per-target EWMA of the direct
        # path's decode step ms (runtime-dispatched steps read the
        # binary's compile_stats instead) + a window of recent finished
        # requests' latency metrics
        self._direct_step_ms: dict[str, Optional[float]] = {
            "host": None, "accel": None}
        # EWMA of per-iteration decode stall (ms spent on chunk prefills
        # while decode-ready slots waited) — the feedback signal
        # LatencyAwarePolicy.prefill_budget contracts on.  Steps with no
        # chunk work blend in 0.0 so a past burst decays instead of
        # pinning the budget down forever.
        self._stall_ewma: Optional[float] = None
        self._latency_window: collections.deque = collections.deque(
            maxlen=64)
        self.reset_stats()
        if runtime is not None:
            self._prepare_runtime(runtime, fn_prefix, eager_accel)

    def reset_stats(self) -> None:
        """Zero the per-serve counters (benchmarks call this after their
        warm-up pass so warm-up steps don't pollute measured stats).
        ``prefill_tokens`` counts tokens actually COMPUTED by prefill
        (real feed positions, not bucket padding); ``prefix_hit_tokens``
        counts prompt positions served from the prefix cache instead —
        their ratio is the cache hit rate.  ``prefill_chunks`` counts
        prefill-at-offset calls, ``chunk_hist`` their bucketed widths,
        ``decode_stall_ms`` wall time spent on chunk prefills while
        decode-ready slots waited, and ``spans_admitted`` requests
        rehydrated from a disaggregated KV handoff."""
        self.stats = {"prefills": 0, "decode_steps": 0,
                      "decode_row_util": 0.0,
                      "prefill_tokens": 0, "prefix_hit_tokens": 0,
                      "prefill_chunks": 0, "decode_stall_ms": 0.0,
                      "decode_stall_max_ms": 0.0,
                      "chunk_hist": {}, "spans_admitted": 0,
                      "spec_rounds": 0, "spec_proposed_tokens": 0,
                      "spec_accepted_tokens": 0, "spec_emitted_tokens": 0}

    def spec_stats(self) -> dict:
        """Speculative-decoding effectiveness counters (zeros when spec
        decode is off).  ``spec_proposed_tokens`` counts DRAFTED tokens
        actually put to the verifier (n_valid - 1 per row per round),
        ``spec_accepted_tokens`` how many of those verify confirmed —
        their ratio is the acceptance rate.  ``spec_emitted_tokens``
        counts tokens emitted by spec rounds (accepted + the one
        verify-sampled token each row always yields, truncated at
        stop tokens), so emitted/rounds is tokens-per-dispatch-pair."""
        s = self.stats
        return {"spec_rounds": s["spec_rounds"],
                "spec_proposed_tokens": s["spec_proposed_tokens"],
                "spec_accepted_tokens": s["spec_accepted_tokens"],
                "spec_emitted_tokens": s["spec_emitted_tokens"],
                "spec_acceptance_rate": (s["spec_accepted_tokens"]
                                         / max(s["spec_proposed_tokens"],
                                               1))}

    def prefix_stats(self) -> dict:
        """Prefix-cache effectiveness counters (zeros when caching is
        off): token hit rate plus the pool/manager sharing counters."""
        computed = self.stats["prefill_tokens"]
        hit = self.stats["prefix_hit_tokens"]
        out = {"prefill_tokens": computed, "prefix_hit_tokens": hit,
               "prefix_hit_rate": hit / max(hit + computed, 1),
               "prefill_chunks": self.stats["prefill_chunks"],
               "decode_stall_ms": self.stats["decode_stall_ms"],
               "decode_stall_max_ms": self.stats["decode_stall_max_ms"],
               "chunk_hist": dict(self.stats["chunk_hist"]),
               "spans_admitted": self.stats["spans_admitted"]}
        if self.paged:
            pool = self.slots.pool
            out.update(cow_forks=self.slots._stats["cow_forks"],
                       prefix_block_hits=self.slots._stats[
                           "prefix_block_hits"],
                       cache_hits=pool.stats["cache_hits"],
                       evicted=pool.stats["evicted"],
                       cached_blocks=pool.cached_blocks())
        return out

    def _now(self) -> float:
        """Engine-loop clock (seconds since the current run() started)."""
        if self._clock0 is None:
            return 0.0
        return time.perf_counter() - self._clock0

    # ---------------------------------------------------------- telemetry
    def signals(self) -> LoadSignals:
        """This engine's serve-telemetry snapshot — the real policy
        input that replaced the synthetic process counter: queue depth,
        in-flight rows, free KV capacity (block pool under paging, rows
        otherwise), per-target recent decode step ms (EWMA from the
        runtime binary's ``compile_stats``, or the direct path's own
        timer) and TTFT/TPOT p50 over recently finished requests."""
        if self.paged:
            free = (self.slots.pool.free_blocks()
                    / max(self.slots.pool.num_blocks, 1))
        else:
            cap = self.slots.max_slots
            free = (cap - len(self.slots.active)) / max(cap, 1)
        host_ms = self._direct_step_ms["host"]
        accel_ms = self._direct_step_ms["accel"]
        if self.runtime is not None:
            binary = self.runtime.binaries.get(self._decode_name)
            if binary is not None:
                cs = binary.compile_stats
                host_ms = cs.get(TargetKind.HOST, {}).get(
                    "recent_exec_ms", host_ms)
                accel_ms = cs.get(TargetKind.ACCEL, {}).get(
                    "recent_exec_ms", accel_ms)
        ttft = sorted(t for t, _ in self._latency_window)
        tpot = sorted(t for _, t in self._latency_window)
        # arrived_len, not len(): the heap also holds scheduled-but-
        # future arrivals (pre-submitted Poisson streams), which are not
        # load yet — counting them inflated x86_load and tripped
        # queue_depth_hi thresholds before any request existed
        return LoadSignals(
            queue_depth=self.queue.arrived_len(self._now()),
            active_slots=len(self.slots.active),
            free_kv_frac=free,
            host_decode_ms=host_ms,
            accel_decode_ms=accel_ms,
            ttft_p50_s=ttft[len(ttft) // 2] if ttft else None,
            tpot_p50_s=tpot[len(tpot) // 2] if tpot else None,
            decode_stall_ms=self._stall_ewma,
        )

    def _publish_signals(self) -> None:
        """Feed the snapshot to the scheduler (each loop iteration):
        with a shared/central server this is how one engine's pressure
        reaches every co-tenant's placement decision."""
        if self.runtime is not None:
            self.runtime.publish_signals(self.engine_id, self.signals())

    # ------------------------------------------------- runtime plumbing
    def _prepare_runtime(self, rt: XarTrekRuntime, fn_prefix: str,
                         eager_accel: bool) -> None:
        def step_fns(impl: str):
            def prefill_fn(params, batch):
                return self.model.prefill_at_sampled(params, batch,
                                                     backend=impl)

            def decode_fn(params, cache, batch):
                return self.model.decode_sampled(params, cache, batch,
                                                 backend=impl)

            return prefill_fn, decode_fn

        # HOST keeps the XLA reference; ACCEL is a genuinely different
        # build on the Pallas kernels (same ABI, checked at prepare) —
        # except DENSE int8 caches (the dequantising kernel is paged-
        # only) and PinHost, which pins both variants to XLA.  Paged
        # int8 gets the real kernel: blocks + scale planes streamed
        # through the block table, dequantised in VMEM.
        accel_impl = ("pallas" if (not isinstance(self.policy, PinHost)
                                   and (self.cfg.kv_cache_dtype != "int8"
                                        or self.paged))
                      else "xla")
        host_prefill, host_decode = step_fns("xla")
        if accel_impl == "pallas":
            accel_prefill, accel_decode = step_fns(accel_impl)
        else:
            # identical math: reuse the HOST functions and keep the
            # ACCEL pre-configuration asynchronous — a blocking eager
            # compile would buy zero kernel asymmetry
            accel_prefill, accel_decode = host_prefill, host_decode
            eager_accel = False
        # one app (= one threshold row) per step function, so Algorithm 1
        # doesn't mix prefill and decode timings in one row
        for name, host_fn, accel_fn in (
                (self._prefill_name, host_prefill, accel_prefill),
                (self._decode_name, host_decode, accel_decode)):
            if name not in rt.registry:
                rt.registry.register(MigratableFunction(
                    name, name,
                    {TargetKind.HOST: host_fn, TargetKind.ACCEL: accel_fn}))
        greedy = SamplingParams()
        ex_prefill = (self.params,
                      {"tokens": jnp.zeros((1, self.min_bucket), jnp.int32),
                       "length": jnp.ones((1,), jnp.int32),
                       **sampling_leaves(greedy, 1)})
        dec_batch = {"tokens": jnp.zeros((self.slots.max_slots, 1),
                                         jnp.int32),
                     "index": jnp.zeros((self.slots.max_slots,), jnp.int32),
                     **sampling_leaves(greedy, self.slots.max_slots)}
        if self.paged:
            # paged decode keys its compile on the block-table shape too;
            # steady state is one static signature (see binary.shape_key)
            dec_batch["block_table"] = jnp.zeros(
                (self.slots.max_slots, self.slots.table_width), jnp.int32)
        ex_decode = (self.params, self.cache, dec_batch)
        rt.prepare(self._prefill_name, *ex_prefill, eager_accel=eager_accel)
        rt.prepare(self._decode_name, *ex_decode, donate_argnums=(1,),
                   eager_accel=eager_accel)
        if self._needs_ctx:
            # prefill-at-offset (chunked prefill / prefix-cache re-feed):
            # HOST is the XLA gather reference, ACCEL the paged_gqa_prefill
            # Pallas kernel (chunk flash self-attention fused with the
            # masked [0, offset) pool read) — the same genuine kernel
            # asymmetry as decode, including the int8-dequantising paged
            # variant.
            def ctx_fn(impl):
                def fn(params, cache, batch):
                    return self.model.prefill_ctx_sampled(params, cache,
                                                          batch, backend=impl)
                return fn

            host_ctx = ctx_fn("xla")
            accel_ctx = ctx_fn("pallas") if accel_impl == "pallas" \
                else host_ctx
            if self._prefill_ctx_name not in rt.registry:
                rt.registry.register(MigratableFunction(
                    self._prefill_ctx_name, self._prefill_ctx_name,
                    {TargetKind.HOST: host_ctx,
                     TargetKind.ACCEL: accel_ctx}))
            ex_ctx = (self.params, self.cache,
                      {"tokens": jnp.zeros((1, self.min_bucket), jnp.int32),
                       "offset": jnp.zeros((1,), jnp.int32),
                       "length": jnp.ones((1,), jnp.int32),
                       "block_table": jnp.zeros(
                           (1, self.slots.table_width), jnp.int32),
                       **sampling_leaves(greedy, 1)})
            rt.prepare(self._prefill_ctx_name, *ex_ctx,
                       eager_accel=eager_accel)
        if self.spec is not None:
            # speculative decoding registers TWO MORE distinct binaries:
            # the fused k-step draft chain and the multi-token verify.
            # Each gets its own threshold row / call counters, so the
            # headline draft-on-HOST / verify-on-ACCEL split is a real
            # per-function placement the scheduler (and summary()) sees —
            # and migrating either is a kernel swap like any other step.
            draft_model, W = self.spec.model, self.spec.draft_len

            def draft_fn(impl):
                def fn(params, cache, batch):
                    return draft_model.decode_draft(params, cache, batch,
                                                    backend=impl,
                                                    max_steps=W)
                return fn

            def verify_fn(impl):
                def fn(params, cache, batch):
                    return self.model.decode_verify(
                        params, cache, spec_verify_batch(batch),
                        backend=impl)
                return fn

            host_draft = draft_fn("xla")
            # the draft cache is always full-precision dense (see
            # spec.draft_model_config), so its ACCEL build is the real
            # Pallas flash-decode even when the TARGET pool is int8
            accel_draft = (draft_fn("pallas")
                           if not isinstance(self.policy, PinHost)
                           else host_draft)
            host_verify = verify_fn("xla")
            accel_verify = (verify_fn("pallas") if accel_impl == "pallas"
                            else host_verify)
            for name, host_fn, accel_fn in (
                    (self._draft_name, host_draft, accel_draft),
                    (self._verify_name, host_verify, accel_verify)):
                if name not in rt.registry:
                    rt.registry.register(MigratableFunction(
                        name, name, {TargetKind.HOST: host_fn,
                                     TargetKind.ACCEL: accel_fn}))
            B = self.slots.max_slots
            ex_draft = (self.spec.params, self.spec.cache,
                        {"tokens": jnp.zeros((B, 1), jnp.int32),
                         "index": jnp.zeros((B,), jnp.int32),
                         "n_steps": jnp.int32(1),
                         **sampling_leaves(greedy, B)})
            ex_verify = (self.params, self.cache,
                         {"drafts": jnp.zeros((B, W), jnp.int32),
                          "last": jnp.zeros((B, 1), jnp.int32),
                          "pos": jnp.zeros((B,), jnp.int32),
                          "n_valid": jnp.zeros((B,), jnp.int32),
                          "block_table": jnp.zeros(
                              (B, self.slots.table_width), jnp.int32),
                          **sampling_leaves(greedy, B)})
            rt.prepare(self._draft_name, *ex_draft, donate_argnums=(1,),
                       eager_accel=eager_accel)
            rt.prepare(self._verify_name, *ex_verify, donate_argnums=(1,),
                       eager_accel=eager_accel)

    # -------------------------------------------------------- admission
    def submit(self, request, max_new_tokens: int = 16,
               arrival_s: float = 0.0, stop_tokens: tuple = (),
               sampling: Optional[SamplingParams] = None,
               on_token=None) -> RequestHandle:
        """Enqueue one request; returns its streaming ``RequestHandle``.

        ``request`` is a ``GenerationRequest`` (the remaining kwargs are
        ignored then) or a bare prompt array, in which case EVERY
        request field routes through — max_new_tokens, arrival time,
        stop_tokens AND the sampling spec (the v1 engine silently
        dropped ``stop_tokens`` here).  Validates at submission, not
        mid-serve: a request that cannot fit a cache row would otherwise
        fail only once a slot frees."""
        if not isinstance(request, GenerationRequest):
            request = GenerationRequest(
                np.asarray(request), max_new_tokens=max_new_tokens,
                arrival_s=arrival_s, stop_tokens=stop_tokens,
                sampling=sampling or SamplingParams())
        self.queue.submit(self.slots.validate(request))
        return self._handle_for(request, on_token=on_token)

    def submit_resume(self, request: GenerationRequest,
                      tokens=(), logprobs=None,
                      on_token=None) -> RequestHandle:
        """Enqueue a request that already generated ``tokens`` somewhere
        else (a worker that died mid-stream): admission re-prefills
        prompt + tokens[:-1] and replays the stash, exactly the
        preempt/resume path, so the continuation is byte-identical to
        never having moved — sampling keys depend only on
        (seed, position).  The replayed tokens re-emit through the
        handle; cross-process consumers dedup on absolute index.
        Empty ``tokens`` degrades to a plain ``submit``."""
        tokens = [int(t) for t in tokens]
        lps = [float(x) for x in (logprobs if logprobs is not None else ())]
        if tokens and len(lps) != len(tokens):
            lps = [0.0] * len(tokens)
        self.queue.submit(self.slots.validate(request))
        if tokens:
            self._resume[request.req_id] = (tokens, lps)
        return self._handle_for(request, on_token=on_token)

    def _handle_for(self, req: GenerationRequest,
                    on_token=None) -> RequestHandle:
        h = self._handles.get(req.req_id)
        if h is None:
            h = self._handles[req.req_id] = RequestHandle(
                req, engine=self, on_token=on_token)
        elif on_token is not None:
            h.on_token = on_token
        return h

    def abort(self, req_id: int) -> bool:
        """Cancel a queued or in-flight request.  Its handle finishes
        with ``finish_reason="aborted"`` and whatever tokens were
        generated; an in-flight slot — and, under paging, its KV blocks
        — frees at the next loop iteration.  Returns False if the
        request is unknown or already finished.

        Thread-safe: the caller only MARKS the abort; all engine state
        (queue, slots, results) is touched by the loop thread in
        ``_service_aborts`` — except when no loop is running, in which
        case the abort is serviced inline."""
        handle = self._handles.get(req_id)
        if handle is None or handle.finished or req_id in self.results:
            return False
        with self._abort_lock:
            self._abort_pending.add(req_id)
        if self._clock0 is None:       # no loop running: service inline
            self._service_aborts(self._now())
        return True

    def _service_aborts(self, now: float) -> None:
        """Loop-thread half of ``abort``: finish aborted requests
        wherever they currently live — an active slot (release it;
        paged: frees its blocks), the queue (remove it, covering both
        never-admitted and preempted-awaiting-resume requests), or
        already finished (drop the mark).  A request caught mid-admission
        stays pending and is serviced next iteration."""
        with self._abort_lock:
            pending = set(self._abort_pending)
        for req_id in pending:
            done = False
            for slot in list(self.slots.active.values()):
                if slot.request.req_id == req_id:
                    self._sync_handle(slot, now)
                    self._finalize(self._handle_for(slot.request),
                                   FINISH_ABORTED, now)
                    self.slots.release(slot)   # paged: frees blocks too
                    done = True
                    break
            if not done:
                req = self.queue.remove(req_id)
                if req is not None:
                    self._resume.pop(req_id, None)
                    self._spans.pop(req_id, None)
                    self._finalize(self._handle_for(req), FINISH_ABORTED,
                                   now)
                    done = True
            if done or req_id in self.results:
                with self._abort_lock:
                    self._abort_pending.discard(req_id)

    def _can_admit(self, req: GenerationRequest) -> bool:
        """Admission capacity beyond a free row: the paged pool must hold
        the prefill's blocks plus a growth watermark (block-exhaustion
        backpressure replaces the dense engine's slot-count-only gate)."""
        if not self.paged:
            return True
        if req.req_id in self._spans:
            # handed-off KV rehydrates into exactly the prompt's blocks
            return self.slots.can_admit(req.prompt_len, req)
        resume = self._resume.get(req.req_id)
        plen = req.prompt_len + (len(resume[0]) - 1 if resume else 0)
        if self.prefix_cache:
            # admission must see the actual feed: cached blocks cost
            # nothing, so only the uncached span (+ the COW fork when
            # fully cached) gates admission
            feed = req.prompt if resume is None else np.concatenate(
                [req.prompt, np.asarray(resume[0][:-1], np.int32)])
            return self.slots.can_admit(plen, req, feed=feed)
        return self.slots.can_admit(plen, req)

    def _admit(self, req: GenerationRequest, now: float = 0.0) -> None:
        # resume of a preempted request: the cache must again hold
        # prompt + generated-so-far, so re-prefill over both; the replayed
        # tokens were already sampled (and streamed), so the recomputation
        # is bit-compatible with the original KV regardless of the
        # request's sampling spec (same math, same weights, same tokens)
        span = self._spans.pop(req.req_id, None)
        if span is not None:
            self._admit_span(req, span, now)
            return
        resume = self._resume.pop(req.req_id, None)
        if resume is None:
            feed = req.prompt
        else:
            feed = np.concatenate(
                [req.prompt, np.asarray(resume[0][:-1], np.int32)])
        S = len(feed)
        if self.paged and self._step_budget is not None:
            # chunked prefill: admit the slot with its blocks but NO
            # model call — _advance_prefills spends the per-step budget
            # on it between decode steps.  Short feeds (net of any
            # cached prefix) stay monolithic: one call is cheaper than
            # the chunk machinery.
            cached = (self.slots.matchable_blocks(feed) * self.block_size
                      if self.prefix_cache else 0)
            if S - min(cached, S - 1) > self._step_budget:
                try:
                    slot = self._admit_chunked(req, feed, S, resume)
                except RuntimeError:
                    if resume is not None:
                        self._resume[req.req_id] = resume
                    raise
                self._post_admit(slot, req, now)
                return
        if self.paged and self.prefix_cache:
            try:
                slot = self._admit_cached(req, feed, S, resume)
            except RuntimeError:         # pool raced dry: undo the pop
                if resume is not None:
                    self._resume[req.req_id] = resume
                raise
            self._post_admit(slot, req, now)
            return
        Sb = prompt_bucket(S, self.min_bucket)
        toks = np.zeros((1, Sb), np.int32)
        toks[0, :S] = feed
        batch = {"tokens": jnp.asarray(toks),
                 "length": jnp.full((1,), S, jnp.int32),
                 **sampling_leaves(req.sampling, 1)}
        if self.runtime is not None:
            tok0, lp0, pc = self.runtime.call(self._prefill_name,
                                              self.params, batch)
        else:
            tok0, lp0, pc = self._prefill(self.params, batch)
        self.stats["prefills"] += 1
        self.stats["prefill_tokens"] += S
        if resume is None:
            # first token sampled IN-GRAPH at position = prompt length
            first, tokens, logprobs = int(np.asarray(tok0)[0]), None, None
            first_lp = float(np.asarray(lp0)[0])
        else:
            # the pending token was already sampled before preemption;
            # the resume prefill only rebuilds the KV (its token unused,
            # and the stashed logprobs replay alongside the tokens)
            first, (tokens, logprobs) = resume[0][-1], resume
            first_lp = 0.0                       # overridden by logprobs
        if self.paged:
            blocks = self.slots.pool.alloc(self.slots.blocks_for(S))
            slot = self.slots.admit(req, first, blocks=blocks,
                                    tokens=tokens, logprobs=logprobs,
                                    first_logprob=first_lp, pos=S)
            # scatter the bucketed prefill KV (leaves (L,1,S_bucket,KV,hd),
            # seq axis 2) into the slot's physical blocks; the tail of the
            # last block carries junk KV, which write-then-attend decode
            # always overwrites before reading (see batch.py docstring)
            self.cache = self._scatter(self.cache, pc,
                                       jnp.asarray(blocks, jnp.int32))
        else:
            slot = self.slots.admit(req, first, tokens=tokens,
                                    logprobs=logprobs,
                                    first_logprob=first_lp, pos=S)
            # write the request's bucketed KV into its cache row (leaves
            # are (L, 1, S_bucket, KV, hd|1); seq is axis 2).  Positions
            # [S, S_bucket) carry pad KV, overwritten before any read
            if Sb > self.slots.max_seq:    # bucket overhangs the row
                pc = {k: jax.lax.slice_in_dim(pc[k], 0, self.slots.max_seq,
                                              axis=2) for k in pc}
            self.cache = self._write_slot(self.cache, pc,
                                          jnp.int32(slot.index))
        self._post_admit(slot, req, now)

    def _post_admit(self, slot: Slot, req: GenerationRequest,
                    now: float) -> None:
        slot.t_admit = now
        handle = self._handle_for(req)
        if handle.t_admit is None:     # first admission only (not resume)
            handle.t_admit = now
        # the first token was just forced out of the prefill: timestamp it
        # AFTER the prefill so TTFT includes prefill latency
        t_tok = self._now()
        slot.t_last_token = t_tok
        self._sync_handle(slot, t_tok)
        if slot.done:            # max_new_tokens reached or stop token
            self._finish(slot, t_tok)

    def _admit_cached(self, req: GenerationRequest, feed: np.ndarray,
                      S: int, resume) -> Slot:
        """Admission with prefix caching: match the feed's full-block
        prefix against the pool's hash index, allocate only the uncached
        span's blocks, and prefill the CHUNK ``feed[offset:]`` against
        the cached context.

        When the whole feed is cached (C >= S, a block-aligned repeat),
        the last feed token is re-fed as a one-token chunk at offset
        S - 1: its logits reproduce the uncached first sample, and its
        KV write targets the last MATCHED block — which ``ensure_writable``
        forks copy-on-write first, so sharers are untouched.  Otherwise
        the chunk starts at the block-aligned offset C and writes land
        only in freshly-allocated private blocks."""
        bs = self.slots.block_size
        # match BEFORE alloc: references on matched blocks keep the LRU
        # eviction inside alloc() from reclaiming them
        matched, hashes = self.slots.match_prefix(feed)
        C = len(matched) * bs
        offset = C if C < S else S - 1
        n_chunk = S - offset
        n_total = self.slots.blocks_for(S)
        try:
            fresh = (self.slots.pool.alloc(n_total - len(matched))
                     if n_total > len(matched) else [])
        except RuntimeError:
            # pool raced dry between can_admit and here: hand back the
            # matched references before re-raising
            self.slots.pool.free(matched)
            raise
        blocks = matched + fresh
        tail = offset // bs
        copy = None
        if tail < len(matched):          # chunk writes into a matched block
            try:
                blocks, copy = self.slots.ensure_writable(blocks, tail)
            except RuntimeError:         # no block left for the COW fork
                self.slots.pool.free(matched + fresh)
                raise
            hashes = hashes[:tail]
        if copy is not None:
            src, dst = copy
            self.cache = self._copy_block(self.cache, jnp.int32(dst),
                                          jnp.int32(src))
        self.stats["prefix_hit_tokens"] += offset
        tok0, lp0 = self._ctx_chunk(feed, offset, n_chunk, blocks,
                                    req.sampling)
        self.stats["prefills"] += 1
        if resume is None:
            first, tokens, logprobs = int(np.asarray(tok0)[0]), None, None
            first_lp = float(np.asarray(lp0)[0])
        else:
            first, (tokens, logprobs) = resume[0][-1], resume
            first_lp = 0.0
        slot = self.slots.admit(req, first, blocks=blocks, tokens=tokens,
                                logprobs=logprobs, first_logprob=first_lp,
                                pos=S)
        slot.block_hashes = hashes
        self.slots.register_full_blocks(slot, feed)
        return slot

    def _ctx_chunk(self, feed, offset: int, n_chunk: int,
                   blocks: list[int], sampling) -> tuple:
        """One prefill-at-offset call — the SINGLE chunk path shared by
        prefix-cache re-feed, budgeted chunked prefill, and the
        disaggregated prefill-to-span loop, so every chunk width routes
        through the same ``prompt_bucket`` policy and the compile
        signatures coincide.

        Computes KV for ``feed[offset:offset + n_chunk]`` attending the
        pool context ``[0, offset)`` through ``blocks`` and scatters it
        into the blocks covering those positions (physical ids padded
        with junk block 0 to a static per-bucket width).  Returns the
        in-graph sample ``(token, logprob)`` drawn at position
        ``offset + n_chunk`` — meaningful only for the FINAL chunk,
        where it equals the monolithic prefill's first draw."""
        bs = self.slots.block_size
        Cb = prompt_bucket(n_chunk, self.min_bucket)
        toks = np.zeros((1, Cb), np.int32)
        toks[0, :n_chunk] = feed[offset:offset + n_chunk]
        table = np.zeros((1, self.slots.table_width), np.int32)
        table[0, :len(blocks)] = blocks
        batch = {"tokens": jnp.asarray(toks),
                 "offset": jnp.full((1,), offset, jnp.int32),
                 "length": jnp.full((1,), offset + n_chunk, jnp.int32),
                 "block_table": jnp.asarray(table),
                 **sampling_leaves(sampling, 1)}
        if (self.runtime is not None
                and self._prefill_ctx_name in self.runtime.registry):
            tok0, lp0, pc = self.runtime.call(self._prefill_ctx_name,
                                              self.params, self.cache, batch)
        else:
            # no migratable build registered (e.g. prefill_to_span on an
            # engine prepared without prefix cache or chunking): the
            # direct jit serves the chunk on the engine's own backend
            tok0, lp0, pc = self._prefill_ctx(self.params, self.cache, batch)
        nphys = (Cb + 2 * bs - 2) // bs
        span = blocks[offset // bs:][:nphys]
        phys = np.zeros((nphys,), np.int32)
        phys[:len(span)] = span
        self.cache = self._scatter_chunk(self.cache, pc,
                                         jnp.asarray(phys),
                                         jnp.int32(offset % bs),
                                         jnp.int32(n_chunk))
        self.stats["prefill_tokens"] += n_chunk
        self.stats["prefill_chunks"] += 1
        hist = self.stats["chunk_hist"]
        hist[Cb] = hist.get(Cb, 0) + 1
        return tok0, lp0

    # ------------------------------------------------- chunked prefill
    def _prefill_budget(self) -> Optional[int]:
        """Prompt tokens the chunk path may compute this step: the
        policy's ``prefill_budget`` hook (fed live signals) when it has
        one, else the engine's static knob.  None = monolithic."""
        policy = self.policy
        if policy is None and self.runtime is not None:
            policy = self.runtime.server.policy
        hook = getattr(policy, "prefill_budget", None)
        if hook is not None:
            b = hook(self.signals(), self.prefill_tokens_per_step)
        else:
            b = self.prefill_tokens_per_step
        return None if b is None else max(int(b), 1)

    def _admit_chunked(self, req: GenerationRequest, feed: np.ndarray,
                       S: int, resume) -> Slot:
        """Admit a long feed WITHOUT prefilling it: claim any cached
        prefix (shared, refcounted), allocate every remaining block up
        front (the chunk loop then never races the pool), and mark the
        slot partially prefilled at the block-aligned cached offset.
        ``_advance_prefills`` computes the rest under the budget."""
        bs = self.slots.block_size
        matched, hashes = self.slots.match_prefix(feed)   # [] if cache off
        offset = len(matched) * bs
        n_total = self.slots.blocks_for(S)
        try:
            fresh = (self.slots.pool.alloc(n_total - len(matched))
                     if n_total > len(matched) else [])
        except RuntimeError:
            self.slots.pool.free(matched)
            raise
        self.stats["prefix_hit_tokens"] += offset
        slot = self.slots.admit(req, 0, blocks=matched + fresh, tokens=[],
                                logprobs=[], pos=offset)
        slot.block_hashes = hashes
        slot.prefill = PartialPrefill(feed=np.asarray(feed, np.int32),
                                      resume=resume)
        return slot

    def _advance_prefills(self, budget: Optional[int]) -> None:
        """Spend this step's chunk budget on partially-prefilled slots,
        oldest first (a None budget — chunking disabled for the step —
        finishes each in one chunk).  Time spent here while decode-ready
        slots sit waiting is the decode stall the budget knob bounds."""
        pending = self.slots.prefilling_slots()
        if not pending:
            # no chunk work: decay the stall signal toward zero so the
            # policy's stall-feedback contraction releases once the
            # prefill burst that caused it has drained
            self._stall_ewma = ewma(self._stall_ewma, 0.0)
            return
        t0 = time.perf_counter()
        stalled = bool(self.slots.active_slots())
        remaining = float("inf") if budget is None else budget
        for slot in pending:
            if remaining < 1:
                break
            w = int(min(remaining, len(slot.prefill.feed) - slot.pos))
            self._prefill_chunk(slot, w)
            remaining -= w
        if stalled:
            ms = (time.perf_counter() - t0) * 1e3
            self.stats["decode_stall_ms"] += ms
            # worst single-step stall: the SLO number the budget bounds
            self.stats["decode_stall_max_ms"] = max(
                self.stats["decode_stall_max_ms"], ms)
            self._stall_ewma = ewma(self._stall_ewma, ms)
        else:
            self._stall_ewma = ewma(self._stall_ewma, 0.0)

    def _prefill_chunk(self, slot: Slot, n_chunk: int) -> None:
        """Advance one slot's prefill by ``n_chunk`` feed tokens.  Full
        blocks register in the prefix index as they fill, so a
        preemption right after this keeps them warm (cached set) and
        resume restarts from the completed offset.  The final chunk's
        in-graph sample is the request's first token — intermediate
        chunks' draws are discarded (their position is not S)."""
        feed = slot.prefill.feed
        offset, S = slot.pos, len(feed)
        tok0, lp0 = self._ctx_chunk(feed, offset, n_chunk, slot.blocks,
                                    slot.request.sampling)
        slot.pos = offset + n_chunk
        self.slots.register_full_blocks(slot, feed[:slot.pos])
        if slot.pos < S:
            return
        resume = slot.prefill.resume
        slot.prefill = None
        self.stats["prefills"] += 1
        if resume is None:
            slot.tokens = [int(np.asarray(tok0)[0])]
            slot.logprobs = [float(np.asarray(lp0)[0])]
        else:
            slot.tokens = list(resume[0])
            slot.logprobs = list(resume[1])
        slot.last_token = slot.tokens[-1]
        t_tok = self._now()
        slot.t_last_token = t_tok
        self._sync_handle(slot, t_tok)
        if slot.done:
            self._finish(slot, t_tok)

    # --------------------------------------------------- disaggregation
    def prefill_to_span(self, request: GenerationRequest,
                        budget: Optional[int] = None) -> KVSpan:
        """Prefill-role entry: run ``request``'s prefill into scratch
        pool blocks (chunked under ``budget``, or this engine's own
        per-step budget), lift the KV out as a ``KVSpan``, and free the
        blocks.  The span carries the prompt KV in POOL dtype plus the
        first sampled token/logprob, so a decode-role engine admits the
        request via ``submit_span`` without recomputing anything."""
        if not self.paged:
            raise ValueError("prefill_to_span requires paged=True")
        feed = np.asarray(request.prompt, np.int32)
        S = len(feed)
        blocks = self.slots.pool.alloc(self.slots.blocks_for(S))
        try:
            offset, tok0, lp0 = 0, None, None
            step = budget if budget is not None else self._prefill_budget()
            while offset < S:
                w = S - offset if step is None else min(step, S - offset)
                tok0, lp0 = self._ctx_chunk(feed, offset, w, blocks,
                                            request.sampling)
                offset += w
            self.stats["prefills"] += 1
            bl = np.asarray(blocks)
            kv = {k: np.asarray(self.cache[k][:, bl]) for k in self.cache}
        finally:
            self.slots.pool.free(blocks)
        return KVSpan(prompt=feed, first_token=int(np.asarray(tok0)[0]),
                      first_logprob=float(np.asarray(lp0)[0]),
                      block_size=self.block_size, kv=kv)

    def submit_span(self, request: GenerationRequest, span: KVSpan,
                    on_token=None) -> RequestHandle:
        """Decode-role entry: queue a request whose prefill already ran
        on another engine; admission rehydrates the span's blocks into
        the local pool instead of prefilling."""
        if not self.paged:
            raise ValueError("submit_span requires paged=True")
        if span.block_size != self.block_size:
            raise ValueError(
                f"span block_size {span.block_size} != engine "
                f"block_size {self.block_size}")
        self._spans[request.req_id] = span
        self.queue.submit(self.slots.validate(request))
        return self._handle_for(request, on_token=on_token)

    def _admit_span(self, req: GenerationRequest, span: KVSpan,
                    now: float) -> None:
        """Rehydrate a handed-off prefill: scatter the span's block KV
        (already pool-dtype) into freshly allocated local blocks and
        admit the slot decode-ready at pos = prompt length.

        The scatter is the fused static-signature ``_scatter_span``:
        the span KV is padded host-side to the table-width worst case
        (pad blocks route to junk block 0), so spans of EVERY length
        share one compiled donate-in-place executable — the per-block-
        count specializing ``_scatter`` recompiled (and re-traversed
        the whole pool for) each distinct span size."""
        S = len(span.prompt)
        blocks = self.slots.pool.alloc(self.slots.blocks_for(S))
        W = self.slots.table_width
        phys = np.zeros((W,), np.int32)
        phys[:len(blocks)] = blocks
        part = {}
        for k, v in span.kv.items():   # (L, n_blocks, block_size, ...)
            pad = np.zeros((v.shape[0], W - v.shape[1]) + v.shape[2:],
                           v.dtype)
            part[k] = jnp.asarray(np.concatenate([v, pad], axis=1))
        self.cache = self._scatter_span(self.cache, part,
                                        jnp.asarray(phys),
                                        jnp.int32(len(blocks)))
        slot = self.slots.admit(req, span.first_token, blocks=blocks,
                                first_logprob=span.first_logprob, pos=S)
        if self.prefix_cache:
            self.slots.register_full_blocks(slot, span.prompt)
        self.stats["spans_admitted"] += 1
        self._post_admit(slot, req, now)

    def _sync_handle(self, slot: Slot, now: float) -> None:
        """Stream any not-yet-emitted tokens to the request's handle.
        Resume replays stashed tokens into the slot; the handle's
        already-pushed count keeps them from re-emitting."""
        handle = self._handles.get(slot.request.req_id)
        if handle is None:
            return
        start = len(handle.tokens)
        for tok, lp in zip(slot.tokens[start:], slot.logprobs[start:]):
            handle._push(int(tok), now, lp)

    def _finalize(self, handle: RequestHandle, reason: str,
                  now: float) -> None:
        out = handle._finish(reason, now)
        self.results[handle.req_id] = out
        self._latency_window.append((out.ttft_s, out.tpot_s))
        if self.on_finish is not None:
            self.on_finish(handle, out)

    def _finish(self, slot: Slot, now: float = 0.0) -> None:
        self._sync_handle(slot, now)
        reason = (FINISH_STOP
                  if slot.tokens and slot.request.stops(slot.tokens[-1])
                  and len(slot.tokens) <= slot.request.max_new_tokens
                  else FINISH_LENGTH)
        self._finalize(self._handle_for(slot.request), reason, now)
        self.slots.release(slot)

    # ----------------------------------------------------------- decode
    def _preempt(self, slot: Slot) -> None:
        """Evict a live slot to relieve pool pressure: stash its generated
        tokens (+ logprobs), free its blocks, requeue the request at the
        front.  The resume path re-prefills prompt+generated, so output
        is unchanged (sampled tokens replay from the stash; sampling
        keys depend only on (seed, position), so post-resume draws are
        unchanged too).

        A slot caught MID-CHUNKED-PREFILL has no generated tokens to
        stash — stashing its empty token list would corrupt resume.
        Instead, re-stash only the original decode-preemption replay it
        was carrying (if any).  Its private blocks free; its REGISTERED
        full blocks park in the pool's cached set (prefix-matched
        shared ones just drop our reference), so resume re-matches them
        and restarts from the completed-chunk offset, not token 0."""
        if slot.prefilling:
            if slot.prefill.resume is not None:
                self._resume[slot.request.req_id] = slot.prefill.resume
            else:
                self._resume.pop(slot.request.req_id, None)
        else:
            self._resume[slot.request.req_id] = (list(slot.tokens),
                                                 list(slot.logprobs))
        self.slots.preempt(slot)
        self.queue.requeue(slot.request)

    def _ensure_decode_blocks(self) -> None:
        """Before a paged decode step, every active slot whose next write
        crosses into a new block must hold one.  Oldest slots allocate
        first; if the pool runs dry the YOUNGEST other slot is preempted
        (least work lost).  Forward progress is guaranteed: a lone slot's
        worst-case block count fits the pool (validate()), so its growth
        can always be satisfied once neighbours are evicted."""
        for slot in sorted(self.slots.active.values(), key=lambda s: s.seq):
            if self.slots.active.get(slot.index) is not slot:
                continue                   # preempted earlier this pass
            if slot.prefilling:
                continue   # holds every block up front; still a victim
            if self.slots.needs_block(slot):
                while not self.slots.pool.free_blocks():
                    victims = [s for s in self.slots.active.values()
                               if s is not slot]
                    assert victims, "validate() bounds a lone slot to the pool"
                    self._preempt(max(victims, key=lambda s: s.seq))
                slot.blocks.extend(self.slots.pool.alloc(1))
            elif self.prefix_cache:
                # defense in depth: decode normally only ever writes its
                # own private tail block (admission forks the re-fed
                # tail), but if the write target is somehow shared, fork
                # it copy-on-write rather than corrupt the sharers
                blk_idx = slot.pos // self.slots.block_size
                if self.slots.pool.refcount.get(slot.blocks[blk_idx],
                                                0) > 1:
                    while not self.slots.pool.free_blocks():
                        victims = [s for s in self.slots.active.values()
                                   if s is not slot]
                        assert victims, "a lone slot shares with no one"
                        self._preempt(max(victims, key=lambda s: s.seq))
                blocks, copy = self.slots.ensure_writable(slot.blocks,
                                                          blk_idx)
                if copy is not None:
                    src, dst = copy
                    self.cache = self._copy_block(self.cache,
                                                  jnp.int32(dst),
                                                  jnp.int32(src))
                    slot.blocks = blocks
                    slot.block_hashes = slot.block_hashes[:blk_idx]

    def _decode_step(self) -> None:
        if self.paged:
            self._ensure_decode_blocks()
        active = self.slots.active_slots()
        if not active:                     # everything was preempted
            return
        batch = {"tokens": jnp.asarray(self.slots.token_vector()),
                 "index": jnp.asarray(self.slots.index_vector()),
                 **self.slots.sampling_vectors()}
        if self.paged:
            batch["block_table"] = jnp.asarray(self.slots.block_table())
        if self.runtime is not None:
            toks, logps, self.cache = self.runtime.call(
                self._decode_name, self.params, self.cache, batch)
            toks = np.asarray(toks)        # (B,) sampled in-graph
        else:
            t0 = time.perf_counter()
            toks, logps, self.cache = self._decode(self.params, self.cache,
                                                   batch)
            toks = np.asarray(toks)        # forces completion
            ms = (time.perf_counter() - t0) * 1e3
            tgt = "accel" if self._direct_impl == "pallas" else "host"
            self._direct_step_ms[tgt] = ewma(self._direct_step_ms[tgt], ms)
        self.stats["decode_steps"] += 1
        self.stats["decode_row_util"] += len(active) / self.slots.max_slots
        logps = np.asarray(logps)
        now = self._now()
        for slot in active:
            t = int(toks[slot.index])
            slot.tokens.append(t)
            slot.logprobs.append(float(logps[slot.index]))
            slot.last_token = t
            slot.pos += 1
            slot.t_last_token = now
            if (self.prefix_cache
                    and slot.pos % self.slots.block_size == 0):
                # a block just filled: key it in the prefix index so a
                # follow-up request sharing prompt+generated matches it
                self.slots.register_full_blocks(slot,
                                                self._kv_tokens(slot))
            self._sync_handle(slot, now)
            if slot.done:
                self._finish(slot, now)

    # ------------------------------------------------ speculative decode
    def _draft_len(self) -> int:
        """Draft length k for this round: the policy's ``draft_len``
        hook (fed live signals) when it has one, else the engine's
        configured ``spec_draft_len``.  0 disables speculation for the
        step (the loop falls back to plain decode); the result is
        clamped to the compiled verify width."""
        if self.spec is None:
            return 0
        policy = self.policy
        if policy is None and self.runtime is not None:
            policy = self.runtime.server.policy
        hook = getattr(policy, "draft_len", None)
        k = (hook(self.signals(), self.spec.draft_len)
             if hook is not None else self.spec.draft_len)
        return max(0, min(int(k), self.spec.draft_len))

    def _ensure_spec_blocks(self, k: int) -> Optional[dict[int, int]]:
        """Pre-reserve the speculative fan-out: every decode-ready slot
        must hold blocks backing positions ``[pos, pos + n)`` BEFORE the
        round writes up to n candidate positions of KV.  Returns
        {slot.index: n} with per-slot n = min(k, remaining token
        budget); when the pool cannot cover a slot's fan-out, its n
        shrinks to the capacity it already holds rather than preempting
        mid-round — and if any slot ends at n < 1, returns None so the
        caller falls back to ``_decode_step`` (whose preempt-youngest
        loop guarantees progress).  Rejection later never frees these
        blocks (see ``PagedSlotManager.fanout_blocks``); shared blocks
        in the write range fork copy-on-write first, exactly like the
        plain decode path."""
        bs = self.slots.block_size
        plan: dict[int, int] = {}
        for slot in self.slots.active_slots():
            rem = slot.request.max_new_tokens - len(slot.tokens)
            n = min(k, max(rem, 0))
            need = self.slots.fanout_blocks(slot, n)
            if need > self.slots.pool.free_blocks():
                # pool short: spend only the capacity already held
                n = min(n, len(slot.blocks) * bs - slot.pos)
                need = 0
            if n < 1:
                return None
            if need:
                slot.blocks.extend(self.slots.pool.alloc(need))
            if self.prefix_cache:
                # a spec round writes a RANGE of blocks, not just the
                # tail — COW-fork any shared one before the scatter
                # touches it (fresh fan-out blocks are private already)
                for bi in range(slot.pos // bs, (slot.pos + n - 1) // bs
                                + 1):
                    if self.slots.pool.refcount.get(slot.blocks[bi],
                                                    0) <= 1:
                        continue
                    try:
                        blocks, copy = self.slots.ensure_writable(
                            slot.blocks, bi)
                    except RuntimeError:
                        return None    # no block for the fork: fall back
                    if copy is not None:
                        src, dst = copy
                        self.cache = self._copy_block(
                            self.cache, jnp.int32(dst), jnp.int32(src))
                        slot.blocks = blocks
                        slot.block_hashes = slot.block_hashes[:bi]
            plan[slot.index] = n
        return plan or None

    def _refresh_draft(self, slot: Slot) -> None:
        """Rebuild one slot's draft-cache row from its committed tokens
        (fresh admission, preempt/resume, or any round the fingerprint
        misses): a bucketed dense draft prefill over ``_kv_tokens``,
        written into the row — after which positions < pos are valid
        and the chain may extend from there."""
        toks = self._kv_tokens(slot)
        S = len(toks)
        Sb = prompt_bucket(S, self.min_bucket)
        arr = np.zeros((1, Sb), np.int32)
        arr[0, :S] = toks
        batch = {"tokens": jnp.asarray(arr),
                 "length": jnp.full((1,), S, jnp.int32),
                 **sampling_leaves(SamplingParams(), 1)}
        _, _, pc = self._draft_prefill(self.spec.params, batch)
        if Sb > self._spec_width:      # bucket overhangs the row
            pc = {k: jax.lax.slice_in_dim(pc[k], 0, self._spec_width,
                                          axis=2) for k in pc}
        self.spec.cache = self._write_slot(self.spec.cache, pc,
                                           jnp.int32(slot.index))
        self.spec.mark(slot.index, slot.request.req_id, slot.pos)

    def _spec_put(self, key: str, arr: np.ndarray):
        """Device copy of a small per-round host vector, reused across
        rounds while its CONTENT is unchanged (content-addressed, so it
        can never serve stale values): sampling leaves only change on
        admission/finish, the block table every block_size tokens,
        n_valid on plan changes — re-uploading them every round was a
        measurable slice of the round's host overhead."""
        ent = self._spec_dev_cache.get(key)
        b = arr.tobytes()
        if ent is not None and ent[0] == b:
            return ent[1]
        dev = jnp.asarray(arr)
        self._spec_dev_cache[key] = (b, dev)
        return dev

    def _spec_step(self, k: int) -> bool:
        """One speculative round: fused k-step draft chain, then ONE
        multi-token verify, then host-side longest-accepted-prefix
        acceptance.  Emits 1..k tokens per slot — every emitted token
        is VERIFY'S OWN sample at its position, so output is
        byte-identical to sequential decode on any target split.
        Returns False (round not run) when the fan-out cannot be
        reserved; the caller then takes the plain decode path."""
        plan = self._ensure_spec_blocks(k)
        if plan is None:
            return False
        active = [s for s in self.slots.active_slots()
                  if s.index in plan]
        if not active:
            return False
        for slot in active:
            if not self.spec.valid_for(slot.index, slot.request.req_id,
                                       slot.pos):
                self._refresh_draft(slot)
        B, W = self.slots.max_slots, self.spec.draft_len
        n_valid = np.zeros((B,), np.int32)
        for slot in active:
            n_valid[slot.index] = plan[slot.index]
        n_steps = int(n_valid.max())
        # 1 fused dispatch: the chain runs n_steps draft decodes,
        # feeding each sample back in and writing draft KV as it goes.
        # Convert the host vectors ONCE — tokens/index double as the
        # verify batch's last/pos (index_vector IS slot.pos), and the
        # sampling leaves are shared by both dispatches.
        tokvec = jnp.asarray(self.slots.token_vector())
        idxvec = jnp.asarray(self.slots.index_vector())
        sv = {k: self._spec_put("sv_" + k, v)
              for k, v in self.slots.sampling_vectors().items()}
        dbatch = {"tokens": tokvec, "index": idxvec,
                  "n_steps": jnp.int32(n_steps), **sv}
        if self.runtime is not None:
            drafts, _, self.spec.cache = self.runtime.call(
                self._draft_name, self.spec.params, self.spec.cache,
                dbatch)
        else:
            drafts, _, self.spec.cache = self._draft_chain(
                self.spec.params, self.spec.cache, dbatch)
        # 1 fused dispatch: verify feeds [t0, d_1..d_{n-1}] at offset
        # pos and samples the target's token at EVERY position.  The
        # drafts stay ON DEVICE — spec_verify_batch assembles the
        # token/offset/length feed in-graph, so the chain->verify edge
        # never round-trips through the host and the only sync in the
        # round is pulling verify's samples below.
        vbatch = {"drafts": drafts, "last": tokvec, "pos": idxvec,
                  "n_valid": self._spec_put("n_valid", n_valid),
                  "block_table": self._spec_put(
                      "bt", self.slots.block_table()),
                  **sv}
        if self.runtime is not None:
            vtoks, vlogps, self.cache = self.runtime.call(
                self._verify_name, self.params, self.cache, vbatch)
            vtoks = np.asarray(vtoks)
        else:
            t0 = time.perf_counter()
            vtoks, vlogps, self.cache = self._verify(self.params,
                                                     self.cache, vbatch)
            vtoks = np.asarray(vtoks)      # forces chain + verify
            ms = (time.perf_counter() - t0) * 1e3
            tgt = "accel" if self._direct_impl == "pallas" else "host"
            self._direct_step_ms[tgt] = ewma(self._direct_step_ms[tgt],
                                             ms)
        drafts = np.asarray(drafts)        # (B, W): col i = d_{i+1}
        vlogps = np.asarray(vlogps)
        emit = spec_lib.acceptance_lengths(drafts[:, :max(W - 1, 0)],
                                           vtoks, n_valid)
        now = self._now()
        self.stats["spec_rounds"] += 1
        for slot in active:
            i, n, e = slot.index, int(n_valid[slot.index]), 0
            self.stats["spec_proposed_tokens"] += n - 1
            self.stats["spec_accepted_tokens"] += emit[i] - 1
            for j in range(emit[i]):
                t = int(vtoks[i, j])
                slot.tokens.append(t)
                slot.logprobs.append(float(vlogps[i, j]))
                slot.last_token = t
                slot.pos += 1
                e += 1
                if (self.prefix_cache
                        and slot.pos % self.slots.block_size == 0):
                    self.slots.register_full_blocks(
                        slot, self._kv_tokens(slot))
                if slot.request.stops(t):
                    # sequential decode would have finished HERE: the
                    # accepted tail past a stop token must not emit
                    break
            self.stats["spec_emitted_tokens"] += e
            slot.t_last_token = now
            # draft KV through the new pos holds exactly the committed
            # tokens' keys (accepted drafts == verify samples), so the
            # next round extends without re-prefilling
            self.spec.mark(i, slot.request.req_id, slot.pos)
            self._sync_handle(slot, now)
            if slot.done:
                self._finish(slot, now)
        return True

    def _kv_tokens(self, slot: Slot) -> list[int]:
        """Tokens whose KV the slot's blocks hold, in position order:
        prompt then generated (the decode at step k writes token k's KV
        at its position before sampling token k+1), truncated to the
        written span.  Holds across resume too — the resume feed is
        prompt + replayed[:-1], a prefix of prompt + tokens."""
        return (list(slot.request.prompt) + slot.tokens)[:slot.pos]

    # ------------------------------------------------------- serve loop
    def run(self, requests: Iterable[GenerationRequest] = (),
            poll_s: float = 0.002) -> dict[int, RequestOutput]:
        """Drain ``requests`` plus anything already submitted; returns
        {req_id: RequestOutput} for the requests completed by THIS call
        (``self.results`` is drained, so a long-lived engine doesn't
        accumulate finished outputs; aborts serviced between calls are
        included).  Arrival times are relative to this call's start.

        If the loop raises, every unfinished handle is finished as
        ``aborted`` before re-raising, so streaming consumers blocked on
        another thread never hang on a dead engine loop."""
        try:
            for r in requests:
                self.queue.submit(self.slots.validate(r))
                self._handle_for(r)
            self._clock0 = time.perf_counter()
            while len(self.queue) or self.slots.active:
                now = self._now()
                self._service_aborts(now)
                # publish BEFORE admission: the policy deciding this
                # iteration's steps sees the arrived-but-unadmitted
                # pressure, and a central scheduler sees it cross-engine
                self._publish_signals()
                self._step_budget = (self._prefill_budget()
                                     if self._chunking else None)
                while self.slots.has_free():
                    req = self.queue.pop_arrived(now)
                    if req is None:
                        break
                    if not self._can_admit(req):
                        # block-exhaustion backpressure: head-of-queue
                        # waits (front of its arrival cohort) for blocks
                        self.queue.requeue(req)
                        break
                    self._admit(req, now)
                if self._chunking:
                    self._advance_prefills(self._step_budget)
                if self.slots.active:
                    # speculative round when enabled and the policy's
                    # draft_len allows it (k=0 = plain decode); a round
                    # that cannot reserve its fan-out also falls back —
                    # _decode_step's preempt loop guarantees progress
                    stepped = False
                    if self.spec is not None:
                        k = self._draft_len()
                        if k >= 1:
                            stepped = self._spec_step(k)
                    if not stepped:
                        self._decode_step()
                    if self.on_step is not None:
                        self.on_step(self)
                else:
                    nxt = self.queue.next_arrival()
                    if nxt is None:
                        break
                    time.sleep(min(max(nxt - now, 0.0), 0.05) + poll_s)
        except BaseException:
            for h in list(self._handles.values()):
                if not h.finished:
                    self._finalize(h, FINISH_ABORTED, self._now())
            raise
        finally:
            self._clock0 = None
            # retract this run's pressure: without a final snapshot the
            # scheduler would keep aggregating the last IN-RUN publish
            # (nonzero queue/slots) long after this engine went idle,
            # and co-tenants would migrate against phantom load
            self._publish_signals()
        out, self.results = self.results, {}
        for rid in out:
            self._handles.pop(rid, None)
        return out

    def serve(self, requests: Iterable[GenerationRequest] = (),
              poll_s: float = 0.002):
        """Removed v1 surface (was a deprecation shim until PR 5)."""
        raise RuntimeError(
            "ContinuousBatchingEngine.serve() was removed; use run() — "
            "it returns {req_id: RequestOutput} (RequestOutput.tokens "
            "is the old bare array)")

    def generate(self, prompts, max_new_tokens: int = 16,
                 sampling: Optional[SamplingParams] = None) -> np.ndarray:
        """ServeEngine.generate-compatible convenience: all prompts
        arrive at t=0; returns (B, max_new_tokens) tokens in order.
        (Stop-token requests can return ragged lengths — use run().)"""
        reqs = [GenerationRequest(np.asarray(p), max_new_tokens,
                                  sampling=sampling or SamplingParams())
                for p in np.asarray(prompts)]
        out = self.run(reqs)
        return np.stack([out[r.req_id].tokens for r in reqs])
