"""OS-process cluster serving: real workers, a streaming result plane,
and a fault-tolerant supervisor.

The threaded cluster (``serve/cluster.py``) shares one Python process,
so the GIL caps its scaling and one worker's crash is everyone's crash.
This module promotes each worker to a real OS process (``spawn``, not
``fork`` — its own JAX runtime, registry, kernel bank and
``ContinuousBatchingEngine``), connected to the parent by two planes:

* **Control plane** — the existing line-JSON TCP scheduler transport
  (``core/scheduler.py``).  Each worker's runtime clients talk to the
  parent's central ``SchedulerServer`` (``request``/``report``/
  ``publish``), workers report their kernel-bank residency with the
  ``kernel`` op (the central server cannot query a bank in another
  address space), liveness beats ride the ``heartbeat`` op, and
  disaggregated KV spans ride ``handoff`` exactly as in the threaded
  cluster.
* **Result plane** — a NEW full-duplex line-JSON socket per worker,
  carrying commands down (``submit``/``abort``/``prefill``/``span``/
  ``warmup``/``reset``/``summary``/``stop``) and streaming events up:

      {"ev": "token", "req": id, "i": abs_index, "t": tok, "lp": lp}
      {"ev": "finish", "req": id, "tokens": [...], "logprobs": [...],
       "finish_reason": "stop|length|aborted", "queue_wait_s": s}

  The parent rehydrates its ``RequestHandle`` for the request from
  these events (``RequestHandle.apply_event``), so streaming
  iteration, ``on_token`` callbacks, ``result()`` and ``abort()`` keep
  their exact v2 semantics across the process boundary.  Token events
  carry the ABSOLUTE index so a re-routed request's replayed prefix
  dedups instead of double-emitting.

**Fault tolerance** — ``ClusterSupervisor`` owns worker lifecycle:
spawn, warmup, per-worker heartbeat deadlines, and failure handling.
A worker is declared dead when its result-plane socket hits EOF, its
process exits, or its heartbeat goes silent past the liveness deadline
(stragglers are killed, not waited out).  The dead worker's in-flight
requests re-route to the least-loaded survivor via
resume-by-re-prefill: the parent hands the survivor the prompt plus
every token already streamed, the survivor re-prefills
prompt + tokens[:-1] and replays the stash (``submit_resume``), and —
because sampling keys depend only on (seed, position) — the
continuation is byte-identical to a run with no failure at all.

``ProcClusterFrontEnd`` presents the same ``submit``/``warmup``/
``drain``/``summary`` surface as ``ClusterFrontEnd``, including the
prefill/decode role split over real processes.  All model parameters
are rebuilt deterministically in each worker from the shared seed
(``model.init(PRNGKey(seed))``), so every process serves identical
weights without shipping arrays over a pipe.
"""
from __future__ import annotations

import base64
import collections
import dataclasses
import itertools
import json
import queue as queue_lib
import socket
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.policy import PolicyLike
from repro.core.monitor import LoadMonitor
from repro.core.scheduler import (
    SchedulerServer, TcpSchedulerClient, TcpSchedulerServer,
)
from repro.core.targets import Platform, TPU_PLATFORM
from repro.core.thresholds import ThresholdTable
from repro.serve.api import (
    FINISH_ABORTED, GenerationRequest, RequestHandle, RequestOutput,
    SamplingParams,
)
from repro.serve.cluster import WORKER_ROLES

# worker-internal requests (warmup) live above this id so they can never
# collide with parent-assigned req_ids (both processes count from 0)
_INTERNAL_RID_BASE = 1_000_000_000


def _req_to_wire(req: GenerationRequest) -> dict:
    return {"req_id": int(req.req_id),
            "prompt": np.asarray(req.prompt, np.int32).tolist(),
            "max_new_tokens": int(req.max_new_tokens),
            "arrival_s": 0.0,     # parent routes on submit; no deferral
            "stop_tokens": [int(t) for t in req.stop_tokens],
            "sampling": dataclasses.asdict(req.sampling)}


def _req_from_wire(msg: dict) -> GenerationRequest:
    return GenerationRequest(
        np.asarray(msg["prompt"], np.int32),
        max_new_tokens=msg["max_new_tokens"],
        arrival_s=msg.get("arrival_s", 0.0),
        stop_tokens=tuple(msg.get("stop_tokens", ())),
        sampling=SamplingParams(**msg["sampling"]),
        req_id=msg["req_id"])


def _jsonable(x):
    """Wire-safe copy: enum/tuple keys stringify, numpy scalars box."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    return x


# --------------------------------------------------------- worker process

def _worker_main(worker_id: str, cfg, seed: int, engine_kwargs: dict,
                 scheduler_addr: tuple, result_addr: tuple, role: str,
                 heartbeat_interval_s: float) -> None:
    """Entry point of one spawned worker process.

    Order matters: the result-plane ``hello`` and the heartbeat thread
    start BEFORE the engine builds, so the multi-second JAX compile at
    boot is never mistaken for a dead worker, and the parent's accept
    loop can match this connection to its worker slot immediately."""
    sock = socket.create_connection(result_addr)
    rfile = sock.makefile("r")
    wfile = sock.makefile("w")
    send_lock = threading.Lock()

    def send(obj: dict) -> None:
        try:
            with send_lock:
                wfile.write(json.dumps(obj) + "\n")
                wfile.flush()
        except OSError:        # parent gone: nothing left to report to
            pass

    send({"ev": "hello", "worker": worker_id})

    hb_stop = threading.Event()

    def beat() -> None:
        try:
            client = TcpSchedulerClient(f"{worker_id}_hb", scheduler_addr)
        except OSError:
            return
        seq = 0
        while not hb_stop.wait(heartbeat_interval_s):
            try:
                client.heartbeat(worker_id, seq)
                seq += 1
            except Exception:  # noqa: BLE001 — scheduler gone: stop beating
                break
        client.close()

    threading.Thread(target=beat, daemon=True,
                     name=f"{worker_id}-heartbeat").start()

    # heavy imports deferred past hello/heartbeat so boot liveness does
    # not wait on jax initialisation
    from repro.core.function import FunctionRegistry
    from repro.core.runtime import XarTrekRuntime
    from repro.serve.batch import KVSpan
    from repro.serve.engine import ContinuousBatchingEngine

    runtime = XarTrekRuntime(registry=FunctionRegistry(),
                             scheduler_address=scheduler_addr)
    # params=None + shared seed: every worker rebuilds IDENTICAL weights
    # deterministically instead of receiving arrays over the pipe
    engine = ContinuousBatchingEngine(cfg, params=None, seed=seed,
                                      runtime=runtime,
                                      fn_prefix=worker_id, **engine_kwargs)
    ctl = TcpSchedulerClient(f"{worker_id}_ctl", scheduler_addr)
    for name in runtime.binaries:
        # push this process's bank state to the central server (it
        # cannot query across the address-space boundary)
        ctl.register_remote_kernel(name, name,
                                   runtime.bank.is_resident(name),
                                   runtime.bank.is_loading(name))

    internal_rid = itertools.count(_INTERNAL_RID_BASE).__next__
    stop = threading.Event()
    wake = threading.Event()
    prefill_q: collections.deque = collections.deque()
    warmup_q: collections.deque = collections.deque()

    def emit_token(handle: RequestHandle):
        def on_token(tok: int) -> None:
            send({"ev": "token", "req": handle.req_id,
                  "i": len(handle.tokens) - 1, "t": int(tok),
                  "lp": float(handle.logprobs[-1])
                  if handle.logprobs else 0.0})
        return on_token

    def on_finish(handle: RequestHandle, out) -> None:
        if handle.req_id >= _INTERNAL_RID_BASE:
            return                       # warmup traffic stays local
        send({"ev": "finish", "req": handle.req_id,
              "tokens": [int(t) for t in out.tokens],
              "logprobs": [float(x) for x in handle.logprobs],
              "finish_reason": out.finish_reason,
              "queue_wait_s": float(out.queue_wait_s)})

    engine.on_finish = on_finish

    def accept(msg: dict, resume_tokens=(), resume_logprobs=None,
               span=None) -> None:
        """Register the handle (with its emitter) BEFORE queueing, so a
        token admitted by an already-running engine loop can never beat
        the emitter attachment; a validation failure reports as an
        aborted finish instead of dying silently in another process."""
        req = _req_from_wire(msg)
        handle = engine._handle_for(req)
        handle.on_token = emit_token(handle)
        try:
            if span is not None:
                engine.submit_span(req, span)
            else:
                engine.submit_resume(req, resume_tokens, resume_logprobs)
        except Exception as e:  # noqa: BLE001 — report, keep serving
            engine._handles.pop(req.req_id, None)
            send({"ev": "finish", "req": req.req_id, "tokens": [],
                  "logprobs": [], "finish_reason": FINISH_ABORTED,
                  "queue_wait_s": 0.0, "error": str(e)})
            return
        wake.set()

    def summary_dict() -> dict:
        d = {"worker": worker_id, "role": role,
             "engine_stats": _jsonable(engine.stats),
             "runtime": _jsonable(runtime.summary())}
        if engine.paged:
            pool = engine.slots.pool
            d["pool"] = {"num_blocks": int(pool.num_blocks),
                         "free_blocks": int(pool.free_blocks()),
                         "cached_blocks": int(pool.cached_blocks())}
        return d

    def read_loop() -> None:
        try:
            for line in rfile:
                msg = json.loads(line)
                cmd = msg.get("cmd")
                if cmd == "submit":
                    accept(msg["req"],
                           resume_tokens=msg.get("resume_tokens") or (),
                           resume_logprobs=msg.get("resume_logprobs"))
                elif cmd == "abort":
                    engine.abort(int(msg["req"]))
                    wake.set()
                elif cmd == "prefill":
                    prefill_q.append((_req_from_wire(msg["req"]),
                                      msg["dest"]))
                    wake.set()
                elif cmd == "span":
                    accept(msg["req"], span=KVSpan.from_bytes(
                        base64.b64decode(msg["payload"])))
                elif cmd == "warmup":
                    warmup_q.append(msg)
                    wake.set()
                elif cmd == "reset":
                    runtime.call_log.clear()
                    engine.reset_stats()
                    send({"ev": "reset_done", "worker": worker_id})
                elif cmd == "summary":
                    send({"ev": "summary", "worker": worker_id,
                          "data": summary_dict()})
                elif cmd == "stop":
                    break
        except (OSError, ValueError):
            pass
        finally:
            stop.set()                   # EOF/parent death: shut down
            wake.set()

    threading.Thread(target=read_loop, daemon=True,
                     name=f"{worker_id}-reader").start()

    def do_warmup(msg: dict) -> None:
        vocab = max(getattr(cfg, "vocab_size", 2), 2)
        reqs = [GenerationRequest(np.arange(1, 5, dtype=np.int32) % vocab,
                                  max_new_tokens=2, req_id=internal_rid())]
        mp = int(msg.get("max_prompt") or 0)
        if mp > 8:
            # pre-compile the longest prompt bucket the caller will use
            reqs.append(GenerationRequest(
                np.arange(1, mp + 1, dtype=np.int32) % vocab,
                max_new_tokens=2, req_id=internal_rid()))
        for r in reqs:
            engine.submit(r)
        engine.run()
        runtime.call_log.clear()
        engine.reset_stats()
        send({"ev": "warmed", "worker": worker_id})

    send({"ev": "ready", "worker": worker_id})
    try:
        while not stop.is_set():
            busy = False
            while warmup_q:
                do_warmup(warmup_q.popleft())
                busy = True
            while prefill_q:
                req, dest = prefill_q.popleft()
                engine._publish_signals()
                payload = engine.prefill_to_span(req).to_bytes()
                ctl.handoff(dest, req.req_id, payload)
                busy = True
            if len(engine.queue) or engine.slots.active:
                engine.run()
                busy = True
            if not busy:
                wake.wait(timeout=0.02)
                wake.clear()
    except Exception as e:  # noqa: BLE001 — last words, then die
        send({"ev": "error", "worker": worker_id, "error": repr(e)})
        raise
    finally:
        hb_stop.set()
        try:
            sock.close()
        except OSError:
            pass


# ----------------------------------------------------------- parent side

class ProcessEngineWorker:
    """Parent-side proxy for one spawned worker process.

    Owns the process handle and the result-plane connection; ``owned``
    is the set of parent req_ids currently routed here (the routing
    weight AND the re-route worklist if this worker dies)."""

    def __init__(self, worker_id: str, role: str, process):
        self.worker_id = worker_id
        self.role = role
        self.process = process
        self.sock: Optional[socket.socket] = None
        self._rfile = None
        self._wfile = None
        self._send_lock = threading.Lock()
        self.ready = threading.Event()
        self.warmed = threading.Event()
        self.reset_done = threading.Event()
        self.dead = threading.Event()    # result-plane EOF / send failure
        self.failed = False              # supervisor verdict (final)
        self.summaries: queue_lib.Queue = queue_lib.Queue()
        self.owned: set[int] = set()     # unfinished parent req_ids here
        self.pending_prefills = 0        # span jobs routed here (prefill)

    def attach(self, sock: socket.socket) -> None:
        self.sock = sock
        self._rfile = sock.makefile("r")
        self._wfile = sock.makefile("w")

    def send(self, obj: dict) -> bool:
        """Best-effort command write; a broken pipe marks the worker
        dead (the supervisor picks it up) instead of raising into the
        caller's submit path."""
        if self._wfile is None or self.dead.is_set():
            return False
        try:
            with self._send_lock:
                self._wfile.write(json.dumps(obj) + "\n")
                self._wfile.flush()
            return True
        except OSError:
            self.dead.set()
            return False

    def alive(self) -> bool:
        return (not self.failed and not self.dead.is_set()
                and self.process.is_alive())

    def load(self) -> int:
        return len(self.owned)

    def kill(self) -> None:
        if self.process.is_alive():
            self.process.kill()          # SIGKILL: no cleanup, no mercy
        self.process.join(timeout=10.0)
        self.close()

    def close(self) -> None:
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass


class ClusterSupervisor:
    """Worker lifecycle daemon: liveness via result-plane EOF, process
    exit, and heartbeat deadlines; failures hand the dead worker's
    in-flight requests back to the front-end for re-routing.

    A straggler (no heartbeat within ``liveness_deadline_s`` of the
    previous one, measured on the parent's clock at the central
    scheduler) is treated as failed: it is killed first, so a wedged
    process can never hold requests hostage while technically alive."""

    def __init__(self, front, liveness_deadline_s: float,
                 poll_s: float = 0.05):
        self.front = front
        self.liveness_deadline_s = liveness_deadline_s
        self.poll_s = poll_s
        self.stragglers = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="cluster-supervisor")

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)

    def _run(self) -> None:
        while not self._stop.wait(self.poll_s):
            for w in self.front.workers:
                if w.failed:
                    continue
                straggling = self._straggling(w)
                if (w.dead.is_set() or not w.process.is_alive()
                        or straggling):
                    if straggling:
                        self.stragglers += 1
                    self.front._on_worker_failure(w)

    def _straggling(self, w: ProcessEngineWorker) -> bool:
        beat = self.front.server.heartbeats.get(w.worker_id)
        if beat is None:
            return False     # no beat yet: spawn grace, EOF covers death
        return (time.monotonic() - beat["t"]) > self.liveness_deadline_s


class ProcClusterFrontEnd:
    """N OS-process engine workers, one central scheduler, one
    ``submit()`` surface — the ``ClusterFrontEnd`` contract over real
    processes, plus fault tolerance.

    The scheduler control plane is ALWAYS the TCP transport (an
    in-process server cannot cross address spaces).  ``roles`` enables
    the prefill/decode split exactly as in the threaded cluster; spans
    travel over the central ``handoff`` op and are forwarded to the
    decode owner's process as a ``span`` command.

    ``heartbeat_interval_s``/``liveness_deadline_s`` tune failure
    detection; the deadline should be several beats deep so one
    GC pause or scheduler hiccup is not a death sentence.  On failure,
    every in-flight request owned by the dead worker re-submits to the
    least-loaded survivor with its already-streamed tokens as a resume
    stash — greedy/seeded output is byte-identical to the no-failure
    run (see ``ContinuousBatchingEngine.submit_resume``).  ``summary()``
    reports ``failures`` and ``rerouted`` counts.
    """

    def __init__(self, cfg, n_workers: int = 2,
                 policy: PolicyLike = "xartrek",
                 platform: Platform = TPU_PLATFORM,
                 table: Optional[ThresholdTable] = None,
                 seed: int = 0, worker_prefix: str = "pw",
                 roles: Optional[Sequence[str]] = None,
                 heartbeat_interval_s: float = 0.25,
                 liveness_deadline_s: float = 10.0,
                 spawn_timeout_s: float = 300.0,
                 **engine_kwargs):
        if n_workers < 1:
            raise ValueError(f"need at least one worker: {n_workers}")
        if roles is None:
            roles = ("mixed",) * n_workers
        roles = tuple(roles)
        if len(roles) != n_workers:
            raise ValueError(f"roles {roles} must name all "
                             f"{n_workers} workers")
        if any(r not in WORKER_ROLES for r in roles):
            raise ValueError(f"roles must be in {WORKER_ROLES}: {roles}")
        if not any(r in ("decode", "mixed") for r in roles):
            raise ValueError("need at least one decode-capable worker "
                             "(role 'decode' or 'mixed')")
        if any(r == "prefill" for r in roles) \
                and not engine_kwargs.get("paged"):
            raise ValueError("disaggregated roles require paged=True "
                             "(spans move KV at block granularity)")
        self.cfg = cfg
        self.roles = roles
        self.seed = seed
        self.engine_kwargs = dict(engine_kwargs)
        self.spawn_timeout_s = spawn_timeout_s
        self.heartbeat_interval_s = heartbeat_interval_s
        self.table = table or ThresholdTable()
        self.server = SchedulerServer(platform, self.table, bank=None,
                                      monitor=LoadMonitor(platform),
                                      policy=policy)
        self.failures = 0
        self.rerouted = 0
        self._span_threshold = int(
            engine_kwargs.get("prefill_tokens_per_step")
            or engine_kwargs.get("block_size") or 16)
        self._lock = threading.Lock()
        self._handles: dict[int, RequestHandle] = {}
        self._owner: dict[int, ProcessEngineWorker] = {}
        # req_id -> (request, owner, prefiller): spans in flight; the
        # central handoff sink resolves the CURRENT owner at delivery
        # time, so an owner that died meanwhile redirects to a survivor
        self._pending_spans: dict[
            int, tuple[GenerationRequest, ProcessEngineWorker,
                       ProcessEngineWorker]] = {}
        self.last_owners: dict[int, str] = {}
        self._started = False
        self._stopped = False
        self._tcp: Optional[TcpSchedulerServer] = None
        self._listener: Optional[socket.socket] = None
        self.workers: list[ProcessEngineWorker] = []
        self.supervisor = ClusterSupervisor(self, liveness_deadline_s)
        try:
            self._tcp = TcpSchedulerServer(self.server)
            self._sched_addr = self._tcp.start()
            # port 0 = kernel-assigned ephemeral port, race-free by
            # construction (no pick-then-bind window)
            self._listener = socket.socket(socket.AF_INET,
                                           socket.SOCK_STREAM)
            self._listener.bind(("127.0.0.1", 0))
            self._listener.listen(n_workers)
            self._result_addr = self._listener.getsockname()
            import multiprocessing as mp
            ctx = mp.get_context("spawn")   # own JAX runtime per worker
            for i in range(n_workers):
                wid = f"{worker_prefix}{i}"
                proc = ctx.Process(
                    target=_worker_main,
                    args=(wid, cfg, seed, dict(engine_kwargs),
                          self._sched_addr, self._result_addr, roles[i],
                          heartbeat_interval_s),
                    daemon=True, name=f"engine-{wid}")
                self.workers.append(ProcessEngineWorker(wid, roles[i],
                                                        proc))
            if any(r == "prefill" for r in roles):
                for w in self.workers:
                    if w.role != "prefill":
                        self.server.register_handoff_sink(
                            w.worker_id, self._make_sink())
        except BaseException:
            # construction failed halfway: release every socket/thread
            # already acquired so the caller's except path leaks nothing
            self._teardown_transport()
            raise

    # ------------------------------------------------------------ control
    def start(self) -> "ProcClusterFrontEnd":
        if self._started:
            return self
        self._started = True
        for w in self.workers:
            w.process.start()
        deadline = time.monotonic() + self.spawn_timeout_s
        try:
            pending = {w.worker_id: w for w in self.workers}
            while pending:
                self._listener.settimeout(
                    max(deadline - time.monotonic(), 0.001))
                sock, _ = self._listener.accept()
                hello = json.loads(sock.makefile("r").readline())
                w = pending.pop(hello["worker"])
                w.attach(sock)
                threading.Thread(target=self._read_loop, args=(w,),
                                 daemon=True,
                                 name=f"reader-{w.worker_id}").start()
        except (socket.timeout, OSError) as e:
            self.stop()
            raise TimeoutError(
                f"workers failed to connect within "
                f"{self.spawn_timeout_s}s: {sorted(pending)}") from e
        self.supervisor.start()
        return self

    def __enter__(self) -> "ProcClusterFrontEnd":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        """Idempotent full teardown: supervisor first (so deliberate
        shutdown is never misread as failure), then workers, then the
        transports."""
        if self._stopped:
            return
        self._stopped = True
        self.supervisor.stop()
        for w in self.workers:
            w.send({"cmd": "stop"})
        for w in self.workers:
            if w.process.ident is not None:
                w.process.join(timeout=10.0)
                if w.process.is_alive():
                    w.process.kill()
                    w.process.join(timeout=10.0)
            w.close()
        self._teardown_transport()
        self._started = False

    def _teardown_transport(self) -> None:
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
        if self._tcp is not None:
            self._tcp.stop()
            self._tcp = None

    # ------------------------------------------------------- result plane
    def _read_loop(self, w: ProcessEngineWorker) -> None:
        try:
            for line in w._rfile:
                ev = json.loads(line)
                kind = ev.get("ev")
                if kind in ("token", "finish"):
                    with self._lock:
                        handle = self._handles.get(ev["req"])
                    if handle is not None:
                        handle.apply_event(ev)
                    if kind == "finish":
                        with self._lock:
                            w.owned.discard(ev["req"])
                elif kind == "ready":
                    w.ready.set()
                elif kind == "warmed":
                    w.warmed.set()
                elif kind == "reset_done":
                    w.reset_done.set()
                elif kind == "summary":
                    w.summaries.put(ev["data"])
                # "hello" handled at accept; "error" falls through to EOF
        except (OSError, ValueError):
            pass
        finally:
            w.dead.set()     # supervisor re-routes anything still owned

    # ---------------------------------------------------- fault tolerance
    def _on_worker_failure(self, w: ProcessEngineWorker) -> None:
        """Supervisor callback: declare ``w`` dead, kill what's left of
        it, and re-route its in-flight requests to survivors via
        resume-by-re-prefill.  Requests replay their already-streamed
        tokens, so consumers observe a seamless, byte-identical
        stream."""
        with self._lock:
            if w.failed:
                return
            w.failed = True
            self.failures += 1
        w.kill()
        with self._lock:
            rids = sorted(w.owned)
            w.owned.clear()
            # spans this worker was still prefilling: hand the whole
            # request to its decode owner (local prefill beats waiting
            # for a span that will never arrive)
            orphan_spans = [rid for rid, (_, _, src)
                            in self._pending_spans.items() if src is w]
        for rid in orphan_spans:
            with self._lock:
                entry = self._pending_spans.pop(rid, None)
            if entry is None:
                continue
            request, owner, _ = entry
            if owner.failed:
                with self._lock:
                    rids.append(rid)     # owner died too: full re-route
            else:
                owner.send({"cmd": "submit",
                            "req": _req_to_wire(request)})
        for rid in rids:
            self._reroute(rid)

    def _reroute(self, rid: int) -> None:
        with self._lock:
            handle = self._handles.get(rid)
        if handle is None or handle.finished:
            return
        survivors = [v for v in self.workers
                     if v.role != "prefill" and v.alive()]
        if not survivors:
            # nobody left to serve it: fail the handle loudly instead
            # of letting result() hang to its timeout
            handle.apply_event({"ev": "finish",
                                "tokens": list(handle.tokens),
                                "logprobs": list(handle.logprobs),
                                "finish_reason": FINISH_ABORTED})
            return
        with self._lock:
            self._pending_spans.pop(rid, None)
            target = min(survivors, key=lambda v: v.load())
            target.owned.add(rid)
            self._owner[rid] = target
            self.rerouted += 1
        target.send({"cmd": "submit", "req": _req_to_wire(handle.request),
                     "resume_tokens": list(handle.tokens),
                     "resume_logprobs": list(handle.logprobs)})

    # ------------------------------------------------------ disaggregation
    def _make_sink(self):
        """Span consumer on the central scheduler: forward the span to
        the request's CURRENT decode owner's process.  Runs on the TCP
        handler thread."""
        def sink(req_id: int, payload: bytes) -> None:
            with self._lock:
                entry = self._pending_spans.pop(req_id, None)
            if entry is None:
                return               # request re-routed meanwhile: drop
            request, owner, _ = entry
            msg = {"cmd": "span", "req": _req_to_wire(request),
                   "payload": base64.b64encode(payload).decode()}
            if owner.failed or not owner.send(msg):
                # owner died between routing and delivery: serve the
                # request fresh on a survivor (prefill recomputes)
                self._reroute(req_id)
        return sink

    # ------------------------------------------------------------- serve
    def _require_started(self) -> None:
        if not self._started or self._stopped:
            raise RuntimeError("cluster not started (use start() or with)")

    def warmup(self, timeout: float = 300.0,
               max_prompt: Optional[int] = None) -> None:
        """Wait for every worker's engine build, then run each worker's
        warmup pass (compiles the lazy jits, then zeroes stats) —
        strictly outside any timed region, like the single-engine
        benchmarks.  ``max_prompt`` additionally pre-compiles the
        longest prompt bucket the caller intends to use."""
        self._require_started()
        deadline = time.monotonic() + timeout
        for w in self.workers:
            if not w.ready.wait(max(deadline - time.monotonic(), 0.001)):
                raise TimeoutError(
                    f"worker {w.worker_id} not ready within {timeout}s")
        for w in self.workers:
            w.warmed.clear()
            w.send({"cmd": "warmup", "max_prompt": max_prompt})
        for w in self.workers:
            if not w.warmed.wait(max(deadline - time.monotonic(), 0.001)):
                raise TimeoutError(
                    f"worker {w.worker_id} warmup timed out")
        if any(w.role == "prefill" for w in self.workers):
            # warm the span tier end to end (prefill-to-span, handoff,
            # span-rehydrate scatter), then reset every worker's stats
            vocab = max(getattr(self.cfg, "vocab_size", 2), 2)
            n = self._span_threshold + 4
            h = self.submit(GenerationRequest(
                np.arange(1, n + 1, dtype=np.int32) % vocab,
                max_new_tokens=2))
            h.result(timeout=max(deadline - time.monotonic(), 0.001))
            with self._lock:
                self._handles.pop(h.req_id, None)
                self._owner.pop(h.req_id, None)
            for w in self.workers:
                w.reset_done.clear()
                w.send({"cmd": "reset"})
            for w in self.workers:
                w.reset_done.wait(max(deadline - time.monotonic(), 0.001))

    def set_decode_thresholds(self, fpga_thr: float,
                              arm_thr: float = float("inf")) -> None:
        """Seed every worker's decode-step threshold row on the CENTRAL
        table (decisions happen here; the workers' local tables are
        bypassed by the TCP clients)."""
        for w in self.workers:
            row = self.table.row(f"{w.worker_id}_decode")
            row.fpga_thr, row.arm_thr = fpga_thr, arm_thr

    def submit(self, request: GenerationRequest,
               on_token=None) -> RequestHandle:
        """Route to the least-loaded live decode-capable worker; the
        returned handle rehydrates from result-plane events, so
        streaming, ``result()`` and ``abort()`` behave exactly as
        in-process.  With prefill roles, long prompts route through the
        span tier (prefill worker -> handoff -> owner), short ones
        prefill locally on the owner."""
        self._require_started()
        prefillers = [w for w in self.workers
                      if w.role == "prefill" and w.alive()]
        with self._lock:
            decoders = [w for w in self.workers
                        if w.role != "prefill" and w.alive()]
            if not decoders:
                raise RuntimeError("no live decode-capable workers")
            dest = min(decoders, key=lambda w: w.load())
            handle = RequestHandle(request, engine=self,
                                   on_token=on_token)
            self._handles[request.req_id] = handle
            self._owner[request.req_id] = dest
            dest.owned.add(request.req_id)
            span_tier = (prefillers
                         and request.prompt_len > self._span_threshold)
            if span_tier:
                source = min(prefillers,
                             key=lambda w: w.pending_prefills)
                source.pending_prefills += 1
                self._pending_spans[request.req_id] = (request, dest,
                                                       source)
        if span_tier:
            source.send({"cmd": "prefill", "req": _req_to_wire(request),
                         "dest": dest.worker_id})
        else:
            dest.send({"cmd": "submit", "req": _req_to_wire(request)})
        return handle

    def abort(self, req_id: int) -> bool:
        """RequestHandle.abort() proxy: forward to the owning worker.
        The worker's engine finishes the request as ``aborted`` and the
        finish event closes the parent handle."""
        with self._lock:
            handle = self._handles.get(req_id)
            owner = self._owner.get(req_id)
        if handle is None or handle.finished or owner is None:
            return False
        return owner.send({"cmd": "abort", "req": req_id})

    def drain(self, timeout: float = 300.0) -> dict[int, RequestOutput]:
        """Block until every submitted request finished (including any
        re-routed off a failed worker); returns (and forgets) their
        outputs keyed by req_id."""
        self._require_started()
        deadline = time.monotonic() + timeout
        with self._lock:
            handles = dict(self._handles)
            owners = {rid: w.worker_id for rid, w in self._owner.items()}
        out = {}
        for rid, h in handles.items():
            out[rid] = h.result(timeout=max(deadline - time.monotonic(),
                                            0.001))
        with self._lock:
            # attribution reflects the FINAL owner (post-re-route)
            self.last_owners = {rid: self._owner[rid].worker_id
                                if rid in self._owner else owners.get(rid)
                                for rid in out}
            for rid in out:
                self._handles.pop(rid, None)
                self._owner.pop(rid, None)
        return out

    # ------------------------------------------------------------- stats
    def summary(self, timeout: float = 30.0) -> dict:
        """Cluster-wide accounting: per-worker runtime/engine summaries
        fetched over the result plane, the central scheduler's decision
        histogram and signals, plus the fault-tolerance counters
        (``failures``, ``rerouted``, ``stragglers``) and each worker's
        liveness/heartbeat state."""
        per_engine: dict[str, dict] = {}
        pools: dict[str, dict] = {}
        deadline = time.monotonic() + timeout
        for w in self.workers:
            if not w.alive():
                continue
            while not w.summaries.empty():    # drop stale responses
                w.summaries.get_nowait()
            if not w.send({"cmd": "summary"}):
                continue
            try:
                data = w.summaries.get(
                    timeout=max(deadline - time.monotonic(), 0.001))
            except queue_lib.Empty:
                continue
            per_engine[w.worker_id] = data["runtime"]
            per_engine[w.worker_id]["engine_stats"] = data["engine_stats"]
            if "pool" in data:
                pools[w.worker_id] = data["pool"]
        hb = {wid: beat["seq"]
              for wid, beat in self.server.heartbeats.items()}
        return {
            "per_engine": per_engine,
            "migrations": sum(s.get("migrations", 0)
                              for s in per_engine.values()),
            "decisions": {k.value: v
                          for k, v in self.server.decisions.items()},
            "signals": dataclasses.asdict(self.server.signals()),
            "roles": {w.worker_id: w.role for w in self.workers},
            "handoffs": self.server.handoffs,
            "pools": pools,
            "failures": self.failures,
            "rerouted": self.rerouted,
            "stragglers": self.supervisor.stragglers,
            "workers": {w.worker_id: {"alive": w.alive(),
                                      "failed": w.failed,
                                      "heartbeats": hb.get(w.worker_id)}
                        for w in self.workers},
        }
