"""Multi-engine cluster serving under ONE central scheduler.

Closes the ROADMAP's multi-process item: N ``ContinuousBatchingEngine``
workers (each with its own ``XarTrekRuntime``, compiled variants and
kernel bank) register with one central ``SchedulerServer``; the shared
``SchedulingPolicy`` is evaluated over the *aggregate* cross-engine
``LoadSignals``, so one engine's queue pressure migrates another
engine's decode steps to ACCEL — Algorithm 2 balancing real co-tenant
load, as in the paper's evaluation, instead of a synthetic process
counter.

Topology (the paper's Figure-2 run-time, serve-shaped):

* ``ClusterFrontEnd`` owns the central scheduler (policy + threshold
  table + load monitor) and, by default, a ``TcpSchedulerServer``
  wrapping it — workers then talk to the scheduler over the
  paper-faithful line-JSON socket transport (``transport="inproc"``
  skips the sockets for tests).
* Each ``EngineWorker`` runs its engine loop on its own thread; its
  runtime's scheduler *clients* (one per step function, plus the
  signal publisher) connect to the central server, and its kernel bank
  is registered there so residency checks and async reconfigurations
  reach the worker that owns the compiled variants.
* ``ClusterFrontEnd.submit(GenerationRequest)`` routes to the
  least-loaded worker (queued + in-flight) and returns the v2
  ``RequestHandle`` — streaming, ``result()``, ``abort()`` all work
  unchanged; the application-facing contract does not know the cluster
  exists.

**Disaggregation** (``roles=``): workers can be pinned to one serving
phase — ``"prefill"`` workers run chunked prefill into scratch pool
blocks and hand the finished KV off as a serialized ``KVSpan``;
``"decode"`` (or ``"mixed"``) workers rehydrate the span into their own
pool and decode.  The handoff rides the scheduler control plane (a
``handoff`` op next to ``request``/``report``/``publish`` — base64
payload over the line-JSON TCP transport, a direct call in-proc), so
phase migration uses exactly the machinery step migration does.  The
front-end picks the decode owner at submit time (least loaded) and the
prefill worker by shortest prefill queue; the central policy sees both
phases' published signals.

Workers are threads, not OS processes: one JAX runtime serves all
engines (this is the single-host analogue; the TCP control plane is
exactly what a multi-host deployment would speak).  Model parameters
are built once and shared across workers — co-tenants of one
accelerator, as in SYNERGY's multiplexing argument.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.configs.model_config import ModelConfig
from repro.core.function import FunctionRegistry
from repro.core.monitor import LoadMonitor
from repro.core.policy import PolicyLike
from repro.core.runtime import XarTrekRuntime
from repro.core.scheduler import (
    SchedulerServer, TcpSchedulerClient, TcpSchedulerServer,
)
from repro.core.targets import Platform, TPU_PLATFORM
from repro.core.thresholds import ThresholdTable
from repro.serve.api import GenerationRequest, RequestHandle, RequestOutput
from repro.serve.batch import KVSpan
from repro.serve.engine import ContinuousBatchingEngine

WORKER_ROLES = ("mixed", "prefill", "decode")


class EngineWorker:
    """One engine + runtime + serve-loop thread behind the cluster.

    ``role`` pins the worker to one serving phase: a ``"prefill"``
    worker additionally services a span queue (``submit_prefill`` →
    ``engine.prefill_to_span`` → ``on_handoff``); a ``"decode"`` worker
    receives spans via ``submit_span``.  ``"mixed"`` (default) serves
    both phases locally, exactly the pre-role behaviour."""

    def __init__(self, worker_id: str, cfg: ModelConfig,
                 server: SchedulerServer,
                 scheduler_address: Optional[tuple] = None,
                 params=None, seed: int = 0, role: str = "mixed",
                 **engine_kwargs):
        if role not in WORKER_ROLES:
            raise ValueError(f"role must be one of {WORKER_ROLES}: {role!r}")
        self.worker_id = worker_id
        self.role = role
        self.runtime = XarTrekRuntime(
            registry=FunctionRegistry(), server=server,
            scheduler_address=scheduler_address)
        self.engine = ContinuousBatchingEngine(
            cfg, params=params, seed=seed, runtime=self.runtime,
            fn_prefix=worker_id, **engine_kwargs)
        self._prefill_q: collections.deque = collections.deque()
        # set by the front-end on prefill-role workers: called with
        # (request, span_bytes) once a span is ready to hand off
        self.on_handoff = None
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"engine-{worker_id}")

    # ----------------------------------------------------------- serving
    def start(self) -> None:
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        """Idempotent, and safe on a worker whose thread never started
        (a front-end torn down from a constructor failure path)."""
        self._stop.set()
        self._wake.set()
        if self._thread.ident is not None:
            self._thread.join(timeout)
        for client in self.runtime._clients.values():
            close = getattr(client, "close", None)
            if close is not None:
                close()

    def _loop(self) -> None:
        """Drain-and-wait: ``run()`` serves everything queued (new
        submissions land in the thread-safe queue mid-run and are
        admitted the same loop), then the thread parks until the next
        ``submit`` wakes it.  run()'s return dict is dropped — the
        caller-facing results are the RequestHandles, which resolve the
        step each request finishes (reading a worker-side dict here
        would race the front-end, whose drain() returns as soon as the
        handles resolve)."""
        while not self._stop.is_set():
            busy = False
            while self._prefill_q:
                req = self._prefill_q.popleft()
                # publish load BEFORE the span: prefill_to_span never
                # enters run(), so this is the prefill phase's pressure
                # feed to the central policy
                self.engine._publish_signals()
                payload = self.engine.prefill_to_span(req).to_bytes()
                if self.on_handoff is not None:
                    self.on_handoff(req, payload)
                busy = True
            if len(self.engine.queue) or self.engine.slots.active:
                self.engine.run()
                busy = True
            if not busy:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    def submit(self, request: GenerationRequest,
               on_token=None) -> RequestHandle:
        handle = self.engine.submit(request, on_token=on_token)
        self._wake.set()
        return handle

    def submit_prefill(self, request: GenerationRequest) -> None:
        """Queue a prefill-only job (disaggregation: the span hands off
        via ``on_handoff`` when ready)."""
        self._prefill_q.append(request)
        self._wake.set()

    def submit_span(self, request: GenerationRequest,
                    span: KVSpan) -> RequestHandle:
        """Queue a request whose prefill KV arrives pre-computed."""
        handle = self.engine.submit_span(request, span)
        self._wake.set()
        return handle

    def load(self) -> int:
        """Routing weight: requests queued plus rows in flight."""
        return len(self.engine.queue) + len(self.engine.slots.active)

    def prefill_load(self) -> int:
        """Prefill-routing weight: spans queued but not yet computed."""
        return len(self._prefill_q)


class ClusterFrontEnd:
    """N engine workers, one scheduler, one ``submit()`` surface.

    ``policy`` is the SHARED SchedulingPolicy (instance or alias
    string) the central server evaluates over aggregate signals.
    ``transport="tcp"`` (default) runs the scheduler behind a
    ``TcpSchedulerServer`` on loopback; ``"inproc"`` wires the workers
    straight to the server object.  ``engine_kwargs`` (max_slots,
    max_seq, paged, block_size, ...) apply to every worker.  Parameters
    are built once (worker 0) and shared.

    ``roles`` (one per worker, e.g. ``("prefill", "decode")``) enables
    disaggregated serving: requests route decode-first (the least-loaded
    decode-capable worker owns the request and its handle from submit
    time), the shortest-queue prefill worker computes the KV span, and
    the span travels dest-addressed over the scheduler control plane's
    ``handoff`` op into the owner's pool.  Requires ``paged=True`` and
    at least one decode-capable (``decode``/``mixed``) worker.
    """

    def __init__(self, cfg: ModelConfig, n_engines: int = 2,
                 policy: PolicyLike = "xartrek",
                 transport: str = "tcp",
                 platform: Platform = TPU_PLATFORM,
                 table: Optional[ThresholdTable] = None,
                 params=None, seed: int = 0,
                 worker_prefix: str = "w",
                 roles: Optional[Sequence[str]] = None,
                 **engine_kwargs):
        if n_engines < 1:
            raise ValueError(f"need at least one engine: {n_engines}")
        if transport not in ("tcp", "inproc"):
            raise ValueError(f"transport must be tcp|inproc: {transport!r}")
        if roles is None:
            roles = ("mixed",) * n_engines
        roles = tuple(roles)
        if len(roles) != n_engines:
            raise ValueError(f"roles {roles} must name all "
                             f"{n_engines} workers")
        if not any(r in ("decode", "mixed") for r in roles):
            raise ValueError("need at least one decode-capable worker "
                             "(role 'decode' or 'mixed')")
        if any(r == "prefill" for r in roles) \
                and not engine_kwargs.get("paged"):
            raise ValueError("disaggregated roles require paged=True "
                             "(spans move KV at block granularity)")
        self.roles = roles
        self.cfg = cfg
        self.table = table or ThresholdTable()
        self.server = SchedulerServer(platform, self.table, bank=None,
                                      monitor=LoadMonitor(platform),
                                      policy=policy)
        self._tcp: Optional[TcpSchedulerServer] = None
        self._handoff_client = None
        self.workers: list[EngineWorker] = []
        self._stopped = False
        try:
            address = None
            if transport == "tcp":
                self._tcp = TcpSchedulerServer(self.server)
                address = self._tcp.start()
            for i in range(n_engines):
                w = EngineWorker(f"{worker_prefix}{i}", cfg, self.server,
                                 scheduler_address=address, role=roles[i],
                                 params=params, seed=seed, **engine_kwargs)
                if params is None:
                    params = w.engine.params      # share across workers
                self.workers.append(w)
            # disaggregation plumbing: decode-capable workers register a
            # span sink under their worker_id; prefill workers hand
            # spans to the control plane addressed at the request's
            # decode owner
            self._pending_spans: dict[int, tuple[GenerationRequest,
                                                 EngineWorker]] = {}
            # prompts at or under this length prefill in place on their
            # decode owner: the span tier exists for prompts whose
            # prefill would stall co-resident decodes, and a one-chunk
            # prompt costs less to compute locally than to serialize
            # and hand off
            self._span_threshold = int(
                engine_kwargs.get("prefill_tokens_per_step")
                or engine_kwargs.get("block_size") or 16)
            if any(r == "prefill" for r in roles):
                for w in self.workers:
                    if w.role != "prefill":
                        self.server.register_handoff_sink(
                            w.worker_id, self._make_sink(w))
                    else:
                        w.on_handoff = self._handoff_out
                if address is not None:
                    self._handoff_client = TcpSchedulerClient("handoff",
                                                              address)
        except BaseException:
            # a worker that failed to build mid-list, or a handoff
            # client that could not connect, must not leak the TCP
            # server thread / listener socket or the workers' runtime
            # clients into the caller's except path
            self.stop()
            raise
        self._owner: dict[int, EngineWorker] = {}
        self._handles: dict[int, RequestHandle] = {}
        # req_id -> worker_id of requests completed by the last drain()
        # (ownership survives the pop so callers can attribute outputs
        # per engine without racing the worker threads)
        self.last_owners: dict[int, str] = {}
        self._lock = threading.Lock()
        self._started = False

    # ------------------------------------------------------------ control
    def start(self) -> "ClusterFrontEnd":
        if not self._started:
            self._started = True
            for w in self.workers:
                w.start()
        return self

    def stop(self) -> None:
        """Idempotent: workers, the handoff client and the TCP server
        all tolerate repeated/unstarted teardown, so ``with`` blocks,
        explicit ``stop()`` calls and constructor-failure cleanup can
        overlap without double-close errors."""
        if self._stopped:
            return
        self._stopped = True
        for w in self.workers:
            w.stop()
        if self._handoff_client is not None:
            self._handoff_client.close()
        if self._tcp is not None:
            self._tcp.stop()
        self._started = False

    # ------------------------------------------------------ disaggregation
    def _make_sink(self, worker: EngineWorker):
        """Span consumer for one decode-capable worker (runs on the
        delivering thread — TCP handler or prefill worker)."""
        def sink(req_id: int, payload: bytes) -> None:
            with self._lock:
                request, _ = self._pending_spans.pop(req_id)
            worker.submit_span(request, KVSpan.from_bytes(payload))
        return sink

    def _handoff_out(self, request: GenerationRequest,
                     payload: bytes) -> None:
        """Prefill-worker exit: ship the span to the request's decode
        owner over the control plane (TCP when the cluster runs the
        socket transport, a direct server call in-proc)."""
        with self._lock:
            dest = self._pending_spans[request.req_id][1].worker_id
        plane = self._handoff_client or self.server
        plane.handoff(dest, request.req_id, payload)

    def __enter__(self) -> "ClusterFrontEnd":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self, timeout: float = 120.0) -> None:
        """Run one tiny request through every worker, then zero the
        stats: engines compile their lazy pieces (slot-write /
        block-scatter jits) outside any measured or scenario-sensitive
        window, exactly like the single-engine benchmarks' warm pass.
        Without this, a worker's first admission can stall seconds in
        compilation while its co-tenants' load comes and goes unseen."""
        if not self._started:
            raise RuntimeError("cluster not started (use start() or with)")
        vocab = max(getattr(self.cfg, "vocab_size", 2), 2)
        handles = [w.submit(GenerationRequest(
            np.arange(1, 5, dtype=np.int32) % vocab, max_new_tokens=2))
            for w in self.workers]
        for h in handles:
            h.result(timeout=timeout)
        if any(w.role == "prefill" for w in self.workers):
            # warm the disaggregated path too: prefill-to-span on the
            # prefill workers, span-rehydrate scatter on the decoders
            # (long enough to clear the local-prefill threshold)
            n = self._span_threshold + 4
            h = self.submit(GenerationRequest(
                np.arange(1, n + 1, dtype=np.int32) % vocab,
                max_new_tokens=2))
            h.result(timeout=timeout)
            with self._lock:
                self._handles.pop(h.req_id, None)
                self._owner.pop(h.req_id, None)
        for w in self.workers:
            w.runtime.call_log.clear()
            w.engine.reset_stats()

    def set_decode_thresholds(self, fpga_thr: float,
                              arm_thr: float = float("inf")) -> None:
        """Seed every worker's decode-step threshold row (the Table-2
        artifact the compiler would have produced): the load above
        which offloading that worker's decode to ACCEL is profitable."""
        for w in self.workers:
            row = self.table.row(w.engine._decode_name)
            row.fpga_thr, row.arm_thr = fpga_thr, arm_thr

    # ------------------------------------------------------------- serve
    def submit(self, request: GenerationRequest,
               on_token=None) -> RequestHandle:
        """Route one request to the least-loaded worker; the returned
        handle is the worker engine's own (streaming/abort included).

        With prefill roles in play the split is explicit: the decode
        owner is fixed (and its handle returned) at submit time, the
        prefill worker with the shortest span queue computes the KV,
        and admission on the owner waits for the handoff — TTFT covers
        the whole disaggregated path."""
        if not self._started:
            raise RuntimeError("cluster not started (use start() or with)")
        prefillers = [w for w in self.workers if w.role == "prefill"]
        with self._lock:
            if not prefillers:
                worker = min((w for w in self.workers
                              if w.role != "prefill"),
                             key=lambda w: w.load())
                handle = worker.submit(request, on_token=on_token)
                self._owner[request.req_id] = worker
                self._handles[request.req_id] = handle
                return handle
            dest = min((w for w in self.workers if w.role != "prefill"),
                       key=lambda w: w.load())
            if request.prompt_len <= self._span_threshold:
                # interactive class: prefill locally on the owner
                handle = dest.submit(request, on_token=on_token)
                self._owner[request.req_id] = dest
                self._handles[request.req_id] = handle
                return handle
            dest.engine.slots.validate(request)     # fail fast, pre-span
            handle = dest.engine._handle_for(request, on_token=on_token)
            self._pending_spans[request.req_id] = (request, dest)
            self._owner[request.req_id] = dest
            self._handles[request.req_id] = handle
            source = min(prefillers, key=lambda w: w.prefill_load())
        source.submit_prefill(request)
        return handle

    def drain(self, timeout: float = 120.0) -> dict[int, RequestOutput]:
        """Block until every submitted request finished; returns (and
        forgets) their outputs keyed by req_id."""
        deadline = time.monotonic() + timeout
        with self._lock:
            handles = dict(self._handles)
        out = {}
        for rid, h in handles.items():
            out[rid] = h.result(timeout=max(deadline - time.monotonic(),
                                            0.001))
        with self._lock:
            self.last_owners = {
                rid: self._owner[rid].worker_id
                for rid in out if rid in self._owner}
            for rid in out:
                self._handles.pop(rid, None)
                self._owner.pop(rid, None)
        return out

    # ------------------------------------------------------------- stats
    def summary(self) -> dict:
        """Cluster-wide accounting: per-worker runtime summaries plus
        the aggregate migration count and the central server's decision
        histogram — the benchmark artifact's proof that co-tenant load
        moved steps between targets."""
        per_engine = {w.worker_id: w.runtime.summary()
                      for w in self.workers}
        out = {
            "per_engine": per_engine,
            "migrations": sum(s["migrations"]
                              for s in per_engine.values()),
            "decisions": {k.value: v
                          for k, v in self.server.decisions.items()},
            "signals": dataclasses.asdict(self.server.signals()),
            "roles": {w.worker_id: w.role for w in self.workers},
            "handoffs": self.server.handoffs,
            # per-worker chunked-prefill / stall observability (the
            # policy's view of prefill pressure, not just throughput)
            "chunked_prefill": {
                w.worker_id: {
                    "prefill_chunks": w.engine.stats["prefill_chunks"],
                    "decode_stall_ms": w.engine.stats["decode_stall_ms"],
                    "decode_stall_max_ms":
                        w.engine.stats["decode_stall_max_ms"],
                    "chunk_hist": dict(w.engine.stats["chunk_hist"]),
                    "spans_admitted": w.engine.stats["spans_admitted"],
                } for w in self.workers},
        }
        if any(w.engine.prefix_cache for w in self.workers):
            # aggregate prefix-cache effectiveness: each worker has its
            # own pool, so hit rates are per-tenant, summed here the way
            # migrations are
            per_worker = {w.worker_id: w.engine.prefix_stats()
                          for w in self.workers}
            hit = sum(p["prefix_hit_tokens"] for p in per_worker.values())
            computed = sum(p["prefill_tokens"] for p in per_worker.values())
            out["prefix_cache"] = {
                "per_engine": per_worker,
                "prefix_hit_tokens": hit,
                "prefill_tokens": computed,
                "prefix_hit_rate": hit / max(hit + computed, 1),
            }
        return out
