"""Multi-engine cluster serving under ONE central scheduler.

Closes the ROADMAP's multi-process item: N ``ContinuousBatchingEngine``
workers (each with its own ``XarTrekRuntime``, compiled variants and
kernel bank) register with one central ``SchedulerServer``; the shared
``SchedulingPolicy`` is evaluated over the *aggregate* cross-engine
``LoadSignals``, so one engine's queue pressure migrates another
engine's decode steps to ACCEL — Algorithm 2 balancing real co-tenant
load, as in the paper's evaluation, instead of a synthetic process
counter.

Topology (the paper's Figure-2 run-time, serve-shaped):

* ``ClusterFrontEnd`` owns the central scheduler (policy + threshold
  table + load monitor) and, by default, a ``TcpSchedulerServer``
  wrapping it — workers then talk to the scheduler over the
  paper-faithful line-JSON socket transport (``transport="inproc"``
  skips the sockets for tests).
* Each ``EngineWorker`` runs its engine loop on its own thread; its
  runtime's scheduler *clients* (one per step function, plus the
  signal publisher) connect to the central server, and its kernel bank
  is registered there so residency checks and async reconfigurations
  reach the worker that owns the compiled variants.
* ``ClusterFrontEnd.submit(GenerationRequest)`` routes to the
  least-loaded worker (queued + in-flight) and returns the v2
  ``RequestHandle`` — streaming, ``result()``, ``abort()`` all work
  unchanged; the application-facing contract does not know the cluster
  exists.

Workers are threads, not OS processes: one JAX runtime serves all
engines (this is the single-host analogue; the TCP control plane is
exactly what a multi-host deployment would speak).  Model parameters
are built once and shared across workers — co-tenants of one
accelerator, as in SYNERGY's multiplexing argument.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.configs.model_config import ModelConfig
from repro.core.function import FunctionRegistry
from repro.core.monitor import LoadMonitor
from repro.core.policy import PolicyLike
from repro.core.runtime import XarTrekRuntime
from repro.core.scheduler import SchedulerServer, TcpSchedulerServer
from repro.core.targets import Platform, TPU_PLATFORM
from repro.core.thresholds import ThresholdTable
from repro.serve.api import GenerationRequest, RequestHandle, RequestOutput
from repro.serve.engine import ContinuousBatchingEngine


class EngineWorker:
    """One engine + runtime + serve-loop thread behind the cluster."""

    def __init__(self, worker_id: str, cfg: ModelConfig,
                 server: SchedulerServer,
                 scheduler_address: Optional[tuple] = None,
                 params=None, seed: int = 0,
                 **engine_kwargs):
        self.worker_id = worker_id
        self.runtime = XarTrekRuntime(
            registry=FunctionRegistry(), server=server,
            scheduler_address=scheduler_address)
        self.engine = ContinuousBatchingEngine(
            cfg, params=params, seed=seed, runtime=self.runtime,
            fn_prefix=worker_id, **engine_kwargs)
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"engine-{worker_id}")

    # ----------------------------------------------------------- serving
    def start(self) -> None:
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout)
        for client in self.runtime._clients.values():
            close = getattr(client, "close", None)
            if close is not None:
                close()

    def _loop(self) -> None:
        """Drain-and-wait: ``run()`` serves everything queued (new
        submissions land in the thread-safe queue mid-run and are
        admitted the same loop), then the thread parks until the next
        ``submit`` wakes it.  run()'s return dict is dropped — the
        caller-facing results are the RequestHandles, which resolve the
        step each request finishes (reading a worker-side dict here
        would race the front-end, whose drain() returns as soon as the
        handles resolve)."""
        while not self._stop.is_set():
            if len(self.engine.queue) or self.engine.slots.active:
                self.engine.run()
            else:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    def submit(self, request: GenerationRequest,
               on_token=None) -> RequestHandle:
        handle = self.engine.submit(request, on_token=on_token)
        self._wake.set()
        return handle

    def load(self) -> int:
        """Routing weight: requests queued plus rows in flight."""
        return len(self.engine.queue) + len(self.engine.slots.active)


class ClusterFrontEnd:
    """N engine workers, one scheduler, one ``submit()`` surface.

    ``policy`` is the SHARED SchedulingPolicy (instance or alias
    string) the central server evaluates over aggregate signals.
    ``transport="tcp"`` (default) runs the scheduler behind a
    ``TcpSchedulerServer`` on loopback; ``"inproc"`` wires the workers
    straight to the server object.  ``engine_kwargs`` (max_slots,
    max_seq, paged, block_size, ...) apply to every worker.  Parameters
    are built once (worker 0) and shared.
    """

    def __init__(self, cfg: ModelConfig, n_engines: int = 2,
                 policy: PolicyLike = "xartrek",
                 transport: str = "tcp",
                 platform: Platform = TPU_PLATFORM,
                 table: Optional[ThresholdTable] = None,
                 params=None, seed: int = 0,
                 worker_prefix: str = "w",
                 **engine_kwargs):
        if n_engines < 1:
            raise ValueError(f"need at least one engine: {n_engines}")
        if transport not in ("tcp", "inproc"):
            raise ValueError(f"transport must be tcp|inproc: {transport!r}")
        self.cfg = cfg
        self.table = table or ThresholdTable()
        self.server = SchedulerServer(platform, self.table, bank=None,
                                      monitor=LoadMonitor(platform),
                                      policy=policy)
        self._tcp: Optional[TcpSchedulerServer] = None
        address = None
        if transport == "tcp":
            self._tcp = TcpSchedulerServer(self.server)
            address = self._tcp.start()
        self.workers: list[EngineWorker] = []
        for i in range(n_engines):
            w = EngineWorker(f"{worker_prefix}{i}", cfg, self.server,
                             scheduler_address=address,
                             params=params, seed=seed, **engine_kwargs)
            if params is None:
                params = w.engine.params          # share across workers
            self.workers.append(w)
        self._owner: dict[int, EngineWorker] = {}
        self._handles: dict[int, RequestHandle] = {}
        # req_id -> worker_id of requests completed by the last drain()
        # (ownership survives the pop so callers can attribute outputs
        # per engine without racing the worker threads)
        self.last_owners: dict[int, str] = {}
        self._lock = threading.Lock()
        self._started = False

    # ------------------------------------------------------------ control
    def start(self) -> "ClusterFrontEnd":
        if not self._started:
            self._started = True
            for w in self.workers:
                w.start()
        return self

    def stop(self) -> None:
        for w in self.workers:
            w.stop()
        if self._tcp is not None:
            self._tcp.stop()
        self._started = False

    def __enter__(self) -> "ClusterFrontEnd":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self, timeout: float = 120.0) -> None:
        """Run one tiny request through every worker, then zero the
        stats: engines compile their lazy pieces (slot-write /
        block-scatter jits) outside any measured or scenario-sensitive
        window, exactly like the single-engine benchmarks' warm pass.
        Without this, a worker's first admission can stall seconds in
        compilation while its co-tenants' load comes and goes unseen."""
        if not self._started:
            raise RuntimeError("cluster not started (use start() or with)")
        vocab = max(getattr(self.cfg, "vocab_size", 2), 2)
        handles = [w.submit(GenerationRequest(
            np.arange(1, 5, dtype=np.int32) % vocab, max_new_tokens=2))
            for w in self.workers]
        for h in handles:
            h.result(timeout=timeout)
        for w in self.workers:
            w.runtime.call_log.clear()
            w.engine.reset_stats()

    def set_decode_thresholds(self, fpga_thr: float,
                              arm_thr: float = float("inf")) -> None:
        """Seed every worker's decode-step threshold row (the Table-2
        artifact the compiler would have produced): the load above
        which offloading that worker's decode to ACCEL is profitable."""
        for w in self.workers:
            row = self.table.row(w.engine._decode_name)
            row.fpga_thr, row.arm_thr = fpga_thr, arm_thr

    # ------------------------------------------------------------- serve
    def submit(self, request: GenerationRequest,
               on_token=None) -> RequestHandle:
        """Route one request to the least-loaded worker; the returned
        handle is the worker engine's own (streaming/abort included)."""
        if not self._started:
            raise RuntimeError("cluster not started (use start() or with)")
        with self._lock:
            worker = min(self.workers, key=lambda w: w.load())
            handle = worker.submit(request, on_token=on_token)
            self._owner[request.req_id] = worker
            self._handles[request.req_id] = handle
        return handle

    def drain(self, timeout: float = 120.0) -> dict[int, RequestOutput]:
        """Block until every submitted request finished; returns (and
        forgets) their outputs keyed by req_id."""
        deadline = time.monotonic() + timeout
        with self._lock:
            handles = dict(self._handles)
        out = {}
        for rid, h in handles.items():
            out[rid] = h.result(timeout=max(deadline - time.monotonic(),
                                            0.001))
        with self._lock:
            self.last_owners = {
                rid: self._owner[rid].worker_id
                for rid in out if rid in self._owner}
            for rid in out:
                self._handles.pop(rid, None)
                self._owner.pop(rid, None)
        return out

    # ------------------------------------------------------------- stats
    def summary(self) -> dict:
        """Cluster-wide accounting: per-worker runtime summaries plus
        the aggregate migration count and the central server's decision
        histogram — the benchmark artifact's proof that co-tenant load
        moved steps between targets."""
        per_engine = {w.worker_id: w.runtime.summary()
                      for w in self.workers}
        out = {
            "per_engine": per_engine,
            "migrations": sum(s["migrations"]
                              for s in per_engine.values()),
            "decisions": {k.value: v
                          for k, v in self.server.decisions.items()},
            "signals": dataclasses.asdict(self.server.signals()),
        }
        if any(w.engine.prefix_cache for w in self.workers):
            # aggregate prefix-cache effectiveness: each worker has its
            # own pool, so hit rates are per-tenant, summed here the way
            # migrations are
            per_worker = {w.worker_id: w.engine.prefix_stats()
                          for w in self.workers}
            hit = sum(p["prefix_hit_tokens"] for p in per_worker.values())
            computed = sum(p["prefill_tokens"] for p in per_worker.values())
            out["prefix_cache"] = {
                "per_engine": per_worker,
                "prefix_hit_tokens": hit,
                "prefill_tokens": computed,
                "prefix_hit_rate": hit / max(hit + computed, 1),
            }
        return out
