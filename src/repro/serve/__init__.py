"""Serving engines and the v2 request API.

`ServeEngine` is the synchronous baseline; `ContinuousBatchingEngine`
serves ragged arrival streams with dense or paged (block-table) KV,
optional content-addressed prefix caching and int8 quantised pools,
dispatching every step through the Xar-Trek runtime so scheduling
policies migrate prefill/decode between HOST and ACCEL builds.
`ClusterFrontEnd` runs N engine workers behind one central scheduler;
`ProcClusterFrontEnd` promotes the workers to OS processes with a
streaming IPC result plane and a fault-tolerant supervisor.
See README.md in this package for the full design.
"""
from repro.serve.api import (
    GenerationRequest, RequestHandle, RequestOutput, SamplingParams,
)
from repro.serve.batch import BlockPool, PagedSlotManager, Slot, SlotManager
from repro.serve.cluster import ClusterFrontEnd, EngineWorker
from repro.serve.engine import (
    ContinuousBatchingEngine, GenerationResult, ServeEngine, prompt_bucket,
)
from repro.serve.proc import (
    ClusterSupervisor, ProcClusterFrontEnd, ProcessEngineWorker,
)
from repro.serve.scheduler import Request, RequestQueue, poisson_arrivals
