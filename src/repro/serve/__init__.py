from repro.serve.api import (
    GenerationRequest, RequestHandle, RequestOutput, SamplingParams,
)
from repro.serve.batch import BlockPool, PagedSlotManager, Slot, SlotManager
from repro.serve.cluster import ClusterFrontEnd, EngineWorker
from repro.serve.engine import (
    ContinuousBatchingEngine, GenerationResult, ServeEngine, prompt_bucket,
)
from repro.serve.scheduler import Request, RequestQueue, poisson_arrivals
