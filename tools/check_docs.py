"""Docs CI gate: intra-repo markdown links must resolve and every
public ``repro.serve`` / ``repro.kernels`` / ``repro.core`` module
(``serve/proc.py``'s process-cluster subsystem included) must carry a
module docstring.

Pure stdlib + AST — no imports of repro itself, so the check runs in
the lint environment without jax installed.

    python tools/check_docs.py          # from the repo root

Exit 0 when clean; exit 1 listing every broken link / missing
docstring otherwise.

Link check scope: every ``*.md`` tracked in the repo (skipping
hidden/vendored dirs).  A link counts as intra-repo when it is not a
URL (``scheme://``), mailto, or pure ``#fragment``; it must resolve —
relative to the file that contains it, or to the repo root for
``/``-prefixed paths — to an existing file or directory.  Fragments
are stripped (heading anchors are not verified).  Bare-code spans and
fenced code blocks are ignored.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCSTRING_PACKAGES = ("src/repro/serve", "src/repro/kernels",
                      "src/repro/core")
SKIP_DIRS = {".git", ".github", "__pycache__", ".venv", "node_modules",
             "artifacts"}

# [text](target) — excluding images' leading ! is unnecessary: image
# targets must resolve too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^(```|~~~)")


def iter_markdown(root: pathlib.Path):
    for p in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.relative_to(root).parts):
            yield p


def check_links(root: pathlib.Path) -> list[str]:
    errors = []
    for md in iter_markdown(root):
        in_fence = False
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            if _FENCE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in _LINK.findall(line):
                if ("://" in target or target.startswith("mailto:")
                        or target.startswith("#")):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (root / path.lstrip("/") if path.startswith("/")
                            else md.parent / path)
                if not resolved.exists():
                    errors.append(f"{md.relative_to(root)}:{lineno}: "
                                  f"broken link -> {target}")
    return errors


def check_docstrings(root: pathlib.Path) -> list[str]:
    errors = []
    for pkg in DOCSTRING_PACKAGES:
        for py in sorted((root / pkg).glob("*.py")):
            if py.name.startswith("_") and py.name != "__init__.py":
                continue
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError as e:
                errors.append(f"{py.relative_to(root)}: unparsable: {e}")
                continue
            if not ast.get_docstring(tree):
                errors.append(f"{py.relative_to(root)}: "
                              "missing module docstring")
    return errors


def main() -> int:
    errors = check_links(ROOT) + check_docstrings(ROOT)
    for e in errors:
        print(e)
    n_md = sum(1 for _ in iter_markdown(ROOT))
    if errors:
        print(f"\ndocs check FAILED: {len(errors)} problem(s)")
        return 1
    print(f"docs check passed ({n_md} markdown files, "
          f"{len(DOCSTRING_PACKAGES)} docstring-gated packages)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
