"""Shared helpers for the paper-table/figure benchmarks.

Output convention (benchmarks/run.py): every benchmark prints CSV rows
``name,us_per_call,derived`` where ``derived`` carries the figure's
headline quantity (a gain %, a threshold, a throughput...).
"""
from __future__ import annotations

import copy
import random
import time

from repro.core.estimator import estimate_table
from repro.core.sim import AppProfile, MGB_MS, PAPER_APPS, PlatformSim
from repro.core.thresholds import ThresholdTable

BG = AppProfile("mgb", MGB_MS, MGB_MS, MGB_MS, "KNL_MGB")
ALL_KERNELS = tuple(a.hw_kernel for a in PAPER_APPS.values())


def fresh_table() -> ThresholdTable:
    t = ThresholdTable()
    t.rows = {k: copy.deepcopy(v)
              for k, v in estimate_table(PAPER_APPS).rows.items()}
    return t


def make_sim(policy: str, *, hot_bank: bool = True,
             reconfig_ms: float = 4000.0) -> PlatformSim:
    return PlatformSim(policy=policy, table=fresh_table(),
                       reconfig_ms=reconfig_ms,
                       preconfigure=ALL_KERNELS if hot_bank else ())


def run_app_set(policy: str, n_apps: int, n_bg: int, seed: int = 42,
                hot_bank: bool = True) -> float:
    """Average execution time (ms) of a random app set under bg load."""
    sim = make_sim(policy, hot_bank=hot_bank)
    for _ in range(n_bg):
        sim.submit(BG, at=0.0, background=True)
    rng = random.Random(seed)
    apps = list(PAPER_APPS.values())
    for _ in range(n_apps):
        sim.submit(rng.choice(apps), at=10.0)
    sim.run()
    return sim.avg_execution_ms()


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
