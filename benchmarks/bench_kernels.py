"""Kernel micro-bench: wall time of each Pallas kernel (interpret mode on
CPU — correctness-path timing) vs its jnp oracle, plus the analytic TPU
roofline time of the kernel's tiling (the number that matters for the
ACCEL target; see EXPERIMENTS.md §Perf).
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # warm up / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def main() -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # flash attention: B=1,S=1024,H=4,hd=128
    B, S, H, hd = 1, 1024, 4, 128
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    us_ref = _time(lambda: jax.jit(ref.attention_ref)(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        k.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)))
    flops = 4 * B * H * S * S * hd
    tpu_us = flops / PEAK_FLOPS * 1e6
    emit("kernels/flash_attention_ref", us_ref,
         f"oracle; tpu_roofline={tpu_us:.1f}us for {flops/1e9:.2f}GF")
    us_k = _time(lambda: ops.flash_attention(q, k, v, block_q=256,
                                             block_k=256))
    emit("kernels/flash_attention_pallas_interp", us_k,
         "interpret-mode correctness path")

    # ssd scan
    B2, S2, H2, P2, N2 = 2, 512, 4, 64, 32
    x = jax.random.normal(ks[3], (B2, S2, H2, P2))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (B2, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[5], (H2,)) * 0.5)
    Bm = jax.random.normal(ks[6], (B2, S2, N2))
    Cm = jax.random.normal(ks[7], (B2, S2, N2))
    from repro.models.ssm import ssd_chunked
    us_ref = _time(lambda: jax.jit(
        lambda *a: ssd_chunked(*a, chunk=128))(x, dt, A, Bm, Cm))
    emit("kernels/ssd_ref", us_ref, "oracle (chunked jnp)")
    us_k = _time(lambda: ops.ssd_scan(x, dt, A, Bm, Cm, chunk=128))
    emit("kernels/ssd_pallas_interp", us_k, "interpret-mode")

    # grouped matmul
    E, C, D, F = 8, 256, 512, 512
    xg = jax.random.normal(ks[0], (E, C, D), jnp.bfloat16)
    wg = jax.random.normal(ks[1], (E, D, F), jnp.bfloat16)
    gs = jnp.full((E,), C, jnp.int32)
    us_ref = _time(lambda: jax.jit(ref.grouped_matmul_ref)(xg, wg, gs))
    gf = 2 * E * C * D * F / 1e9
    emit("kernels/moe_gmm_ref", us_ref,
         f"oracle; tpu_roofline={2*E*C*D*F/PEAK_FLOPS*1e6:.1f}us for {gf:.2f}GF")

    # rmsnorm
    xr = jax.random.normal(ks[2], (4096, 1024))
    wr = jax.random.normal(ks[3], (1024,))
    us_ref = _time(lambda: jax.jit(ref.rmsnorm_ref)(xr, wr))
    bytes_moved = 2 * xr.size * 4
    emit("kernels/rmsnorm_ref", us_ref,
         f"oracle; tpu_roofline={bytes_moved/HBM_BW*1e6:.1f}us (bw-bound)")

    # knn digits (paper app)
    t = jax.random.randint(ks[4], (256, 7), 0, 2**31 - 1,
                           jnp.int32).astype(jnp.uint32)
    r = jax.random.randint(ks[5], (2048, 7), 0, 2**31 - 1,
                           jnp.int32).astype(jnp.uint32)
    lb = jax.random.randint(ks[6], (2048,), 0, 10, jnp.int32)
    us = _time(lambda: ops.knn_digits(t, r, lb))
    emit("kernels/knn_digits", us, "paper DigitRec function (interp)")

    # gqa decode (flash-decoding style)
    BH, Smax, hd2 = 4, 2048, 128
    qd = jax.random.normal(ks[1], (BH, 1, hd2))
    kd = jax.random.normal(ks[2], (BH, Smax, hd2))
    vd = jax.random.normal(ks[3], (BH, Smax, hd2))
    us_ref = _time(lambda: jax.jit(ref.decode_attention_ref)(
        qd, kd, vd, jnp.int32(Smax - 1)))
    cache_bytes = 2 * BH * Smax * hd2 * 4
    emit("kernels/gqa_decode_ref", us_ref,
         f"oracle; tpu_roofline={cache_bytes/HBM_BW*1e6:.1f}us (cache-read bound)")

    # haar window scorer (paper app)
    img = jax.random.normal(ks[7], (240, 320))
    feats = jax.random.normal(ks[0], (16, 24 * 24))
    us = _time(lambda: ops.window_scores(img, feats))
    emit("kernels/haar_window_320x240", us, "paper FaceDet function (interp)")


if __name__ == "__main__":
    main()
