"""Figures 4/5: random app sets under MEDIUM (60 procs) and HIGH (120
procs) background load; Xar-Trek vs the no-migration baselines."""
from benchmarks.common import Timer, emit, run_app_set


def main() -> None:
    for fig, n_bg in (("fig4_medium", 50), ("fig5_high", 114)):
        for n in (5, 10, 15, 20, 25):
            with Timer() as t:
                x86 = run_app_set("always_host", n, n_bg)
                xar = run_app_set("xartrek", n, n_bg)
            gain = 100.0 * (x86 - xar) / x86
            emit(f"{fig}/{n}apps", t.us / 2,
                 f"x86={x86:.0f} xar={xar:.0f} gain={gain:.0f}%")


if __name__ == "__main__":
    main()
