"""Figures 7/8: periodic (wave) workloads.

Fig 7: thirty waves of 20 apps launched every 30 s (medium->high->medium
load over time); average execution time for x86 / FPGA / Xar-Trek.
Fig 8: face-detection throughput under a 10..120-process wave.
"""
import random

from benchmarks.common import BG, Timer, emit, make_sim
from repro.core.sim import PAPER_APPS


def fig7(policy: str, waves: int = 12, per_wave: int = 20,
         interval_ms: float = 30_000.0) -> float:
    sim = make_sim(policy)
    rng = random.Random(11)
    apps = list(PAPER_APPS.values())
    for w in range(waves):
        for _ in range(per_wave):
            sim.submit(rng.choice(apps), at=w * interval_ms)
    sim.run()
    return sim.avg_execution_ms()


def fig8(policy: str) -> float:
    sim = make_sim(policy)
    # wave of background processes: 10 -> 120 -> 10
    for i in range(120):
        start = abs((i % 120) - 60) * 500.0
        sim.submit(BG, at=start, background=True)
    sim.submit(PAPER_APPS["facedet320"], at=100.0, calls=1000)
    sim.run(until=60_000.0, stop_when_idle=False)
    return sim.completed_calls("facedet320") / 60.0


def main() -> None:
    with Timer() as t:
        x86 = fig7("always_host")
        fpga = fig7("always_accel")
        xar = fig7("xartrek")
    emit("fig7/periodic_exec", t.us / 3,
         f"x86={x86:.0f} fpga={fpga:.0f} xar={xar:.0f} "
         f"gain_vs_x86={100*(x86-xar)/x86:.0f}% "
         f"gain_vs_fpga={100*(fpga-xar)/fpga:.0f}%")
    with Timer() as t:
        x86 = fig8("always_host")
        fpga = fig8("always_accel")
        xar = fig8("xartrek")
    emit("fig8/periodic_throughput", t.us / 3,
         f"x86={x86:.2f}img/s fpga={fpga:.2f} xar={xar:.2f}")


if __name__ == "__main__":
    main()
