"""Roofline table: aggregates the dry-run artifacts (§Roofline).

Reads artifacts/dryrun/*.json and prints, per (arch x shape x mesh):
the three terms, the bottleneck, peak bytes/device, useful-compute ratio
and the roofline fraction.  Run the dry-run first:
    python -m repro.launch.dryrun --all --both-meshes
"""
import glob
import json
import os

from benchmarks.common import emit

ART = (os.path.join("artifacts", "dryrun_final")
       if os.path.isdir(os.path.join("artifacts", "dryrun_final"))
       else os.path.join("artifacts", "dryrun"))


def rows():
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            yield json.load(f)


def main() -> None:
    count = ok = 0
    for rec in rows():
        count += 1
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] != "ok":
            emit(name, 0.0, f"SKIP({rec['reason'][:60]})")
            continue
        ok += 1
        r = rec["roofline"]
        m = rec["memory"]
        emit(name, rec["compile_s"] * 1e6,
             f"tC={r['t_compute_s']:.3f}s tM={r['t_memory_s']:.3f}s "
             f"tX={r['t_collective_s']:.3f}s bn={r['bottleneck']} "
             f"useful={r['useful_compute_ratio']:.2f} "
             f"frac={r['roofline_fraction']:.3f} "
             f"peak={m['peak_bytes']/2**30:.2f}GiB")
    emit("roofline/summary", 0.0, f"{ok} compiled cells of {count} artifacts")


if __name__ == "__main__":
    main()
