"""Continuous vs. synchronous batching throughput under Poisson arrivals.

The Figure-6 scenario on real JAX serving: a multi-tenant stream of
generation requests (Poisson arrivals, ragged prompt lengths and token
budgets) served three ways on the same model and weights:

  * synchronous (static) batching — collect up to ``max_slots`` arrived
    requests, left-pad prompts to a fixed width, run the whole batch for
    the batch-max token budget, then pick up the next batch;
  * continuous batching — admit requests into KV slots the moment they
    arrive, interleave prefill with decode, evict finished slots;
  * paged continuous batching (default on; ``--no-paged`` skips it) —
    same engine
    over a shared block pool at the SAME KV memory as the dense cache,
    with twice the decode rows: short requests stop reserving full rows,
    so more of them run concurrently;
  * ACCEL-backend paged serving (``--no-accel`` skips it) — every step
    on the Pallas kernels (interpret mode on CPU runners), proving the
    ACCEL build serves real tokens;
  * forced-migration serving — the same stream through an XarTrekRuntime
    under a scripted ``FlipSchedule`` SchedulingPolicy (HOST -> ACCEL ->
    HOST at fixed decode-decision counts), so the artifact records
    per-target call counts, per-backend decode step times (the asymmetry
    the scheduling policy can exploit) and the migration count.
    ``--json`` embeds ``XarTrekRuntime.summary()`` so CI can see which
    backend actually served tokens;
  * cluster serving (``--cluster N``, default 2; 0 skips) — N engine
    workers behind ONE central scheduler (TCP transport) sharing the
    Algorithm-2 policy over AGGREGATE cross-engine LoadSignals: a
    low-load trickle decodes on HOST, then a burst drives the aggregate
    queue depth over the decode threshold and decode steps migrate to
    ACCEL — real co-tenant load balancing, per-engine tok/s and the
    aggregate migration count land in the JSON artifact
    (``floor.json`` bounds ``cluster_tok_s`` and
    ``cluster_migrations`` from below);
  * process-cluster serving (``--cluster-proc N``; 0 = default skips) —
    the SAME front-end surface over N real OS-process workers
    (``ProcClusterFrontEnd``: per-process JAX runtimes, streaming IPC
    result plane, fault-tolerant supervisor), measured MLPerf
    offline-style: a fixed greedy batch sorted by length, submitted
    closed-loop, spawn/compile/warmup strictly outside the timed
    region, against a 1-process-worker leg of the same work.  Aggregate
    tok/s and the N-vs-1 scaling ratio land in the artifact — the
    threaded cluster's GIL structurally caps that ratio; processes
    don't (``floor.json`` bounds ``cluster_proc_tok_s`` and
    ``cluster_proc_scaling`` from below);
  * sampled-decode serving — the same stream with per-request
    SamplingParams (temperature 0.8, top-k 40, per-request seeds)
    through the in-graph sampler, reporting tok/s plus per-request
    TTFT/TPOT/queue-wait percentiles from the v2 RequestOutput metrics
    (floor.json holds a tok/s floor AND a ttft_p50_s ceiling);
  * prefix-cache serving (``--prefix-zipf N`` sizes it; defaults to
    ``--n-requests``) — a Zipf-popular shared-prefix stream (K distinct
    two-block system prompts, short unique suffixes) served cache-off
    and cache-on at EQUAL KV memory: the cache-on engine must compute
    STRICTLY fewer prefill tokens (asserted) and the artifact reports
    the prefix hit rate, admitted concurrency and TTFT percentiles
    (``floor.json`` bounds ``prefix_hit_rate`` from below and
    ``prefix_ttft_p50_s`` from above);
  * int8 quantised paged KV — the SAME Zipf stream over an int8+scales
    pool given exactly the f32 pool's byte budget: block capacity at
    equal bytes (>= 1.8x, asserted), prefix hit rate, bitwise
    first-token agreement with the f32 engine (asserted; first tokens
    come from exact f32 prefill math) and the full-stream greedy match
    fraction (``floor.json`` bounds ``int8_capacity_ratio``,
    ``int8_prefix_hit_rate``, ``int8_first_token_match`` and
    ``int8_greedy_match_frac`` from below);
  * chunked prefill / disaggregation (``--disagg``) — a Zipf
    long-prompt + short-decode mix served by a mixed fleet with the
    chunk budget off and on, and by a 1 prefill + 1 decode split (KV
    spans over the TCP control plane) at equal per-worker KV memory:
    wall-clock short-request TTFT p99 for all three, the worst
    single-step decode stall under the budget, and handoff/span counts
    (``floor.json`` bounds ``disagg_tok_s`` and
    ``disagg_ttft_p99_improvement`` from below, ``decode_stall_ms``
    from above);
  * speculative decoding (``--spec``) — a decode-heavy greedy stream
    on a target whose top layers are zeroed (each zeroed layer is an
    exact residual identity, so the 1-layer shared draft computes the
    SAME function and acceptance is ~1): spec-off vs spec-on at EQUAL
    target-pool KV (per-request byte-identity asserted — the emitted
    tokens are always the target's own), then the heterogeneous split
    (draft-on-HOST / verify-on-ACCEL through an XarTrekRuntime under a
    scripted policy) with per-target draft/verify call counts from
    ``summary()`` (``floor.json`` bounds ``spec_speedup``,
    ``spec_acceptance_rate`` and ``spec_byte_identical`` from below).

Emits ``serve_cb/*`` rows; derived carries tok/s for each engine, the
continuous/synchronous throughput ratio, and the paged engine's peak
concurrent slots vs. dense (the paging headline).

    PYTHONPATH=src python -m benchmarks.bench_serve_cb

CI smoke mode (tiny stream, JSON artifact, throughput floor):

    PYTHONPATH=src python -m benchmarks.bench_serve_cb \
        --n-requests 8 --rate 40 --json bench_serve_cb.json \
        --check-floor benchmarks/floor.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.policy import Decision, PinAccel
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.models.attention import paged_kv_block_bytes
from repro.serve import (ClusterFrontEnd, ContinuousBatchingEngine,
                         GenerationRequest, ProcClusterFrontEnd,
                         SamplingParams, ServeEngine)
from repro.serve import spec as spec_lib
from repro.serve.scheduler import RequestQueue, poisson_arrivals

MAX_SLOTS = 4
MAX_SEQ = 96
PAD_TO = 32            # static batching pads every prompt to this width
BLOCK_SIZE = 32        # paged engine's KV block width
SEED = 0
# forced-migration schedule: decode-decision counts at which the
# scripted policy flips HOST -> ACCEL and back (well inside even the CI
# smoke stream, whose longest request decodes ~15+ steps)
MIGRATE_AT = (4, 10)
# prefix-cache scenario: K distinct shared prefixes spanning this many
# full KV blocks each (the "same system prompt" multi-tenant shape)
N_PREFIXES = 4
PREFIX_BLOCKS = 2
# disaggregation scenario (--disagg): long "document" prompt width
# (sized so its monolithic prefill is the widest bucket the engine
# serves — the head-of-line block the scenario measures) and the
# per-step chunk budget (= the engines' min_bucket, so every chunk
# call rides the warmed compile signature)
DISAGG_LONG = 88
CHUNK_BUDGET = 8
# speculative-decoding scenario (--spec): draft length (= verify width)
# and the zeroed-target depth — keep 1 live layer so the 1-layer shared
# draft computes exactly the target's function (acceptance ~1), making
# the k-per-2-dispatches bound observable on random weights.  k=8:
# a CPU decode step is weight-streaming-bound, so an 8-token verify
# costs barely more than a 1-token step and the win is DISPATCH
# amortisation — wider k amortises further while acceptance holds
SPEC_DRAFT_LEN = 8
SPEC_KEEP_LAYERS = 1


class FlipSchedule:
    """Scripted SchedulingPolicy: decode decisions 1..at[0] on HOST,
    (at[0], at[1]] on ACCEL, HOST after — the forced
    HOST -> ACCEL -> HOST mid-stream schedule expressed through the
    policy protocol instead of the deprecated ``on_step`` hook.
    Prefills stay on HOST so the flip isolates the decode asymmetry."""

    name = "flip_schedule"

    def __init__(self, at=MIGRATE_AT):
        self.at = at
        self.decodes = 0

    def decide(self, signals, row, residency):
        if not row.app.endswith("_decode"):
            return Decision(TargetKind.HOST)
        self.decodes += 1
        if self.at[0] < self.decodes <= self.at[1] and residency.resident:
            return Decision(TargetKind.ACCEL)
        return Decision(TargetKind.HOST)


class SpecSplit:
    """Scripted SchedulingPolicy for the --spec split leg: the verify
    step runs on ACCEL (once its kernel bank is resident), the draft
    chain and everything else stay on HOST — the headline Xar-Trek
    configuration with two registered binaries busy per round."""

    name = "spec_split"

    def decide(self, signals, row, residency):
        if row.app.endswith("_verify") and residency.resident:
            return Decision(TargetKind.ACCEL)
        return Decision(TargetKind.HOST)


def make_requests(vocab: int, n: int, rate: float, seed: int = SEED,
                  sampling: bool = False) -> list[GenerationRequest]:
    """With ``sampling=True`` every request carries the sampled-decode
    spec (temperature 0.8, top-k 40) and its own seed."""
    rng = np.random.RandomState(seed)
    arrivals = poisson_arrivals(n, rate, seed)
    return [GenerationRequest(
        rng.randint(0, vocab, size=int(rng.randint(4, PAD_TO))),
        max_new_tokens=int(rng.randint(4, 24)),
        arrival_s=t,
        sampling=(SamplingParams(temperature=0.8, top_k=40, seed=1000 + i)
                  if sampling else SamplingParams()))
            for i, t in enumerate(arrivals)]


def make_prefix_requests(vocab: int, n: int, rate: float,
                         seed: int = SEED) -> list[GenerationRequest]:
    """Zipf-popular shared prefixes: ``N_PREFIXES`` distinct two-block
    (64-token) prefixes with popularity ~ 1/rank, each request
    appending a short unique suffix — the multi-tenant shared
    system-prompt stream the prefix cache exists for."""
    rng = np.random.RandomState(seed + 17)
    plen = PREFIX_BLOCKS * BLOCK_SIZE
    prefixes = [rng.randint(0, vocab, size=plen)
                for _ in range(N_PREFIXES)]
    weights = 1.0 / np.arange(1.0, N_PREFIXES + 1)
    weights /= weights.sum()
    return [GenerationRequest(
        np.concatenate([prefixes[rng.choice(N_PREFIXES, p=weights)],
                        rng.randint(0, vocab, size=int(rng.randint(4, 9)))]),
        max_new_tokens=int(rng.randint(4, 9)), arrival_s=t)
        for t in poisson_arrivals(n, rate, seed + 17)]


def total_tokens(reqs: list[GenerationRequest]) -> int:
    return sum(r.max_new_tokens for r in reqs)


def serve_static(engine: ServeEngine,
                 reqs: list[GenerationRequest]) -> float:
    """Static batching: batches of up to MAX_SLOTS arrived requests, each
    left-padded to PAD_TO and run for the batch-max token budget.  The
    batch shape is held fixed at (MAX_SLOTS, PAD_TO) so the baseline
    compiles exactly once (generous: ragged shapes would recompile)."""
    queue = RequestQueue(reqs)
    done = 0
    t0 = time.perf_counter()
    while done < len(reqs):
        now = time.perf_counter() - t0
        batch: list[GenerationRequest] = []
        while len(batch) < MAX_SLOTS:
            r = queue.pop_arrived(now)
            if r is None:
                break
            batch.append(r)
        if not batch:
            nxt = queue.next_arrival()
            time.sleep(max(min(nxt - now, 0.05), 0.001))
            continue
        toks = np.zeros((MAX_SLOTS, PAD_TO), np.int32)
        for i, req in enumerate(batch):
            toks[i, PAD_TO - req.prompt_len:] = req.prompt    # left pad
        engine.generate(toks, max_new_tokens=max(r.max_new_tokens
                                                 for r in batch))
        done += len(batch)
    return time.perf_counter() - t0


def serve_continuous(engine: ContinuousBatchingEngine,
                     reqs: list[GenerationRequest]
                     ) -> tuple[float, dict]:
    t0 = time.perf_counter()
    out = engine.run(reqs)
    elapsed = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    return elapsed, out


def warm(engine, vocab: int, static: bool = False) -> None:
    reqs = [GenerationRequest(np.arange(1, 5, dtype=np.int32) % vocab,
                              max_new_tokens=2)]
    if static:
        serve_static(engine, reqs)
    else:
        serve_continuous(engine, reqs)
        engine.reset_stats()


def latency_percentiles(outputs: dict) -> dict:
    """Per-request latency percentiles from v2 RequestOutput metrics."""
    ttft = [o.ttft_s for o in outputs.values()]
    tpot = [o.tpot_s for o in outputs.values()]
    qw = [o.queue_wait_s for o in outputs.values()]
    return {
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p90_s": float(np.percentile(ttft, 90)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "tpot_p50_s": float(np.percentile(tpot, 50)),
        "tpot_p90_s": float(np.percentile(tpot, 90)),
        "queue_wait_p50_s": float(np.percentile(qw, 50)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=12.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--no-paged", action="store_true",
                    help="skip the paged-engine run")
    ap.add_argument("--no-accel", action="store_true",
                    help="skip the ACCEL-backend and forced-migration runs")
    ap.add_argument("--prefix-zipf", type=int, default=0, metavar="N",
                    help="requests in the shared-prefix Zipf scenario "
                         "(default: --n-requests)")
    ap.add_argument("--cluster", type=int, default=2, metavar="N",
                    help="run N engine workers behind one TCP scheduler "
                         "(0 skips; --no-accel also skips it — the "
                         "cluster migrates steps to the Pallas build)")
    ap.add_argument("--cluster-proc", type=int, default=0, metavar="N",
                    help="run the process-cluster scenario: N OS-process "
                         "engine workers vs a 1-process leg on the same "
                         "offline batch (0 skips — each worker spawns "
                         "its own JAX runtime)")
    ap.add_argument("--disagg", action="store_true",
                    help="run the chunked-prefill / disaggregation "
                         "scenario: a Zipf long-prompt + short-decode "
                         "mix served by a mixed fleet (chunking off and "
                         "on) and by a 1 prefill + 1 decode split at "
                         "equal KV memory")
    ap.add_argument("--spec", action="store_true",
                    help="run the speculative-decoding scenario: a "
                         "decode-heavy greedy stream spec-off vs "
                         "spec-on at equal target-pool KV, plus the "
                         "draft-on-HOST / verify-on-ACCEL split "
                         "through a runtime")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON (CI artifact)")
    ap.add_argument("--check-floor", metavar="PATH",
                    help="fail (exit 1) if tok/s drops below the floors "
                         "in this JSON file")
    # parse_known_args: benchmarks.run invokes main() under ITS argv
    # (--only ...); unknown flags must not crash the module
    args, _ = ap.parse_known_args(argv)

    cfg = dataclasses.replace(reduced(ARCHS["smollm-135m"]), dtype="float32")
    sync = ServeEngine(cfg, seed=args.seed)
    cb = ContinuousBatchingEngine(cfg, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                                  params=sync.params)
    # equal usable KV memory to the dense engine (MAX_SLOTS * MAX_SEQ
    # positions), but 2x the decode rows: the pool, not row count, is
    # the capacity bound, so the ragged stream packs more requests in
    paged = None
    if not args.no_paged:
        paged = ContinuousBatchingEngine(
            cfg, max_slots=2 * MAX_SLOTS, max_seq=MAX_SEQ,
            params=sync.params, paged=True, block_size=BLOCK_SIZE,
            num_blocks=MAX_SLOTS * MAX_SEQ // BLOCK_SIZE, fn_prefix="pcb")

    # warm every compile cache outside the timed runs
    warm(sync, cfg.vocab_size, static=True)
    warm(cb, cfg.vocab_size)
    if paged is not None:
        warm(paged, cfg.vocab_size)

    reqs = make_requests(cfg.vocab_size, args.n_requests, args.rate,
                         args.seed)
    tokens = total_tokens(reqs)

    t_sync = serve_static(sync, [dataclasses.replace(r) for r in reqs])
    t_cb, _ = serve_continuous(cb, [dataclasses.replace(r) for r in reqs])
    results = {
        "n_requests": args.n_requests, "rate_per_s": args.rate,
        "tokens": tokens,
        "sync_tok_s": tokens / t_sync,
        "cb_tok_s": tokens / t_cb,
        "cb_peak_active": cb.slots.stats["peak_active"],
        "cb_vs_sync": (tokens / t_cb) / max(tokens / t_sync, 1e-9),
    }
    if paged is not None:
        t_paged, _ = serve_continuous(paged,
                                      [dataclasses.replace(r) for r in reqs])
        results.update({
            "paged_tok_s": tokens / t_paged,
            "paged_peak_active": paged.slots.stats["peak_active"],
            "paged_preempted": paged.slots.stats["preempted"],
            "paged_vs_dense_cb": (tokens / t_paged) / (tokens / t_cb),
        })

    # sampled decode (temperature 0.8, top-k 40, per-request seeds)
    # through the in-graph sampler, on the ALREADY-WARM paged engine:
    # the greedy run above populated every prefill shape bucket, so this
    # measures steady-state serving — sampling adds no recompiles (the
    # (B,) sampling vectors are data, not shapes) and the TTFT/TPOT
    # percentiles reflect serving latency, not compile noise
    sampled_engine = paged if paged is not None else cb
    sreqs = make_requests(cfg.vocab_size, args.n_requests, args.rate,
                          args.seed, sampling=True)
    t_sampled, souts = serve_continuous(sampled_engine, sreqs)
    results["sampled_cb_tok_s"] = tokens / t_sampled
    results.update(latency_percentiles(souts))

    # prefix caching: the SAME Zipf shared-prefix stream served
    # cache-off then cache-on at equal KV memory (same pool, same
    # rows).  Warm prompts share no scenario prefix, so the measured
    # runs start from a cold index; the cache-on engine must compute
    # strictly fewer prefill tokens — the matched spans — or the
    # feature is not doing its one job
    n_prefix = args.prefix_zipf or args.n_requests
    preqs = make_prefix_requests(cfg.vocab_size, n_prefix, args.rate,
                                 args.seed)
    ptokens = total_tokens(preqs)
    # prefix engines pin kv_cache_dtype=float32: the engine refuses a
    # prefix cache over a lossy pool (f32 compute over the default bf16
    # pool rounds on write), and f32 is the equal-bytes baseline the
    # int8 run below is measured against
    pcfg = dataclasses.replace(cfg, kv_cache_dtype="float32")
    n_pblocks = MAX_SLOTS * MAX_SEQ // BLOCK_SIZE
    pkw = dict(max_slots=2 * MAX_SLOTS, max_seq=MAX_SEQ,
               params=sync.params, paged=True, block_size=BLOCK_SIZE,
               num_blocks=n_pblocks)
    pfx_off = ContinuousBatchingEngine(pcfg, fn_prefix="pfo", **pkw)
    pfx_on = ContinuousBatchingEngine(pcfg, fn_prefix="pfx",
                                      prefix_cache=True, **pkw)
    warm(pfx_off, cfg.vocab_size)
    warm(pfx_on, cfg.vocab_size)
    t_pfx_off, _ = serve_continuous(pfx_off, [dataclasses.replace(r)
                                              for r in preqs])
    t_pfx_on, pouts = serve_continuous(pfx_on, [dataclasses.replace(r)
                                                for r in preqs])
    on_stats, off_stats = pfx_on.prefix_stats(), pfx_off.prefix_stats()
    assert on_stats["prefill_tokens"] < off_stats["prefill_tokens"], (
        "prefix cache computed as many prefill tokens as cache-off",
        on_stats, off_stats)
    pttft = sorted(o.ttft_s for o in pouts.values())
    results.update({
        "prefix_n_requests": n_prefix,
        "prefix_off_tok_s": ptokens / t_pfx_off,
        "prefix_on_tok_s": ptokens / t_pfx_on,
        "prefix_hit_rate": on_stats["prefix_hit_rate"],
        "prefix_hit_tokens": on_stats["prefix_hit_tokens"],
        "prefix_prefill_tokens_on": on_stats["prefill_tokens"],
        "prefix_prefill_tokens_off": off_stats["prefill_tokens"],
        "prefix_cow_forks": on_stats["cow_forks"],
        "prefix_peak_active_on": pfx_on.slots.stats["peak_active"],
        "prefix_peak_active_off": pfx_off.slots.stats["peak_active"],
        "prefix_ttft_p50_s": pttft[len(pttft) // 2],
        "prefix_ttft_p90_s": pttft[int(len(pttft) * 0.9)
                                   if len(pttft) > 1 else 0],
    })

    # int8 quantised pool at EQUAL KV BYTES: the same Zipf stream over
    # an int8+scales pool given exactly the f32 pool's byte budget.
    # The capacity win (>= 1.8x blocks) and the prefix hit rate must
    # hold TOGETHER — more blocks are worthless if quantisation broke
    # block-hash reuse.  Tolerance story: each request's first token
    # comes from exact f32 prefill math and must match the f32 engine
    # bitwise; deeper tokens may flip where the random-init model's
    # top-2 logit margin is below the int8 perturbation, so the full
    # stream gets a match-fraction floor rather than an equality check.
    f32_bytes = paged_kv_block_bytes(BLOCK_SIZE, cfg.num_kv_heads,
                                     cfg.resolved_head_dim, "float32")
    i8_bytes = paged_kv_block_bytes(BLOCK_SIZE, cfg.num_kv_heads,
                                    cfg.resolved_head_dim, "int8")
    n_i8 = int(n_pblocks * f32_bytes) // i8_bytes
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    pfx_i8 = ContinuousBatchingEngine(
        icfg, fn_prefix="pfi", prefix_cache=True,
        allow_lossy_prefix_cache=True,
        **dict(pkw, num_blocks=n_i8))
    warm(pfx_i8, cfg.vocab_size)
    t_pfx_i8, iouts = serve_continuous(pfx_i8, [dataclasses.replace(r)
                                                for r in preqs])
    i8_stats = pfx_i8.prefix_stats()
    cap_ratio = n_i8 / n_pblocks
    assert cap_ratio >= 1.8, (n_i8, n_pblocks)
    firsts = [int(pouts[r].tokens[0]) == int(iouts[r].tokens[0])
              for r in pouts]
    assert all(firsts), "int8 first tokens diverged from f32 prefill"
    matched = total = 0
    for r in pouts:
        a, b = pouts[r].tokens, iouts[r].tokens
        n = min(len(a), len(b))
        matched += int((a[:n] == b[:n]).sum())
        total += n
    results.update({
        "int8_capacity_ratio": cap_ratio,
        "int8_num_blocks": n_i8,
        "int8_prefix_hit_rate": i8_stats["prefix_hit_rate"],
        "int8_on_tok_s": ptokens / t_pfx_i8,
        "int8_first_token_match": sum(firsts) / len(firsts),
        "int8_greedy_match_frac": matched / total,
    })

    t_accel = t_mig = None
    if not args.no_accel:
        # every step on the Pallas kernels (interpret mode on CPU)
        accel = ContinuousBatchingEngine(
            cfg, max_slots=2 * MAX_SLOTS, max_seq=MAX_SEQ,
            params=sync.params, paged=True, block_size=BLOCK_SIZE,
            num_blocks=MAX_SLOTS * MAX_SEQ // BLOCK_SIZE, fn_prefix="acb",
            policy=PinAccel())
        warm(accel, cfg.vocab_size)
        t_accel, _ = serve_continuous(accel,
                                      [dataclasses.replace(r) for r in reqs])
        results["accel_cb_tok_s"] = tokens / t_accel

        # forced HOST -> ACCEL -> HOST schedule through the runtime via
        # the scripted FlipSchedule policy, flipped mid-stream while
        # slots are live: the policy's target choice is a real kernel
        # swap
        rt = XarTrekRuntime(registry=FunctionRegistry(),
                            policy="always_host")
        mig = ContinuousBatchingEngine(
            cfg, max_slots=2 * MAX_SLOTS, max_seq=MAX_SEQ,
            params=sync.params, paged=True, block_size=BLOCK_SIZE,
            num_blocks=MAX_SLOTS * MAX_SEQ // BLOCK_SIZE, fn_prefix="mig",
            runtime=rt)
        warm(mig, cfg.vocab_size)
        rt.call_log.clear()                   # timed region only
        rt.server.policy = FlipSchedule()     # warm steps don't count
        t_mig, _ = serve_continuous(mig, [dataclasses.replace(r)
                                          for r in reqs])
        summary = rt.summary()
        decode_fn = summary["per_function"]["mig_decode"]
        step_ms = {"host": [], "accel": []}
        for rec in rt.call_log:
            if rec["fn"] == "mig_decode":
                step_ms[rec["target"]].append(rec["ms"])
        results.update({
            "mig_tok_s": tokens / t_mig,
            "mig_host_decode_calls": decode_fn["calls"].get("host", 0),
            "mig_accel_decode_calls": decode_fn["calls"].get("accel", 0),
            "mig_migrations": decode_fn["migrations"],
            # per-backend decode step time: the perf asymmetry the
            # scheduling policy can exploit (Fig. 6's lever)
            "mig_host_decode_ms": float(np.mean(step_ms["host"]))
            if step_ms["host"] else None,
            "mig_accel_decode_ms": float(np.mean(step_ms["accel"]))
            if step_ms["accel"] else None,
            "runtime_summary": summary,
        })

    # N engines, one TCP scheduler, shared Algorithm-2 policy over
    # aggregate signals: a low-load trickle decodes on HOST, the burst's
    # queue pressure crosses the decode threshold, steps migrate to
    # ACCEL — the ROADMAP's co-tenant balancing, measured
    t_cluster = None
    if args.cluster and not args.no_accel:
        fe = ClusterFrontEnd(cfg, n_engines=args.cluster, policy="xartrek",
                             transport="tcp", params=sync.params,
                             max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                             worker_prefix="cw")
        fe.set_decode_thresholds(fpga_thr=3.0)
        crng = np.random.RandomState(args.seed)

        def short_req(n_new):
            # prompts fit the warmed 8-wide bucket: no mid-scenario
            # shape-bucket compile can eat the pressure window
            return GenerationRequest(
                crng.randint(0, cfg.vocab_size,
                             size=int(crng.randint(4, 9))),
                max_new_tokens=n_new)

        with fe:
            fe.warmup()
            t0 = time.perf_counter()
            trickle = [fe.submit(short_req(40))]      # low load -> HOST
            time.sleep(0.02)
            burst = [fe.submit(short_req(8))          # pressure -> ACCEL
                     for _ in range(max(args.n_requests,
                                        4 * args.cluster))]
            outs = fe.drain()
            t_cluster = time.perf_counter() - t0
            csummary = fe.summary()
        assert len(outs) == len(trickle) + len(burst)
        ctokens = sum(o.n_tokens for o in outs.values())
        per_engine = {}
        for w in fe.workers:
            wtok = sum(o.n_tokens for rid, o in outs.items()
                       if fe.last_owners.get(rid) == w.worker_id)
            decode = (csummary["per_engine"][w.worker_id]["per_function"]
                      .get(f"{w.worker_id}_decode", {}))
            per_engine[w.worker_id] = {
                "tok_s": wtok / t_cluster,
                "decode_calls": decode.get("calls", {}),
                "migrations": decode.get("migrations", 0),
            }
        results.update({
            "cluster_n": args.cluster,
            "cluster_tok_s": ctokens / t_cluster,
            "cluster_migrations": csummary["migrations"],
            "cluster_decisions": csummary["decisions"],
            "cluster_per_engine": per_engine,
        })

    # process-cluster scaling, MLPerf offline style: a FIXED greedy
    # batch, sorted longest-first (the offline scenario's length-sorted
    # batching), submitted closed-loop to N OS-process workers and to a
    # single-process-worker leg of the exact same work.  Spawn, engine
    # compile and warmup (including the longest prompt bucket) are
    # strictly outside the timed region.  The threaded cluster shares
    # one GIL, so its N-worker aggregate is structurally capped near
    # 1x; real processes are the honest version of the scaling claim.
    t_cproc = None
    if args.cluster_proc:
        prng = np.random.RandomState(args.seed + 13)
        n_p = max(args.n_requests, 8 * args.cluster_proc)
        proc_reqs = sorted(
            (GenerationRequest(
                prng.randint(0, cfg.vocab_size,
                             size=int(prng.randint(4, PAD_TO))),
                max_new_tokens=32,
                sampling=SamplingParams(temperature=0.0))
             for _ in range(n_p)),
            key=lambda r: r.prompt_len + r.max_new_tokens, reverse=True)
        ptok = total_tokens(proc_reqs)

        def proc_leg(n_workers: int) -> tuple[float, dict]:
            with ProcClusterFrontEnd(
                    cfg, n_workers=n_workers, policy="xartrek",
                    seed=args.seed, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                    worker_prefix=f"pw{n_workers}_") as fe:
                fe.warmup(max_prompt=PAD_TO - 1)
                t0 = time.perf_counter()
                for r in proc_reqs:
                    fe.submit(dataclasses.replace(r))
                outs = fe.drain()
                elapsed = time.perf_counter() - t0
                summ = fe.summary()
            assert len(outs) == n_p
            assert sum(o.n_tokens for o in outs.values()) == ptok
            return elapsed, summ

        t_cproc1, _ = proc_leg(1)
        t_cproc, cproc_summ = proc_leg(args.cluster_proc)
        try:
            usable_cores = len(os.sched_getaffinity(0))
        except AttributeError:          # non-Linux
            usable_cores = os.cpu_count() or 1
        results.update({
            "cluster_proc_n": args.cluster_proc,
            "cluster_proc_cores": usable_cores,
            "cluster_proc_tok_s": ptok / t_cproc,
            "cluster_proc_1w_tok_s": ptok / t_cproc1,
            "cluster_proc_scaling": t_cproc1 / t_cproc,
            "cluster_proc_failures": cproc_summ["failures"],
            "cluster_proc_heartbeats": {
                wid: w["heartbeats"]
                for wid, w in cproc_summ["workers"].items()},
        })

    # chunked prefill + prefill/decode disaggregation: an adversarial
    # Zipf long-prompt / short-decode mix served three ways at EQUAL
    # per-worker KV memory — a mixed fleet with chunking off (the
    # baseline whose monolithic long prefills stall co-resident
    # decodes), the same fleet with the chunk budget on (bounded
    # per-step stall, measured), and a 1 prefill + 1 decode split
    # (spans over the TCP control plane; the interactive class never
    # shares an engine with a long prefill).  TTFT is wall-clock
    # submit -> first streamed token, so the disaggregated path pays
    # for its own serialization and handoff in the number it reports.
    t_disagg = None
    if args.disagg:
        # the scenario sets its own pressure: arrivals must outpace a
        # monolithic long prefill or least-loaded routing dodges every
        # head-of-line block and the baseline measures nothing
        n_d = max(args.n_requests, 18)
        d_rate = max(args.rate, 80.0)
        drng = np.random.RandomState(args.seed + 7)
        specs = []                      # (prompt, n_new, is_long)
        for i in range(n_d):
            if i % 3 < 2:               # longs arrive in pairs: one per
                # mixed worker, so the following short finds BOTH
                # workers mid-prefill and least-loaded routing can't
                # dodge the head-of-line block
                specs.append((drng.randint(0, cfg.vocab_size,
                                           size=DISAGG_LONG), 4, True))
            else:                       # interactive short, Zipf decode
                n_new = int(4 + min(drng.zipf(2.0) * 4, 16))
                specs.append((drng.randint(0, cfg.vocab_size,
                                           size=int(drng.randint(4, 9))),
                              n_new, False))
        d_arrivals = poisson_arrivals(n_d, d_rate, args.seed + 7)
        n_dblocks = MAX_SLOTS * MAX_SEQ // BLOCK_SIZE

        def disagg_leg(roles=None, chunk=None, transport="inproc",
                       prefix="dg"):
            kw = dict(paged=True, block_size=BLOCK_SIZE,
                      num_blocks=n_dblocks)
            if roles is not None:
                kw["roles"] = roles
            if chunk is not None:
                kw["prefill_tokens_per_step"] = chunk
            fe = ClusterFrontEnd(cfg, n_engines=2, policy="xartrek",
                                 transport=transport, params=sync.params,
                                 max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                                 worker_prefix=prefix, **kw)
            ttft, rids = {}, []
            with fe:
                fe.warmup()
                # compile the long-prompt path (monolithic bucket or
                # span tier) outside the measured window — on EVERY
                # decode-capable worker, not just the least-loaded one
                long_warm = np.arange(1, DISAGG_LONG + 1,
                                      dtype=np.int32) % cfg.vocab_size
                if roles is None:
                    warm_h = [w.submit(GenerationRequest(
                        long_warm, max_new_tokens=2))
                        for w in fe.workers]
                    for h in warm_h:
                        h.result(timeout=120)
                else:
                    fe.submit(GenerationRequest(long_warm,
                                                max_new_tokens=2))
                    fe.drain()
                for w in fe.workers:
                    w.engine.reset_stats()
                t0 = time.perf_counter()
                for (prompt, n_new, _), arr in zip(specs, d_arrivals):
                    now = time.perf_counter() - t0
                    if arr > now:
                        time.sleep(arr - now)
                    req = GenerationRequest(prompt, max_new_tokens=n_new)
                    sub = time.perf_counter()

                    def cb(_tok, rid=req.req_id, sub=sub):
                        ttft.setdefault(rid, time.perf_counter() - sub)
                    rids.append(req.req_id)
                    fe.submit(req, on_token=cb)
                outs = fe.drain()
                elapsed = time.perf_counter() - t0
                summ = fe.summary()
            tok = sum(o.n_tokens for o in outs.values())
            short = [ttft[rid] for rid, (_, _, is_long)
                     in zip(rids, specs) if not is_long]
            return (tok, elapsed,
                    float(np.percentile(short, 99)), summ)

        _, _, base_p99, _ = disagg_leg(prefix="db")
        chk_tok, chk_t, chk_p99, chk_summ = disagg_leg(
            chunk=CHUNK_BUDGET, prefix="dc")
        dtokens, t_disagg, dis_p99, dis_summ = disagg_leg(
            roles=("prefill", "decode"), chunk=CHUNK_BUDGET,
            transport="tcp", prefix="dd")
        chunked = chk_summ["chunked_prefill"].values()
        results.update({
            "disagg_baseline_ttft_p99_s": base_p99,
            "chunked_mixed_tok_s": chk_tok / chk_t,
            "chunked_mixed_ttft_p99_s": chk_p99,
            # worst single-step decode stall under the chunk budget —
            # the SLO number floor.json holds a ceiling on
            "decode_stall_ms": max(
                v["decode_stall_max_ms"] for v in chunked),
            "decode_stall_total_ms": sum(
                v["decode_stall_ms"] for v in chunked),
            "disagg_tok_s": dtokens / t_disagg,
            "disagg_ttft_p99_s": dis_p99,
            "disagg_ttft_p99_improvement": base_p99 / max(dis_p99, 1e-9),
            "disagg_handoffs": dis_summ["handoffs"],
            "disagg_spans": sum(
                v["spans_admitted"]
                for v in dis_summ["chunked_prefill"].values()),
        })

    # speculative decoding: spec-off vs spec-on at EQUAL target-pool KV
    # on a zeroed-top-layers target (the 1-layer shared draft then
    # computes exactly the target's function, so acceptance ~1 and the
    # k-per-2-dispatches bound is observable), then the heterogeneous
    # draft-on-HOST / verify-on-ACCEL split through a runtime.  The
    # stream is decode-heavy (short prompts, long budgets): that is the
    # regime speculation exists for, and the one the floor bounds.
    t_spec = None
    if args.spec:
        zp = spec_lib.zero_top_layers(sync.params, SPEC_KEEP_LAYERS)
        n_s = max(args.n_requests, 8)

        # closed-loop (all requests pre-arrived): an open-loop trickle
        # at smoke rates is arrival-bound, and a speedup of decode
        # dispatches can't show up in time spent WAITING for arrivals.
        # Decode-heavy (short prompts, 64 new tokens) so the measured
        # ratio is the decode-path speedup, not prefill dilution.
        def spec_reqs():
            rng = np.random.RandomState(args.seed + 11)
            return [GenerationRequest(
                rng.randint(0, cfg.vocab_size, size=int(rng.randint(4, 9))),
                max_new_tokens=64, arrival_s=0.0)
                for _ in range(n_s)]

        stok = total_tokens(spec_reqs())
        # lossless f32 target pool (same for EVERY leg): the zeroed-top
        # construction makes draft == target exactly, but the default
        # bf16 pool rounds KV on write where the dense f32 draft cache
        # doesn't — occasional argmax flips that cap acceptance ~0.94
        # and are noise in THIS scenario's k-per-2-dispatches bound
        # (acceptance is exactly 1.0 on a lossless pool)
        scfg = dataclasses.replace(cfg, kv_cache_dtype="float32")
        skw = dict(max_slots=MAX_SLOTS, max_seq=MAX_SEQ, params=zp,
                   paged=True, block_size=BLOCK_SIZE,
                   num_blocks=MAX_SLOTS * MAX_SEQ // BLOCK_SIZE)
        spkw = dict(spec_decode=True, spec_draft_len=SPEC_DRAFT_LEN,
                    spec_draft_layers=SPEC_KEEP_LAYERS)
        s_off = ContinuousBatchingEngine(scfg, fn_prefix="sb", **skw)
        s_on = ContinuousBatchingEngine(scfg, fn_prefix="ss", **spkw, **skw)
        warm(s_off, cfg.vocab_size)
        warm(s_on, cfg.vocab_size)
        # best-of-3, legs interleaved: the floored number is a RATIO of
        # two short wall-clock runs, so one co-tenant scheduling blip on
        # a shared CI runner can skew a single pair; the fastest rep of
        # each leg is the least-interfered measurement of the same
        # fixed work (identical token streams every rep — asserted)
        t_s_off, t_spec, off_outs, identical = np.inf, np.inf, None, True
        for _ in range(3):
            off_reqs, on_reqs = spec_reqs(), spec_reqs()
            t_off_i, off_outs = serve_continuous(s_off, off_reqs)
            t_on_i, on_outs = serve_continuous(s_on, on_reqs)
            t_s_off, t_spec = min(t_s_off, t_off_i), min(t_spec, t_on_i)
            identical = identical and all(
                np.array_equal(off_outs[a.req_id].tokens,
                               on_outs[b.req_id].tokens)
                for a, b in zip(off_reqs, on_reqs))
        sstats = s_on.spec_stats()

        # split leg: draft and verify registered as DISTINCT binaries,
        # dispatched to different targets by the scripted policy
        s_rt = XarTrekRuntime(registry=FunctionRegistry(),
                              policy="always_host")
        s_split = ContinuousBatchingEngine(scfg, fn_prefix="sx",
                                           runtime=s_rt, **spkw, **skw)
        s_rt.server.policy = SpecSplit()
        warm(s_split, cfg.vocab_size)
        split_reqs = spec_reqs()
        t_s_split, split_outs = serve_continuous(s_split, split_reqs)
        identical = identical and all(
            np.array_equal(off_outs[a.req_id].tokens,
                           split_outs[b.req_id].tokens)
            for a, b in zip(off_reqs, split_reqs))
        spf = s_rt.summary()["per_function"]
        results.update({
            "spec_off_tok_s": stok / t_s_off,
            "spec_on_tok_s": stok / t_spec,
            "spec_speedup": t_s_off / t_spec,
            "spec_acceptance_rate": sstats["spec_acceptance_rate"],
            "spec_rounds": sstats["spec_rounds"],
            "spec_emitted_tokens": sstats["spec_emitted_tokens"],
            "spec_byte_identical": 1.0 if identical else 0.0,
            "spec_split_tok_s": stok / t_s_split,
            "spec_draft_calls_host":
                spf["sx_draft"]["calls"].get("host", 0),
            "spec_draft_calls_accel":
                spf["sx_draft"]["calls"].get("accel", 0),
            "spec_verify_calls_host":
                spf["sx_verify"]["calls"].get("host", 0),
            "spec_verify_calls_accel":
                spf["sx_verify"]["calls"].get("accel", 0),
        })

    util = cb.stats["decode_row_util"] / max(cb.stats["decode_steps"], 1)
    emit("serve_cb/sync", t_sync * 1e6 / tokens,
         f"{results['sync_tok_s']:.1f}tok/s")
    emit("serve_cb/continuous", t_cb * 1e6 / tokens,
         f"{results['cb_tok_s']:.1f}tok/s util={util:.2f}")
    emit("serve_cb/ratio", 0.0,
         f"continuous_vs_sync={results['cb_vs_sync']:.2f}x")
    if paged is not None:
        emit("serve_cb/paged", t_paged * 1e6 / tokens,
             f"{results['paged_tok_s']:.1f}tok/s "
             f"peak_slots={results['paged_peak_active']}"
             f"(dense={results['cb_peak_active']}) "
             f"preempted={results['paged_preempted']}")
    emit("serve_cb/sampled", t_sampled * 1e6 / tokens,
         f"{results['sampled_cb_tok_s']:.1f}tok/s t=0.8 k=40 "
         f"ttft_p50={results['ttft_p50_s'] * 1e3:.0f}ms "
         f"tpot_p50={results['tpot_p50_s'] * 1e3:.1f}ms")
    emit("serve_cb/prefix", t_pfx_on * 1e6 / ptokens,
         f"{results['prefix_on_tok_s']:.1f}tok/s "
         f"hit_rate={results['prefix_hit_rate']:.2f} "
         f"prefill={results['prefix_prefill_tokens_on']}"
         f"(off={results['prefix_prefill_tokens_off']}) "
         f"peak_slots={results['prefix_peak_active_on']}"
         f"(off={results['prefix_peak_active_off']}) "
         f"cow={results['prefix_cow_forks']} "
         f"ttft_p50={results['prefix_ttft_p50_s'] * 1e3:.0f}ms")
    emit("serve_cb/prefix_int8", t_pfx_i8 * 1e6 / ptokens,
         f"{results['int8_on_tok_s']:.1f}tok/s "
         f"capacity={results['int8_capacity_ratio']:.2f}x "
         f"({results['int8_num_blocks']}blk vs {n_pblocks}) "
         f"hit_rate={results['int8_prefix_hit_rate']:.2f} "
         f"first_tok_match={results['int8_first_token_match']:.2f} "
         f"greedy_match={results['int8_greedy_match_frac']:.2f}")
    if t_accel is not None:
        emit("serve_cb/accel", t_accel * 1e6 / tokens,
             f"{results['accel_cb_tok_s']:.1f}tok/s pallas")
        hd_ms = results["mig_host_decode_ms"]
        ad_ms = results["mig_accel_decode_ms"]
        emit("serve_cb/migration", t_mig * 1e6 / tokens,
             f"{results['mig_tok_s']:.1f}tok/s "
             f"host={results['mig_host_decode_calls']}x"
             f"{'' if hd_ms is None else f'{hd_ms:.1f}ms'} "
             f"accel={results['mig_accel_decode_calls']}x"
             f"{'' if ad_ms is None else f'{ad_ms:.1f}ms'} "
             f"migrations={results['mig_migrations']}")
    if t_cluster is not None:
        per_eng = " ".join(
            f"{wid}={pe['tok_s']:.1f}tok/s(mig={pe['migrations']})"
            for wid, pe in results["cluster_per_engine"].items())
        emit("serve_cb/cluster", t_cluster * 1e6 / max(ctokens, 1),
             f"{results['cluster_tok_s']:.1f}tok/s n={args.cluster} "
             f"migrations={results['cluster_migrations']} {per_eng}")
    if t_cproc is not None:
        emit("serve_cb/cluster_proc", t_cproc * 1e6 / max(ptok, 1),
             f"{results['cluster_proc_tok_s']:.1f}tok/s "
             f"n={args.cluster_proc} "
             f"scaling={results['cluster_proc_scaling']:.2f}x "
             f"(1w={results['cluster_proc_1w_tok_s']:.1f}tok/s, "
             f"cores={results['cluster_proc_cores']}) "
             f"failures={results['cluster_proc_failures']}")
    if t_disagg is not None:
        emit("serve_cb/disagg", t_disagg * 1e6 / max(dtokens, 1),
             f"{results['disagg_tok_s']:.1f}tok/s "
             f"short_ttft_p99={results['disagg_ttft_p99_s'] * 1e3:.0f}ms"
             f"(mixed={results['disagg_baseline_ttft_p99_s'] * 1e3:.0f}"
             f"ms chunked={results['chunked_mixed_ttft_p99_s'] * 1e3:.0f}"
             f"ms) stall_max={results['decode_stall_ms']:.0f}ms "
             f"handoffs={results['disagg_handoffs']} "
             f"spans={results['disagg_spans']}")
    if t_spec is not None:
        emit("serve_cb/spec", t_spec * 1e6 / stok,
             f"{results['spec_on_tok_s']:.1f}tok/s "
             f"speedup={results['spec_speedup']:.2f}x "
             f"accept={results['spec_acceptance_rate']:.2f} "
             f"identical={int(results['spec_byte_identical'])} "
             f"split={results['spec_split_tok_s']:.1f}tok/s "
             f"draft_host={results['spec_draft_calls_host']} "
             f"verify_accel={results['spec_verify_calls_accel']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)

    if args.check_floor:
        with open(args.check_floor) as f:
            floor = json.load(f)
        failed = []
        for key, bound in floor.items():
            # *_min keys are floors (got >= bound); *_max keys are
            # ceilings (got <= bound) — e.g. the TTFT latency bound
            ceiling = key.endswith("_max")
            name = key.removesuffix("_max" if ceiling else "_min")
            got = results.get(name)
            if got is None:
                # a bound with no matching result (typo'd key, renamed
                # metric, --no-paged) must fail loudly, not pass vacuously
                failed.append(f"{key}: no result named {name!r}")
            elif ceiling and got > bound:
                failed.append(f"{name}={got:.2f} > ceiling {bound}")
            elif not ceiling and got < bound:
                failed.append(f"{name}={got:.2f} < floor {bound}")
        if failed:
            print("FLOOR CHECK FAILED: " + "; ".join(failed),
                  file=sys.stderr)
            return 1
        print(f"floor check passed ({len(floor)} bounds)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
