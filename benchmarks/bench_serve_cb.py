"""Continuous vs. synchronous batching throughput under Poisson arrivals.

The Figure-6 scenario on real JAX serving: a multi-tenant stream of
generation requests (Poisson arrivals, ragged prompt lengths and token
budgets) served two ways on the same model and weights:

  * synchronous (static) batching — collect up to ``max_slots`` arrived
    requests, left-pad prompts to a fixed width, run the whole batch for
    the batch-max token budget, then pick up the next batch;
  * continuous batching — admit requests into KV slots the moment they
    arrive, interleave prefill with decode, evict finished slots.

Emits ``serve_cb/*`` rows; derived carries tok/s for both engines and
the continuous/synchronous throughput ratio (the acceptance headline).

    PYTHONPATH=src python -m benchmarks.bench_serve_cb
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro.configs import ARCHS, reduced
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine
from repro.serve.scheduler import RequestQueue, poisson_arrivals

MAX_SLOTS = 4
MAX_SEQ = 96
PAD_TO = 32            # static batching pads every prompt to this width
N_REQUESTS = 24
RATE_PER_S = 12.0      # Poisson arrival rate
SEED = 0


def make_requests(vocab: int, seed: int = SEED) -> list[Request]:
    rng = np.random.RandomState(seed)
    arrivals = poisson_arrivals(N_REQUESTS, RATE_PER_S, seed)
    return [Request(rng.randint(0, vocab, size=int(rng.randint(4, PAD_TO))),
                    max_new_tokens=int(rng.randint(4, 24)),
                    arrival_s=t)
            for t in arrivals]


def total_tokens(reqs: list[Request]) -> int:
    return sum(r.max_new_tokens for r in reqs)


def serve_static(engine: ServeEngine, reqs: list[Request]) -> float:
    """Static batching: batches of up to MAX_SLOTS arrived requests, each
    left-padded to PAD_TO and run for the batch-max token budget.  The
    batch shape is held fixed at (MAX_SLOTS, PAD_TO) so the baseline
    compiles exactly once (generous: ragged shapes would recompile)."""
    queue = RequestQueue(reqs)
    done = 0
    t0 = time.perf_counter()
    while done < len(reqs):
        now = time.perf_counter() - t0
        batch: list[Request] = []
        while len(batch) < MAX_SLOTS:
            r = queue.pop_arrived(now)
            if r is None:
                break
            batch.append(r)
        if not batch:
            nxt = queue.next_arrival()
            time.sleep(max(min(nxt - now, 0.05), 0.001))
            continue
        toks = np.zeros((MAX_SLOTS, PAD_TO), np.int32)
        for i, r in enumerate(batch):
            toks[i, PAD_TO - r.prompt_len:] = r.prompt        # left pad
        engine.generate(toks, max_new_tokens=max(r.max_new_tokens
                                                 for r in batch))
        done += len(batch)
    return time.perf_counter() - t0


def serve_continuous(engine: ContinuousBatchingEngine,
                     reqs: list[Request]) -> float:
    t0 = time.perf_counter()
    out = engine.serve(reqs)
    elapsed = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    return elapsed


def main() -> None:
    cfg = dataclasses.replace(reduced(ARCHS["smollm-135m"]), dtype="float32")
    sync = ServeEngine(cfg, seed=SEED)
    cb = ContinuousBatchingEngine(cfg, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                                  params=sync.params)

    # warm both compile caches outside the timed runs
    warm = [Request(np.arange(1, 5, dtype=np.int32) % cfg.vocab_size,
                    max_new_tokens=2)]
    serve_static(sync, [dataclasses.replace(w, arrival_s=0.0) for w in warm])
    serve_continuous(cb, warm)
    cb.stats = {"prefills": 0, "decode_steps": 0, "decode_row_util": 0.0}

    reqs = make_requests(cfg.vocab_size)
    tokens = total_tokens(reqs)

    t_sync = serve_static(sync, [dataclasses.replace(r) for r in reqs])
    t_cb = serve_continuous(cb, [dataclasses.replace(r) for r in reqs])

    sync_tps = tokens / t_sync
    cb_tps = tokens / t_cb
    util = cb.stats["decode_row_util"] / max(cb.stats["decode_steps"], 1)
    emit("serve_cb/sync", t_sync * 1e6 / tokens, f"{sync_tps:.1f}tok/s")
    emit("serve_cb/continuous", t_cb * 1e6 / tokens,
         f"{cb_tps:.1f}tok/s util={util:.2f}")
    emit("serve_cb/ratio", 0.0,
         f"continuous_vs_sync={cb_tps / max(sync_tps, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
