"""Figure 3: average execution time of random app sets at LOW load
(1..5 apps, fewer than the 6 x86 cores) for x86 / FPGA / ARM / Xar-Trek."""
from benchmarks.common import Timer, emit, run_app_set


def main() -> None:
    for n in (1, 2, 3, 4, 5):
        with Timer() as t:
            x86 = run_app_set("always_host", n, 0)
            fpga = run_app_set("always_accel", n, 0)
            arm = run_app_set("always_aux", n, 0)
            xar = run_app_set("xartrek", n, 0)
        gain_vs_fpga = 100.0 * (fpga - xar) / fpga
        emit(f"fig3/{n}apps", t.us / 4,
             f"x86={x86:.0f} fpga={fpga:.0f} arm={arm:.0f} xar={xar:.0f} "
             f"gain_vs_fpga={gain_vs_fpga:.0f}%")


if __name__ == "__main__":
    main()
