"""Table 1: per-benchmark execution times on x86 / FPGA / ARM.

The profiles are the calibration inputs (from the paper's measurements);
this benchmark runs each app in isolation through the simulator on each
forced target and checks the sim reproduces the isolated times exactly
(queueing-free), i.e. the platform model is faithful at the fixed point.
"""
from benchmarks.common import Timer, emit, make_sim
from repro.core.sim import PAPER_APPS


def main() -> None:
    policies = [("always_host", "x86"), ("always_accel", "fpga"),
                ("always_aux", "arm")]
    for app in PAPER_APPS.values():
        row = []
        with Timer() as t:
            for policy, label in policies:
                sim = make_sim(policy)
                sim.submit(app, at=0.0)
                sim.run()
                row.append((label, sim.avg_execution_ms()))
        want = {"x86": app.x86_ms, "fpga": app.fpga_ms, "arm": app.arm_ms}
        for label, got in row:
            ok = abs(got - want[label]) < 1e-6
            emit(f"table1/{app.name}/{label}", t.us / 3,
                 f"{got:.0f}ms(expected {want[label]:.0f} ok={ok})")


if __name__ == "__main__":
    main()
