"""Figure 10: binary sizes — serialized executables of the DigitRec
migratable function for (i) single-target x86, (ii) the traditional
x86+FPGA pair, and (iii) the full Xar-Trek multi-target binary.

Multi-target is necessarily the largest (it subsumes both baselines);
reported in bytes via jax.export serialization.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core.binary import MultiTargetBinary
from repro.core.function import FunctionRegistry, MigratableFunction
from repro.core.targets import TargetKind
from repro.kernels import ops, ref


def _host_knn(test, train, labels):
    d = ref.hamming_ref(test, train)
    _, idx = jax.lax.top_k(-d, 3)
    votes = labels[idx]
    counts = jax.vmap(lambda v: jnp.bincount(v, length=10))(votes)
    return jnp.argmax(counts, -1).astype(jnp.int32)


def _aux_knn(test, train, labels):           # "ARM": same ref, alt target
    return _host_knn(test, train, labels)


def _accel_knn(test, train, labels):
    return ops.knn_digits(test, train, labels)


def main() -> None:
    key = jax.random.PRNGKey(0)
    test = jax.random.randint(key, (64, 7), 0, 2**31 - 1,
                              jnp.int32).astype(jnp.uint32)
    train = jax.random.randint(key, (512, 7), 0, 2**31 - 1,
                               jnp.int32).astype(jnp.uint32)
    labels = jax.random.randint(key, (512,), 0, 10, jnp.int32)
    specs = tuple(jax.ShapeDtypeStruct(x.shape, x.dtype)
                  for x in (test, train, labels))

    def sizes_for(variants) -> int:
        reg = FunctionRegistry()
        fn = MigratableFunction("knn", "digitrec", variants)
        reg.register(fn)
        binary = MultiTargetBinary(fn)
        with Timer() as t:
            s = binary.serialized_sizes(*specs)
        return sum(s.values()), t.us

    x86_only, us1 = sizes_for({TargetKind.HOST: _host_knn})
    x86_fpga, us2 = sizes_for({TargetKind.HOST: _host_knn,
                               TargetKind.ACCEL: _accel_knn})
    xartrek, us3 = sizes_for({TargetKind.HOST: _host_knn,
                              TargetKind.AUX: _aux_knn,
                              TargetKind.ACCEL: _accel_knn})
    emit("fig10/x86_only", us1, f"{x86_only}B")
    emit("fig10/x86_fpga", us2, f"{x86_fpga}B "
         f"(+{100*(x86_fpga-x86_only)/x86_only:.0f}% vs x86)")
    emit("fig10/xartrek_multi", us3, f"{xartrek}B "
         f"(+{100*(xartrek-x86_only)/x86_only:.0f}% vs x86; subsumes both)")
    assert xartrek >= x86_fpga >= x86_only


if __name__ == "__main__":
    main()
