"""Table 4: BFS (pointer-chasing) is FPGA-hostile at every size — the
estimator must refuse to produce a finite FPGA threshold, so Xar-Trek
always leaves BFS on x86."""
from benchmarks.common import Timer, emit
from repro.core.estimator import estimate_table
from repro.core.sim import BFS_TABLE4, bfs_profile
from repro.core.thresholds import INF


def main() -> None:
    for nodes, (x86_ms, fpga_ms) in BFS_TABLE4.items():
        app = bfs_profile(nodes)
        with Timer() as t:
            table = estimate_table({app.name: app}, max_load=64)
        thr = table.rows[app.name].fpga_thr
        emit(f"table4/bfs{nodes}", t.us,
             f"x86={x86_ms}ms fpga={fpga_ms}ms fpga_thr="
             f"{'inf(never migrate)' if thr == INF else thr}")


if __name__ == "__main__":
    main()
