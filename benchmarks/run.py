"""Benchmark orchestrator: one module per paper table/figure plus the
kernel and roofline benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,table2]
"""
import argparse
import sys
import traceback

MODULES = [
    "benchmarks.bench_table1",
    "benchmarks.bench_table2",
    "benchmarks.bench_table4",
    "benchmarks.bench_fig3",
    "benchmarks.bench_fig45",
    "benchmarks.bench_fig6",
    "benchmarks.bench_fig78",
    "benchmarks.bench_fig9",
    "benchmarks.bench_fig10",
    "benchmarks.bench_kernels",
    "benchmarks.bench_roofline",
    "benchmarks.bench_serve_cb",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    wanted = [w for w in args.only.split(",") if w]
    print("name,us_per_call,derived")
    failures = []
    for modname in MODULES:
        if wanted and not any(w in modname for w in wanted):
            continue
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append((modname, repr(e)))
            traceback.print_exc(limit=3, file=sys.stderr)
            print(f"{modname},0.0,ERROR({e!r})")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark modules failed")


if __name__ == "__main__":
    main()
