"""Figure 9: profitable-workload fraction.  Fixed 120-process load,
10-app sets sweeping the CG_A (FPGA-hostile) : Digit2000 (FPGA-friendly)
ratio from 0% to 100% hostile."""
from benchmarks.common import BG, Timer, emit, make_sim
from repro.core.sim import PAPER_APPS


def run(policy: str, n_hostile: int) -> float:
    sim = make_sim(policy)
    for _ in range(110):
        sim.submit(BG, at=0.0, background=True)
    for i in range(10):
        app = PAPER_APPS["cg_a"] if i < n_hostile else PAPER_APPS["digit2000"]
        sim.submit(app, at=10.0)
    sim.run()
    return sim.avg_execution_ms()


def main() -> None:
    for n_hostile in (0, 2, 4, 5, 6, 8, 10):
        with Timer() as t:
            x86 = run("always_host", n_hostile)
            xar = run("xartrek", n_hostile)
        gain = 100.0 * (x86 - xar) / x86
        emit(f"fig9/{n_hostile*10}pct_hostile", t.us / 2,
             f"x86={x86:.0f} xar={xar:.0f} gain={gain:.0f}%")


if __name__ == "__main__":
    main()
