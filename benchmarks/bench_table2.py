"""Table 2: threshold estimation (step G) vs the paper's values."""
import math

from benchmarks.common import Timer, emit
from repro.core.estimator import estimate_table
from repro.core.sim import PAPER_APPS

PAPER_TABLE2 = {  # benchmark -> (FPGA_THR, ARM_THR)
    "cg_a": (31, 25), "facedet320": (16, 31), "facedet640": (0, 23),
    "digit500": (0, 18), "digit2000": (0, 17),
}


def main() -> None:
    with Timer() as t:
        table = estimate_table(PAPER_APPS)
    for row in table.as_table2():
        name = row["Benchmark"]
        fpga = max(0, math.ceil(row["FPGA_THR"]))
        arm = max(0, math.ceil(row["ARM_THR"]))
        pf, pa = PAPER_TABLE2[name]
        emit(f"table2/{name}", t.us / len(PAPER_TABLE2),
             f"FPGA_THR={fpga}(paper {pf}) ARM_THR={arm}(paper {pa})")


if __name__ == "__main__":
    main()
