"""Figure 6: face-detection throughput (images/min) vs background load.

The multi-image FaceDet320 app calls its selected function once per
image; the scheduler re-decides per call.  Past the FPGA threshold
Xar-Trek migrates and throughput jumps (paper: ~4x).  Also shows the
pre-configuration effect: Xar-Trek with a hot bank beats always-FPGA
with a cold one.
"""
from benchmarks.common import BG, Timer, emit, make_sim
from repro.core.sim import PAPER_APPS

WINDOW_MS = 60_000.0


def throughput(policy: str, n_bg: int, hot_bank: bool) -> float:
    sim = make_sim(policy, hot_bank=hot_bank)
    for _ in range(n_bg):
        sim.submit(BG, at=0.0, background=True)
    app = PAPER_APPS["facedet320"]
    sim.submit(app, at=10.0, calls=1000)
    sim.run(until=WINDOW_MS, stop_when_idle=False)
    return sim.completed_calls("facedet320") / (WINDOW_MS / 1e3)


def main() -> None:
    for n_bg in (0, 25, 50, 75, 100):
        with Timer() as t:
            x86 = throughput("always_host", n_bg, hot_bank=True)
            fpga_cold = throughput("always_accel", n_bg, hot_bank=False)
            xar = throughput("xartrek", n_bg, hot_bank=True)
        ratio = xar / max(x86, 1e-9)
        emit(f"fig6/{n_bg}bg", t.us / 3,
             f"x86={x86:.2f}img/s fpga_cold={fpga_cold:.2f} "
             f"xar={xar:.2f} xar_vs_x86={ratio:.1f}x")


if __name__ == "__main__":
    main()
