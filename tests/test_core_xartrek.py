"""Xar-Trek core: Algorithm 1/2 unit + property tests, scheduler,
kernel bank, estimator, simulator reproduction of the paper's claims."""
import copy
import math
import random

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis test dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.estimator import estimate_table, estimate_threshold, host_time_model
from repro.core.kernel_bank import KernelBank, partition
from repro.core.monitor import LoadMonitor
from repro.core.policy import schedule
from repro.core.profile import ProfileManifest
from repro.core.scheduler import (SchedulerServer, TcpSchedulerClient,
                                  TcpSchedulerServer)
from repro.core.sim import (AppProfile, MGB_MS, PAPER_APPS, PlatformSim,
                            bfs_profile)
from repro.core.targets import DEFAULT_PLATFORM, TargetKind
from repro.core.thresholds import INF, ThresholdRow, ThresholdTable

finite_or_inf = st.one_of(st.floats(0, 1e6), st.just(INF))


# ------------------------------------------------------------ Algorithm 2

def test_policy_low_load_stays_host():
    row = ThresholdRow("a", "K", fpga_thr=16, arm_thr=31)
    d = schedule(cpu_load=3, row=row, kernel_resident=True)
    assert d.target == TargetKind.HOST and not d.reconfigure


def test_policy_reconfigure_branch_hides_latency():
    """Above FPGA_THR with a cold bank: stay on a CPU target and kick an
    async reconfiguration (paper §3.4)."""
    row = ThresholdRow("a", "K", fpga_thr=16, arm_thr=31)
    d = schedule(cpu_load=20, row=row, kernel_resident=False)
    assert d.target == TargetKind.HOST and d.reconfigure
    d = schedule(cpu_load=40, row=row, kernel_resident=False)
    assert d.target == TargetKind.AUX and d.reconfigure


def test_policy_prefers_smaller_threshold():
    row = ThresholdRow("a", "K", fpga_thr=16, arm_thr=31)
    assert schedule(20, row, True).target == TargetKind.ACCEL
    row2 = ThresholdRow("a", "K", fpga_thr=31, arm_thr=16)
    assert schedule(40, row2, True).target == TargetKind.AUX


@given(load=st.floats(0, 1e6), fpga=finite_or_inf, arm=finite_or_inf,
       resident=st.booleans())
@settings(max_examples=300, deadline=None)
def test_policy_total_and_consistent(load, fpga, arm, resident):
    """Property: the policy is total, never emits ACCEL with a cold bank,
    and never migrates when the load is under both thresholds."""
    row = ThresholdRow("a", "K", fpga_thr=fpga, arm_thr=arm)
    d = schedule(load, row, resident)
    assert d.target in TargetKind
    if d.target == TargetKind.ACCEL:
        assert resident and load > fpga
    if load <= min(fpga, arm):
        assert d.target == TargetKind.HOST


# ------------------------------------------------------------ Algorithm 1

def test_threshold_update_host_lowers_fpga_thr():
    t = ThresholdTable()
    r = t.row("app")
    r.fpga_exec = 100.0
    r.fpga_thr = 50.0
    t.update("app", TargetKind.HOST, exec_time=200.0, cpu_load=30.0)
    assert r.fpga_thr == 30.0          # Alg.1 l.4-5


def test_threshold_update_accel_backoff():
    t = ThresholdTable()
    r = t.row("app")
    r.x86_exec = 100.0
    r.fpga_thr = 10.0
    t.update("app", TargetKind.ACCEL, exec_time=500.0, cpu_load=30.0)
    assert r.fpga_thr == 11.0          # Alg.1 l.19-21 (increase)


@given(st.lists(st.tuples(st.sampled_from(list(TargetKind)),
                          st.floats(1.0, 1e5), st.floats(0, 200)),
                min_size=1, max_size=50))
@settings(max_examples=200, deadline=None)
def test_threshold_invariants(events):
    """Properties: thresholds stay non-negative; HOST observations can only
    lower thresholds; AUX/ACCEL observations can only raise their own."""
    t = ThresholdTable()
    for kind, exec_time, load in events:
        r = t.row("app")
        before = (r.fpga_thr, r.arm_thr)
        t.update("app", kind, exec_time, load)
        after = (r.fpga_thr, r.arm_thr)
        assert after[0] >= 0 and after[1] >= 0
        if kind == TargetKind.HOST:
            assert after[0] <= before[0] and after[1] <= before[1]
        elif kind == TargetKind.AUX:
            assert after[0] == before[0] and after[1] >= before[1]
        else:
            assert after[1] == before[1] and after[0] >= before[0]


def test_threshold_table_roundtrip(tmp_path):
    t = estimate_table(PAPER_APPS)
    p = str(tmp_path / "thr.json")
    t.save(p)
    t2 = ThresholdTable.load(p)
    assert t2.rows.keys() == t.rows.keys()
    for k in t.rows:
        assert t2.rows[k] == t.rows[k]


# -------------------------------------------------------------- estimator

def test_estimator_reproduces_paper_table2_structure():
    """Table 2: FPGA_THR == 0 exactly for the FPGA-dominant apps, and the
    CG-A thresholds within a few processes of the paper's 31/25."""
    t = estimate_table(PAPER_APPS)
    as_int = {r["Benchmark"]: (max(0, math.ceil(r["FPGA_THR"])),
                               max(0, math.ceil(r["ARM_THR"])))
              for r in t.as_table2()}
    assert as_int["facedet640"][0] == 0
    assert as_int["digit500"][0] == 0
    assert as_int["digit2000"][0] == 0
    assert as_int["facedet320"][0] > 0
    assert abs(as_int["cg_a"][0] - 31) <= 3
    assert abs(as_int["cg_a"][1] - 25) <= 3
    # ordering: for every app ARM_THR/FPGA_THR ordering matches the paper
    assert as_int["facedet320"][1] > as_int["facedet320"][0]
    assert as_int["cg_a"][1] < as_int["cg_a"][0]


def test_estimator_threshold_semantics():
    t_host = host_time_model(100.0, cores=6)
    thr = estimate_threshold(t_host, scenario_ms=150.0)
    # load > thr must be exactly the loads where host loses
    for load in range(0, 30):
        host_loses = t_host(load) > 150.0
        assert (load > thr) == host_loses


def test_bfs_never_profitable():
    for nodes in (1000, 3000, 5000):
        app = bfs_profile(nodes)
        t = estimate_table({app.name: app}, max_load=64)
        assert t.rows[app.name].fpga_thr == INF


# ------------------------------------------------------------ kernel bank

def test_kernel_bank_async_load_and_eviction():
    bank = KernelBank(slots=2, min_load_seconds=0.05)
    assert not bank.is_resident("k1")
    bank.load_async("k1")
    assert not bank.is_resident("k1")      # latency hiding window
    bank.load_sync("k1")
    assert bank.is_resident("k1")
    bank.load_sync("k2")
    bank.load_sync("k3")                   # evicts LRU (k1)
    assert bank.is_resident("k3") and bank.is_resident("k2")
    assert not bank.is_resident("k1")
    assert bank.stats["evictions"] == 1


def test_xclbin_partition_respects_budget():
    areas = {"a": 0.5, "b": 0.4, "c": 0.3, "d": 0.2, "e": 0.15}
    images = partition(areas, image_budget=1.0)
    for img in images:
        assert sum(areas[k] for k in img) <= 1.0 + 1e-9
    assert sorted(k for img in images for k in img) == sorted(areas)


def test_xclbin_partition_pinned():
    areas = {"a": 0.5, "b": 0.5}
    images = partition(areas, 1.0, pinned={"a": 1})
    assert "a" in images[1]


@given(st.dictionaries(st.text(min_size=1, max_size=4),
                       st.floats(0.01, 1.0), min_size=1, max_size=12))
@settings(max_examples=100, deadline=None)
def test_xclbin_partition_property(areas):
    images = partition(areas, image_budget=1.0)
    placed = [k for img in images for k in img]
    assert sorted(placed) == sorted(areas)          # everything placed once
    for img in images:
        assert sum(areas[k] for k in img) <= 1.0 + 1e-9


# ------------------------------------------------------ scheduler server

def _hot_sim_table():
    t = ThresholdTable()
    t.rows = {k: copy.deepcopy(v) for k, v in estimate_table(PAPER_APPS).rows.items()}
    return t


def test_scheduler_server_baselines():
    bank = KernelBank(slots=4)
    srv = SchedulerServer(DEFAULT_PLATFORM, _hot_sim_table(), bank,
                          policy="always_aux")
    assert srv.request("digit2000").target == TargetKind.AUX


def test_tcp_scheduler_roundtrip():
    bank = KernelBank(slots=4)
    inner = SchedulerServer(DEFAULT_PLATFORM, _hot_sim_table(), bank)
    tcp = TcpSchedulerServer(inner)
    addr = tcp.start()
    try:
        client = TcpSchedulerClient("digit2000", addr)
        d = client.before_call()
        assert d.target in TargetKind
        client.after_call(TargetKind.HOST, 123.0, cpu_load=2.0)
        assert inner.table.row("digit2000").x86_exec == 123.0
        client.close()
    finally:
        tcp.stop()


# ---------------------------------------------------------------- monitor

def test_monitor_bands_match_table3():
    mon = LoadMonitor(DEFAULT_PLATFORM)
    assert mon.band(3) == "low"            # < 6 x86 cores
    assert mon.band(60) == "medium"        # < 102 total
    assert mon.band(120) == "high"


# ---------------------------------------------------------------- profile

def test_profile_manifest_roundtrip(tmp_path):
    text = ("platform: tpu-v5e-256\n"
            "application: digitrec\n"
            "  function: knn_digits targets: host,accel\n"
            "application: facedet\n"
            "  function: window_scores targets: host,aux,accel\n")
    m = ProfileManifest.loads(text)
    assert m.platform == "tpu-v5e-256"
    assert len(m.selected()) == 2
    assert ProfileManifest.loads(m.dumps()).dumps() == m.dumps()


# -------------------------------------------------------------- simulator

def test_sim_low_load_xartrek_matches_x86(paper_table=None):
    """Fig 3: at low load Xar-Trek ~ vanilla x86 (the paper itself shows
    x86 winning by up to 21% in one case — FPGA serialisation) and always
    clearly beats always-FPGA."""
    def run(policy):
        sim = PlatformSim(policy=policy, table=_hot_sim_table(),
                          preconfigure=tuple(a.hw_kernel
                                             for a in PAPER_APPS.values()))
        rng = random.Random(7)
        for _ in range(3):
            sim.submit(rng.choice(list(PAPER_APPS.values())), at=0.0)
        sim.run()
        return sim.avg_execution_ms()

    x86 = run("always_host")
    fpga = run("always_accel")
    xar = run("xartrek")
    assert xar <= x86 * 1.25        # paper: within ~21% of vanilla
    assert xar < fpga * 0.75        # and far better than always-FPGA


def test_sim_medium_load_xartrek_beats_x86():
    """Fig 4: with 50 background processes Xar-Trek migrates and wins."""
    def run(policy):
        sim = PlatformSim(policy=policy, table=_hot_sim_table(),
                          preconfigure=tuple(a.hw_kernel
                                             for a in PAPER_APPS.values()))
        bg = AppProfile("mgb", MGB_MS, MGB_MS, MGB_MS, "KNL_MGB")
        for _ in range(50):
            sim.submit(bg, at=0.0, background=True)
        rng = random.Random(3)
        for _ in range(10):
            sim.submit(rng.choice(list(PAPER_APPS.values())), at=10.0)
        sim.run()
        return sim.avg_execution_ms(), sim.decisions

    x86, _ = run("always_host")
    xar, dec = run("xartrek")
    assert xar < x86 * 0.7          # paper: up to 88% gains
    assert dec[TargetKind.AUX] + dec[TargetKind.ACCEL] > 0


def test_sim_reconfiguration_latency_hidden():
    """With a cold bank, calls proceed on CPU targets while the device
    reconfigures; once hot, ACCEL-friendly apps move over."""
    sim = PlatformSim(policy="xartrek", table=_hot_sim_table(),
                      reconfig_ms=500.0)
    bg = AppProfile("mgb", MGB_MS, MGB_MS, MGB_MS, "KNL_MGB")
    for _ in range(40):
        sim.submit(bg, at=0.0, background=True)
    # repeated digit2000 calls: first ones land on CPU, later on ACCEL
    sim.submit(PAPER_APPS["digit2000"], at=10.0, calls=6)
    sim.run()
    assert sim.decisions[TargetKind.ACCEL] > 0
    assert sim.decisions[TargetKind.AUX] + sim.decisions[TargetKind.HOST] > 40
