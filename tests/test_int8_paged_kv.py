"""Int8 quantised paged KV: round-trip error bounds, pool layout,
COW forks carrying scales, equal-bytes capacity, kernel-vs-oracle
parity across GQA ratios and ragged lengths, the lossy-prefix-cache
gate, and the serve-level tolerance story.

Tolerance story (documented in serve/README.md): int8 KV is LOSSY
relative to an f32 pool — per-element error is bounded by scale/2 =
amax/254, so logits shift and greedy tokens can flip wherever the
top-2 margin is smaller than the perturbation.  What IS guaranteed:
HOST and ACCEL read the SAME int8 pool and dequantise to the same
values, so greedy tokens agree byte-for-byte across targets, with
per-token logprobs within ``INT8_LOGPROB_ATOL``; and each request's
FIRST generated token comes from exact full-precision prefill math,
so it matches an f32-pool engine bitwise.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.policy import PinAccel, PinHost
from repro.kernels import ops, ref
from repro.models.attention import (init_paged_kv_cache,
                                    paged_kv_block_bytes)
from repro.models.common import dequantize_int8, quantize_int8
from repro.serve import (ContinuousBatchingEngine, GenerationRequest,
                         SamplingParams)
from repro.serve.engine import kv_cache_lossless

# documented HOST-vs-ACCEL per-token logprob tolerance for int8 paged
# KV: both targets dequantise the same pool, so the residual is only
# float-accumulation order (XLA gather vs the kernel's online softmax)
INT8_LOGPROB_ATOL = 5e-3


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32", kv_cache_dtype="float32")


@pytest.fixture(scope="module")
def icfg(cfg):
    return dataclasses.replace(cfg, kv_cache_dtype="int8")


def _requests(vocab, n=3, seed=0, mnt=6, sampling=None):
    rng = np.random.RandomState(seed)
    return [GenerationRequest(
        rng.randint(0, vocab, size=int(rng.randint(4, 20))).astype(np.int32),
        max_new_tokens=mnt,
        sampling=sampling or SamplingParams()) for _ in range(n)]


# ------------------------------------------------------------- round trip

def test_quantize_roundtrip_error_bound():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 8, 3, 32) * rng.uniform(0.1, 10), jnp.float32)
    q, s = quantize_int8(x, axis=-1)
    back = dequantize_int8(q, s, jnp.float32)
    # symmetric round-to-nearest: |x - dq| <= scale/2 per element
    assert np.all(np.abs(np.asarray(x - back)) <= np.asarray(s) / 2 + 1e-7)
    # scale = amax/127 => the quantised amax saturates the int8 range
    assert int(jnp.max(jnp.abs(q))) == 127


def test_zero_token_roundtrips_to_zero():
    q, s = quantize_int8(jnp.zeros((2, 4, 1, 8)), axis=-1)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(dequantize_int8(q, s, jnp.float32)) == 0.0)


# ------------------------------------------------------- pool layout/bytes

def test_init_paged_kv_cache_int8_leaves():
    pool = init_paged_kv_cache(2, 5, 4, 3, 32, "int8", lane_align=False)
    assert pool["k"].dtype == jnp.int8 and pool["v"].dtype == jnp.int8
    assert pool["k"].shape == (2, 5, 4, 3, 32)
    assert pool["k_scale"].dtype == jnp.float32
    assert pool["k_scale"].shape == (2, 5, 4, 3, 1)
    assert pool["v_scale"].shape == (2, 5, 4, 3, 1)


def test_equal_bytes_capacity_ratio():
    # at equal KV bytes an int8+scales pool holds >= 1.8x the f32
    # blocks (analytically 4*hd/(hd+4): 3.55x at hd=32, 3.88x at 128)
    for hd in (32, 64, 128):
        f32_b = paged_kv_block_bytes(32, 3, hd, "float32")
        i8_b = paged_kv_block_bytes(32, 3, hd, "int8")
        assert (12 * f32_b // i8_b) / 12 >= 1.8
    # the helper must agree with what allocation actually costs
    pool = init_paged_kv_cache(1, 1, 32, 3, 32, "int8", lane_align=False)
    assert sum(a.size * a.dtype.itemsize for a in pool.values()) \
        == paged_kv_block_bytes(32, 3, 32, "int8")


def test_equal_bytes_pool_admits_more(cfg, icfg):
    # engine-level: the same byte budget gives the int8 pool >=1.8x the
    # blocks, letting it admit a request the f32 pool must reject
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    kw = dict(max_slots=4, max_seq=128, paged=True, block_size=16, seed=0)
    n_f32 = 6
    budget = n_f32 * paged_kv_block_bytes(16, kv, hd, "float32")
    n_i8 = int(budget // paged_kv_block_bytes(16, kv, hd, "int8"))
    assert n_i8 / n_f32 >= 1.8
    e32 = ContinuousBatchingEngine(cfg, fn_prefix="cap32",
                                   num_blocks=n_f32, **kw)
    ei8 = ContinuousBatchingEngine(icfg, fn_prefix="capi8",
                                   params=e32.params, num_blocks=n_i8, **kw)
    big = GenerationRequest(np.arange(7 * 16, dtype=np.int32) % cfg.vocab_size,
                            max_new_tokens=2)
    assert not e32.slots.can_admit(big.prompt_len, big)
    assert ei8.slots.can_admit(big.prompt_len, big)


# --------------------------------------------------- kernel vs oracle

@pytest.mark.parametrize("Hp,KV,hd,BS,NBT,lengths", [
    (4, 4, 32, 8, 3, (0, 7, 23)),       # MHA, zero-length row
    (4, 2, 32, 8, 2, (3, 15, 10)),      # GQA 2:1
    (3, 1, 64, 16, 2, (0, 31, 17)),     # odd heads onto one kv head
    (8, 2, 16, 4, 4, (15, 1, 8)),       # GQA 4:1, tiny blocks
])
def test_int8_kernel_matches_f32_kernel_on_dequantised_pool(
        Hp, KV, hd, BS, NBT, lengths):
    """The int8 kernel on (pages, scales) must equal the (already
    oracle-verified) f32 kernel run on the dequantised pool — same
    wrapper, same grouping, same ragged lengths."""
    from repro.models.attention import kv_head_index
    B, NP = len(lengths), NBT * len(lengths) + 1
    rng = np.random.RandomState(Hp * 100 + KV)
    kq, ks = quantize_int8(jnp.asarray(
        rng.randn(NP, BS, KV, hd), jnp.float32), axis=-1)
    vq, vs = quantize_int8(jnp.asarray(
        rng.randn(NP, BS, KV, hd), jnp.float32), axis=-1)
    q = jnp.asarray(rng.randn(B, 1, Hp, hd), jnp.float32)
    kn = jnp.asarray(rng.randn(B, 1, KV, hd), jnp.float32)
    vn = jnp.asarray(rng.randn(B, 1, KV, hd), jnp.float32)
    tables = jnp.asarray(rng.randint(1, NP, size=(B, NBT)), jnp.int32)
    idx = jnp.asarray(lengths, jnp.int32)
    kv_idx = (None if Hp == KV else
              tuple(int(i) for i in kv_head_index(Hp, KV, Hp)))
    got = ops.paged_gqa_decode_int8(q, kq, ks, vq, vs, kn, vn, tables, idx,
                                    kv_index=kv_idx)
    want = ops.paged_gqa_decode(q, dequantize_int8(kq, ks, jnp.float32),
                                dequantize_int8(vq, vs, jnp.float32),
                                kn, vn, tables, idx, kv_index=kv_idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_int8_raw_kernel_matches_int8_oracle():
    """Raw (already-grouped) int8 kernel vs the pure-jnp int8 oracle."""
    from repro.kernels.gqa_decode import paged_gqa_decode_int8 as raw
    B, KV, G, hd, NP, BS, NBT = 3, 2, 3, 32, 7, 8, 4
    rng = np.random.RandomState(5)
    kq, ks = quantize_int8(jnp.asarray(
        rng.randn(NP, BS, KV, hd), jnp.float32), axis=-1)
    vq, vs = quantize_int8(jnp.asarray(
        rng.randn(NP, BS, KV, hd), jnp.float32), axis=-1)
    q = jnp.asarray(rng.randn(B, KV, G, hd), jnp.float32)
    kn = jnp.asarray(rng.randn(B, KV, 1, hd), jnp.float32)
    vn = jnp.asarray(rng.randn(B, KV, 1, hd), jnp.float32)
    tables = jnp.asarray(rng.randint(0, NP, size=(B, NBT)), jnp.int32)
    idx = jnp.asarray([0, 13, 30], jnp.int32)
    got = raw(q, kq, ks, vq, vs, kn, vn, tables, idx, interpret=True)
    want = ref.paged_decode_attention_int8_ref(q, kq, ks, vq, vs, kn, vn,
                                               tables, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_int8_oracle_matches_f32_ref_when_exact():
    # values exactly representable at int8 (integer grid scaled so
    # amax -> 127): the int8 oracle equals the f32 reference bitwise
    rng = np.random.RandomState(3)
    NP, BS, KV, hd, B, G, NBT = 5, 4, 2, 8, 2, 2, 2
    ints = rng.randint(-127, 128, size=(NP, BS, KV, hd)).astype(np.float32)
    kq, ks = quantize_int8(jnp.asarray(ints / 127.0), axis=-1)
    q = jnp.asarray(rng.randn(B, KV, G, hd), jnp.float32)
    kn = jnp.asarray(rng.randn(B, KV, 1, hd), jnp.float32)
    tables = jnp.asarray(rng.randint(1, NP, size=(B, NBT)), jnp.int32)
    lengths = jnp.asarray([3, 7], jnp.int32)
    a = ref.paged_decode_attention_int8_ref(q, kq, ks, kq, ks, kn, kn,
                                            tables, lengths)
    b = ref.paged_decode_attention_ref(
        q, dequantize_int8(kq, ks, jnp.float32),
        dequantize_int8(kq, ks, jnp.float32), kn, kn, tables, lengths)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------- COW + scatter

def test_cow_fork_copy_carries_scales(icfg):
    eng = ContinuousBatchingEngine(icfg, fn_prefix="cow8", max_slots=2,
                                   max_seq=32, paged=True, block_size=8,
                                   num_blocks=8, prefix_cache=True,
                                   allow_lossy_prefix_cache=True, seed=0)
    rng = np.random.RandomState(1)
    src, dst = 3, 5
    filled = dict(eng.cache)
    for name in ("k", "v"):
        arr = np.zeros(filled[name].shape, np.int8)
        arr[:, src] = rng.randint(-127, 128, size=arr[:, src].shape)
        filled[name] = jnp.asarray(arr)
    for name in ("k_scale", "v_scale"):
        arr = np.zeros(filled[name].shape, np.float32)
        arr[:, src] = rng.uniform(0.01, 2.0, size=arr[:, src].shape)
        filled[name] = jnp.asarray(arr)
    copied = eng._copy_block(filled, jnp.int32(dst), jnp.int32(src))
    for name in ("k", "v", "k_scale", "v_scale"):
        got = np.asarray(copied[name])
        np.testing.assert_array_equal(got[:, dst], got[:, src],
                                      err_msg=f"{name} not carried by COW")
        assert got[:, dst].any(), f"{name} copied as zeros"


def test_prefill_scatter_writes_int8_blocks(icfg):
    # admitting a 2-block prompt into a paged int8 engine must leave
    # quantised values AND non-zero scales in the scattered blocks
    eng = ContinuousBatchingEngine(icfg, fn_prefix="sc8", max_slots=2,
                                   max_seq=64, paged=True, block_size=8,
                                   num_blocks=10, seed=0)
    prompt = np.arange(2, 18, dtype=np.int32) % icfg.vocab_size
    eng.run([GenerationRequest(prompt, max_new_tokens=1)])
    pool = eng.cache
    assert pool["k"].dtype == jnp.int8
    assert float(jnp.max(pool["k_scale"])) > 0.0
    # written tokens saturate the int8 grid (scale = amax/127)
    assert int(jnp.max(jnp.abs(pool["k"]))) == 127


# ------------------------------------------------------- serve tolerance

def test_lossy_prefix_cache_gate(cfg, icfg):
    kw = dict(max_slots=2, max_seq=32, paged=True, block_size=8,
              num_blocks=8, seed=0)
    with pytest.raises(ValueError, match="allow_lossy_prefix_cache"):
        ContinuousBatchingEngine(icfg, fn_prefix="g1", prefix_cache=True,
                                 **kw)
    # f32 compute over a bf16 pool is lossy too
    bf = dataclasses.replace(cfg, kv_cache_dtype="bfloat16")
    with pytest.raises(ValueError, match="allow_lossy_prefix_cache"):
        ContinuousBatchingEngine(bf, fn_prefix="g2", prefix_cache=True, **kw)
    assert not kv_cache_lossless(icfg) and not kv_cache_lossless(bf)
    assert kv_cache_lossless(cfg)
    assert kv_cache_lossless(
        dataclasses.replace(cfg, dtype="bfloat16", kv_cache_dtype="float32"))
    # explicit opt-in constructs; lossless f32/f32 never needed the flag
    ContinuousBatchingEngine(icfg, fn_prefix="g3", prefix_cache=True,
                             allow_lossy_prefix_cache=True, **kw)
    ContinuousBatchingEngine(cfg, fn_prefix="g4", prefix_cache=True, **kw)


def test_int8_host_accel_parity(icfg):
    """HOST and ACCEL dequantise the SAME int8 pool: greedy tokens are
    byte-identical across targets and per-token logprobs agree within
    the documented INT8_LOGPROB_ATOL."""
    sp = SamplingParams(logprobs=True)
    kw = dict(max_slots=4, max_seq=64, paged=True, block_size=16,
              num_blocks=16, seed=0)
    host = ContinuousBatchingEngine(icfg, fn_prefix="ph8",
                                    policy=PinHost(), **kw)
    accel = ContinuousBatchingEngine(icfg, fn_prefix="pa8",
                                     params=host.params,
                                     policy=PinAccel(), **kw)
    out_h = host.run(_requests(icfg.vocab_size, sampling=sp))
    out_a = accel.run(_requests(icfg.vocab_size, sampling=sp))
    key = lambda o: o.tokens.tobytes()                          # noqa: E731
    hs, as_ = sorted(out_h.values(), key=key), sorted(out_a.values(), key=key)
    for oh, oa in zip(hs, as_):
        np.testing.assert_array_equal(oh.tokens, oa.tokens)
        np.testing.assert_allclose(oh.logprobs, oa.logprobs,
                                   atol=INT8_LOGPROB_ATOL)


def test_int8_first_tokens_match_f32(cfg, icfg):
    """Each request's FIRST generated token comes from exact f32
    prefill math (the quantised pool is only read back from the second
    token on), so it matches an f32-pool engine bitwise — the
    deterministic slice of the greedy-agreement story; deeper tokens
    agree only where the top-2 logit margin exceeds the int8
    perturbation."""
    kw = dict(max_slots=4, max_seq=64, paged=True, block_size=16,
              num_blocks=24, seed=0)
    e32 = ContinuousBatchingEngine(cfg, fn_prefix="ft32", **kw)
    ei8 = ContinuousBatchingEngine(icfg, fn_prefix="fti8",
                                   params=e32.params, **kw)
    o32 = e32.run(_requests(cfg.vocab_size, n=4, seed=7))
    oi8 = ei8.run(_requests(cfg.vocab_size, n=4, seed=7))
    firsts32 = sorted(int(o.tokens[0]) for o in o32.values())
    firstsi8 = sorted(int(o.tokens[0]) for o in oi8.values())
    assert firsts32 == firstsi8
