"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (assignment c).

All kernels run in interpret mode on CPU (TPU is the compile target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.knn_digits import hamming_distances
from repro.kernels.moe_gmm import grouped_matmul as gmm_raw
from repro.kernels.ssd_scan import ssd_scan as ssd_raw


def _key(i=0):
    return jax.random.PRNGKey(i)


# ------------------------------------------------------ flash attention

@pytest.mark.parametrize("B,S,T,H,hd", [
    (2, 128, 128, 4, 64),
    (1, 256, 256, 2, 128),
    (2, 64, 64, 3, 32),       # odd head count, lane-padded hd
    (1, 512, 512, 1, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(B, S, T, H, hd, dtype):
    ks = jax.random.split(_key(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    want = ref.attention_ref(qf, kf, vf).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_gqa_kv_index():
    """GQA mapping: 4 q heads sharing 2 kv heads."""
    ks = jax.random.split(_key(2), 3)
    B, S, H, KV, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    from repro.models.attention import kv_head_index, plain_attention
    kv_idx = kv_head_index(H, KV, H)
    out = ops.flash_attention(q, k, v, kv_index=tuple(kv_idx),
                              block_q=32, block_k=32)
    want = plain_attention(q, k, v, kv_index=kv_idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_block_shape_sweep():
    ks = jax.random.split(_key(3), 3)
    B, S, hd = 1, 256, 64
    q = jax.random.normal(ks[0], (B, S, hd))
    k = jax.random.normal(ks[1], (B, S, hd))
    v = jax.random.normal(ks[2], (B, S, hd))
    want = ref.attention_ref(q, k, v)
    for bq, bk in [(32, 64), (64, 32), (128, 128), (256, 256)]:
        got = fa_raw(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"block {bq}x{bk}")


# --------------------------------------------------------------- SSD

@pytest.mark.parametrize("S,P,N,chunk", [
    (64, 16, 8, 16), (128, 32, 16, 32), (32, 8, 4, 32),
])
def test_ssd_scan_matches_recurrence(S, P, N, chunk):
    BH = 3
    ks = jax.random.split(_key(4), 5)
    x = jax.random.normal(ks[0], (BH, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)) * 0.5)
    Bm = jax.random.normal(ks[3], (BH, S, N))
    Cm = jax.random.normal(ks[4], (BH, S, N))
    y, state = ssd_raw(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, state_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               atol=1e-4, rtol=1e-4)


def test_ssd_model_wrapper_broadcast():
    """ops.ssd_scan broadcasts B/C over heads like the model does."""
    B, S, H, P, N = 2, 64, 3, 8, 4
    ks = jax.random.split(_key(5), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, state = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    from repro.models.ssm import ssd_chunked
    y_ref, state_ref = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- grouped matmul

@pytest.mark.parametrize("E,C,D,F", [(4, 64, 32, 48), (8, 32, 64, 16),
                                     (2, 128, 16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(E, C, D, F, dtype):
    ks = jax.random.split(_key(6), 3)
    x = jax.random.normal(ks[0], (E, C, D), jnp.float32).astype(dtype)
    w = jax.random.normal(ks[1], (E, D, F), jnp.float32).astype(dtype)
    gs = jax.random.randint(ks[2], (E,), 0, C + 1, jnp.int32)
    got = gmm_raw(x, w, gs, block_c=32, block_f=16, block_d=16,
                  interpret=True)
    want = ref.grouped_matmul_ref(x, w, gs)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# -------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("R,d", [(8, 64), (128, 96), (6, 128), (1, 32)])
def test_rmsnorm(R, d):
    ks = jax.random.split(_key(7), 2)
    x = jax.random.normal(ks[0], (R, d))
    w = jax.random.normal(ks[1], (d,))
    got = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------- knn digits

def test_hamming_distances_exact():
    ks = jax.random.split(_key(8), 2)
    t = jax.random.randint(ks[0], (16, 7), 0, 2**31 - 1, jnp.int32).astype(jnp.uint32)
    r = jax.random.randint(ks[1], (64, 7), 0, 2**31 - 1, jnp.int32).astype(jnp.uint32)
    got = hamming_distances(t, r, block_t=8, block_n=16, interpret=True)
    want = ref.hamming_ref(t, r)
    assert int(jnp.max(jnp.abs(got - want))) == 0


def test_knn_digits_recovers_labels():
    """Clusters of near-identical bitvectors -> KNN must recover labels."""
    rng = np.random.default_rng(0)
    protos = rng.integers(0, 2**32, size=(10, 7), dtype=np.uint64).astype(np.uint32)
    train, labels = [], []
    for lbl in range(10):
        for _ in range(20):
            v = protos[lbl].copy()
            v[rng.integers(0, 7)] ^= np.uint32(1 << rng.integers(0, 32))
            train.append(v)
            labels.append(lbl)
    train = jnp.asarray(np.stack(train))
    labels = jnp.asarray(np.asarray(labels, np.int32))
    test = jnp.asarray(protos)
    pred = ops.knn_digits(test, train, labels, k=3)
    assert list(np.asarray(pred)) == list(range(10))


# ----------------------------------------------------------- haar window

@pytest.mark.parametrize("H,W,win,stride", [(64, 64, 24, 4), (48, 80, 16, 8),
                                            (128, 96, 24, 8)])
def test_window_scores(H, W, win, stride):
    ks = jax.random.split(_key(9), 2)
    img = jax.random.normal(ks[0], (H, W))
    feats = jax.random.normal(ks[1], (5, win * win))
    got = ops.window_scores(img, feats, win=win, stride=stride)
    want = ref.window_scores_ref(img, feats, win=win, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


# ------------------------------------------------------------ gqa decode

@pytest.mark.parametrize("Smax,hd,live", [(256, 64, 100), (512, 128, 511),
                                          (128, 32, 0)])
def test_gqa_decode_matches_oracle(Smax, hd, live):
    from repro.kernels.gqa_decode import gqa_decode as gd_raw
    ks = jax.random.split(_key(10), 3)
    BH = 6
    q = jax.random.normal(ks[0], (BH, 1, hd))
    kc = jax.random.normal(ks[1], (BH, Smax, hd))
    vc = jax.random.normal(ks[2], (BH, Smax, hd))
    got = gd_raw(q, kc, vc, jnp.int32(live), block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, jnp.int32(live))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_gqa_decode_ops_wrapper_gqa_and_padding():
    """ops wrapper: GQA head expansion + lane padding (hd=32 -> 128)."""
    ks = jax.random.split(_key(11), 3)
    B, Smax, H, KV, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kc = jax.random.normal(ks[1], (B, Smax, KV, hd))
    vc = jax.random.normal(ks[2], (B, Smax, KV, hd))
    from repro.models.attention import decode_attention, kv_head_index
    kv_idx = kv_head_index(H, KV, H)
    got = ops.gqa_decode(q, kc, vc, jnp.int32(77), kv_index=tuple(kv_idx),
                         block_k=32)
    want = decode_attention(q, kc, vc, jnp.int32(77), kv_index=kv_idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
