"""Paged KV cache: paged-vs-dense token equivalence, block-pool
exhaustion backpressure, free-list reuse under churn, preempt/resume,
fragmentation accounting, and stop-token capacity release."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.binary import shape_key
from repro.core.function import FunctionRegistry
from repro.core.runtime import XarTrekRuntime
from repro.serve import (BlockPool, ContinuousBatchingEngine,
                         GenerationRequest, PagedSlotManager, ServeEngine,
                         SlotManager)

def _serve(engine, reqs=()):
    """v2 run() flattened to the old {req_id: token-array} shape."""
    return {rid: out.tokens for rid, out in engine.run(reqs).items()}



@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32")


@pytest.fixture(scope="module")
def sync_engine(cfg):
    return ServeEngine(cfg, seed=0)


def _prompts(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)


# ------------------------------------------------------------- equivalence

def test_paged_tokens_match_dense_and_sync(cfg, sync_engine):
    """Byte-identical greedy tokens across all three engines when the
    paged attention span (table_width * block_size) equals max_seq."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(prompts, max_new_tokens=6).tokens
    dense = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                     params=sync_engine.params)
    paged = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                     params=sync_engine.params,
                                     paged=True, block_size=16)
    got_dense = dense.generate(np.asarray(prompts), max_new_tokens=6)
    got_paged = paged.generate(np.asarray(prompts), max_new_tokens=6)
    np.testing.assert_array_equal(want, got_dense)
    np.testing.assert_array_equal(want, got_paged)


def test_paged_mixed_lengths_match_dense(cfg, sync_engine):
    """Ragged arrivals (mixed prompt/gen lengths) through paged and dense
    engines produce the same per-request tokens."""
    rng2 = np.random.RandomState(7)
    reqs_a = [GenerationRequest(rng2.randint(0, cfg.vocab_size,
                                   size=int(rng2.randint(3, 20))),
                      max_new_tokens=int(rng2.randint(1, 8)),
                      arrival_s=0.004 * i) for i in range(6)]
    rng2 = np.random.RandomState(7)
    reqs_b = [GenerationRequest(rng2.randint(0, cfg.vocab_size,
                                   size=int(rng2.randint(3, 20))),
                      max_new_tokens=int(rng2.randint(1, 8)),
                      arrival_s=0.004 * i) for i in range(6)]
    dense = ContinuousBatchingEngine(cfg, max_slots=3, max_seq=64,
                                     params=sync_engine.params)
    paged = ContinuousBatchingEngine(cfg, max_slots=3, max_seq=64,
                                     params=sync_engine.params,
                                     paged=True, block_size=16)
    out_a = _serve(dense, reqs_a)
    out_b = _serve(paged, reqs_b)
    for ra, rb in zip(reqs_a, reqs_b):
        np.testing.assert_array_equal(out_a[ra.req_id], out_b[rb.req_id])


# ----------------------------------------------------- pool + backpressure

def test_block_pool_alloc_free_exhaustion():
    pool = BlockPool(num_blocks=3, block_size=8)
    a = pool.alloc(2)
    assert 0 not in a                     # junk block never handed out
    assert pool.free_blocks() == 1
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)
    pool.free(a)
    assert pool.free_blocks() == 3
    assert pool.stats == {"allocated": 2, "freed": 2, "peak_in_use": 2,
                          "cache_hits": 0, "evicted": 0}


def test_block_exhaustion_backpressure_gates_admission(cfg, sync_engine):
    """Pool smaller than max_slots' worst case: admission waits on blocks
    (not on rows) and nothing is preempted when the watermark holds."""
    rng = np.random.RandomState(11)
    # each request: 2 prompt blocks + 1 growth block = 3 of the 6-block
    # pool; admission watermark lets exactly two run concurrently
    reqs = [GenerationRequest(rng.randint(0, cfg.vocab_size, size=16),
                    max_new_tokens=8) for _ in range(4)]
    eng = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=32,
                                   params=sync_engine.params,
                                   paged=True, block_size=8, num_blocks=6)
    out = _serve(eng, reqs)
    assert sorted(out) == sorted(r.req_id for r in reqs)
    st = eng.slots.stats
    assert st["admitted"] == 4 and st["released"] == 4
    assert st["peak_active"] == 2          # blocks, not rows, were binding
    assert st["preempted"] == 0
    assert eng.slots.pool.blocks_in_use() == 0


def test_overlong_paged_request_rejected_at_submission(cfg, sync_engine):
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                   params=sync_engine.params,
                                   paged=True, block_size=8, num_blocks=3)
    # 3-block pool: a request needing 4 blocks can never run
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(np.arange(1, 20, dtype=np.int32), max_new_tokens=16)
    # engine stays usable
    out = eng.generate(np.arange(1, 9, dtype=np.int32)[None, :],
                       max_new_tokens=2)
    assert out.shape == (1, 2)


def test_block_freelist_reuse_under_churn(cfg, sync_engine):
    """Sequential waves through a small pool recycle the same physical
    blocks; the pool drains back to empty."""
    rng = np.random.RandomState(13)
    reqs = [GenerationRequest(rng.randint(0, cfg.vocab_size, size=8),
                    max_new_tokens=4) for _ in range(6)]
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                   params=sync_engine.params,
                                   paged=True, block_size=8, num_blocks=4)
    out = _serve(eng, reqs)
    assert len(out) == 6
    pst = eng.slots.pool.stats
    assert pst["allocated"] == pst["freed"]
    assert pst["allocated"] > eng.slots.pool.num_blocks   # ids were reused
    assert pst["peak_in_use"] <= eng.slots.pool.num_blocks
    assert eng.slots.pool.blocks_in_use() == 0


def test_preemption_resumes_byte_identical(cfg, sync_engine):
    """A pool too small for two long generations forces a preempt +
    resume-by-recompute; greedy tokens still match the dense engine."""
    rng = np.random.RandomState(3)
    p1 = rng.randint(0, cfg.vocab_size, size=4)
    p2 = rng.randint(0, cfg.vocab_size, size=4)
    dense = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params)
    da, db = GenerationRequest(p1, 12), GenerationRequest(p2, 12)
    want = _serve(dense, [da, db])
    small = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params,
                                     paged=True, block_size=4, num_blocks=6)
    ra, rb = GenerationRequest(p1, 12), GenerationRequest(p2, 12)
    got = _serve(small, [ra, rb])
    assert small.slots.stats["preempted"] >= 1
    np.testing.assert_array_equal(want[da.req_id], got[ra.req_id])
    np.testing.assert_array_equal(want[db.req_id], got[rb.req_id])
    assert small.slots.pool.blocks_in_use() == 0


# ------------------------------------------------------ capacity headline

def test_paged_admits_more_concurrent_at_equal_memory(cfg, sync_engine):
    """Same KV budget (144 positions), short requests: dense caps at 3
    rows, the paged pool runs 6 concurrently."""
    rng = np.random.RandomState(5)
    dense = ContinuousBatchingEngine(cfg, max_slots=3, max_seq=48,
                                     params=sync_engine.params)
    paged = ContinuousBatchingEngine(cfg, max_slots=6, max_seq=48,
                                     params=sync_engine.params,
                                     paged=True, block_size=16,
                                     num_blocks=9)   # 9*16 = 144 = 3*48
    _serve(dense, [GenerationRequest(rng.randint(0, cfg.vocab_size, size=4),
                         max_new_tokens=4) for _ in range(6)])
    _serve(paged, [GenerationRequest(rng.randint(0, cfg.vocab_size, size=4),
                         max_new_tokens=4) for _ in range(6)])
    assert dense.slots.stats["peak_active"] == 3
    assert paged.slots.stats["peak_active"] == 6
    assert paged.slots.stats["preempted"] == 0


# -------------------------------------------------- fragmentation stats

def test_fragmentation_accounting_dense_vs_paged():
    req = GenerationRequest(np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    dense = SlotManager(max_slots=2, max_seq=64)
    dense.admit(dataclasses.replace(req), first_token=7)
    dst = dense.stats
    assert dst["reserved_positions"] == 64      # whole row held
    assert dst["used_positions"] == 4
    assert dst["frag_positions"] == 60

    paged = PagedSlotManager(max_slots=2, block_size=8, num_blocks=16,
                             max_seq=64)
    blocks = paged.pool.alloc(paged.blocks_for(4))
    paged.admit(dataclasses.replace(req), first_token=7, blocks=blocks)
    pst = paged.stats
    assert pst["reserved_positions"] == 8       # one block held
    assert pst["used_positions"] == 4
    assert pst["frag_positions"] == 4           # < block_size, bounded


def test_paged_manager_without_max_seq_is_pool_bound():
    m = PagedSlotManager(max_slots=2, block_size=8, num_blocks=4,
                         max_seq=None)
    assert m.table_width == 4
    m.validate(GenerationRequest(np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=24))      # 32 positions = whole pool
    with pytest.raises(ValueError, match="blocks"):
        m.validate(GenerationRequest(np.arange(1, 9, dtype=np.int32),
                           max_new_tokens=25))


# -------------------------------------------------------- stop tokens

def test_stop_token_ends_generation_early(cfg, sync_engine):
    prompt = np.arange(1, 6, dtype=np.int32)
    base = ContinuousBatchingEngine(cfg, max_slots=1, max_seq=32,
                                    params=sync_engine.params)
    full = list(_serve(base, [GenerationRequest(prompt, 6)]).values())[0].tolist()
    stop = full[1]
    expect_len = full.index(stop) + 1
    eng = ContinuousBatchingEngine(cfg, max_slots=1, max_seq=32,
                                   params=sync_engine.params,
                                   paged=True, block_size=8)
    out = list(_serve(eng, [GenerationRequest(prompt, 6,
                                  stop_tokens=(stop,))]).values())[0]
    assert out.tolist() == full[:expect_len]    # stop token included
    assert len(out) < len(full)


def test_early_stop_releases_capacity_to_queued_arrivals(cfg, sync_engine):
    """With one slot, A stopping early hands the slot (and its blocks) to
    queued B sooner: fewer total decode steps than the no-stop run."""
    pa = np.arange(1, 6, dtype=np.int32)
    pb = np.arange(2, 7, dtype=np.int32)
    ref = ContinuousBatchingEngine(cfg, max_slots=1, max_seq=32,
                                   params=sync_engine.params,
                                   paged=True, block_size=8)
    out_ref = _serve(ref, [GenerationRequest(pa, 6), GenerationRequest(pb, 6)])
    a_toks = [v for k, v in sorted(out_ref.items())][0].tolist()
    stop = a_toks[1]
    eng = ContinuousBatchingEngine(cfg, max_slots=1, max_seq=32,
                                   params=sync_engine.params,
                                   paged=True, block_size=8)
    ra = GenerationRequest(pa, 6, stop_tokens=(stop,))
    rb = GenerationRequest(pb, 6)
    out = _serve(eng, [ra, rb])
    assert len(out) == 2
    assert len(out[ra.req_id]) < 6
    np.testing.assert_array_equal(out[rb.req_id],
                                  out_ref[sorted(out_ref)[1]])
    assert eng.stats["decode_steps"] < ref.stats["decode_steps"]
    assert eng.slots.pool.blocks_in_use() == 0
    st = eng.slots.stats
    assert st["admitted"] == 2 and st["released"] == 2


# ------------------------------------------------------- runtime/compile

def test_paged_decode_static_signature_no_bucket_misses(cfg, sync_engine):
    """Steady-state paged decode (tokens + index + block table) is one
    static shape: the prepare()-time compile serves every step, so
    Algorithm 1 timing never sees a decode compile."""
    rt = XarTrekRuntime(registry=FunctionRegistry(),
                        min_reconfig_seconds=0.0)
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                   params=sync_engine.params, runtime=rt,
                                   fn_prefix="pgd", paged=True, block_size=8)
    rng = np.random.RandomState(17)
    reqs = [GenerationRequest(rng.randint(0, cfg.vocab_size, size=6),
                    max_new_tokens=3) for _ in range(4)]
    out = _serve(eng, reqs)
    assert len(out) == 4
    decode_calls = [r for r in rt.call_log if r["fn"] == "pgd_decode"]
    assert decode_calls
    assert rt.binaries["pgd_decode"].shape_stats["misses"] == 0


def test_shape_key_handles_scalar_leaves():
    a = shape_key((jnp.zeros((2, 3)), {"n": 3}))
    b = shape_key((jnp.zeros((2, 3)), {"n": 4}))
    c = shape_key((jnp.zeros((2, 3)), {"n": 3}))
    assert a != b and a == c
    assert len({a, b, c}) == 2             # hashable, usable as dict keys
