"""Process-cluster serving: result-plane rehydration, OS-process
workers, and the fault-tolerant supervisor.

* ``RequestHandle.apply_event`` — token-event dedup on absolute index,
  finish-event authority, metric accounting (fast, no processes).
* 2-process ``ProcClusterFrontEnd`` greedy outputs byte-identical to a
  single in-process reference engine (same cfg + seed ⇒ same weights).
* SIGKILL a worker mid-stream: the supervisor re-routes its in-flight
  requests to the survivor via resume-by-re-prefill with byte-identical
  greedy output, no orphaned KV blocks, and ``summary()`` reporting the
  failure/re-route counts.

Process-spawn tests are marked ``slow`` (each worker boots its own JAX
runtime and compiles its own engine) — CI fast deselects them; nightly
runs the full set.
"""
import dataclasses
import os
import signal
import time

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.serve import (
    ContinuousBatchingEngine, GenerationRequest, ProcClusterFrontEnd,
    RequestHandle, SamplingParams,
)

SEED = 0


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32")


def _greedy_requests(cfg, n, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(
            1, cfg.vocab_size, size=int(rng.integers(6, 20))).astype(np.int32)
        reqs.append(GenerationRequest(
            prompt, max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.0)))
    return reqs


def _reference_outputs(cfg, requests, **engine_kwargs):
    """Single in-process engine ground truth for byte-identity checks:
    worker processes rebuild the same weights from the shared seed, so
    placement (which worker, failure or not) must never change tokens."""
    eng = ContinuousBatchingEngine(cfg, seed=SEED, **engine_kwargs)
    handles = [eng.submit(dataclasses.replace(r)) for r in requests]
    eng.run()
    return [h.result(timeout=0.0).tokens.tolist() for h in handles]


# ------------------------------------------------ handle rehydration

def _handle(max_new=8, logprobs=False):
    return RequestHandle(GenerationRequest(
        np.asarray([1, 2, 3], np.int32), max_new_tokens=max_new,
        sampling=SamplingParams(temperature=0.0, logprobs=logprobs)))


def test_apply_event_streams_tokens_and_finishes():
    h = _handle()
    seen = []
    h.on_token = seen.append
    h.apply_event({"ev": "token", "req": h.req_id, "i": 0, "t": 7,
                   "lp": -0.5})
    h.apply_event({"ev": "token", "req": h.req_id, "i": 1, "t": 9,
                   "lp": -0.25})
    assert not h.finished and seen == [7, 9]
    h.apply_event({"ev": "finish", "req": h.req_id, "tokens": [7, 9],
                   "logprobs": [-0.5, -0.25], "finish_reason": "length",
                   "queue_wait_s": 0.125})
    out = h.result(timeout=0.0)
    assert out.tokens.tolist() == [7, 9]
    assert out.finish_reason == "length"
    assert out.queue_wait_s == 0.125
    assert out.ttft_s >= 0.0
    assert list(h) == [7, 9]          # stream iterator replays + closes


def test_apply_event_dedups_replayed_prefix():
    """A re-routed request replays its stash through the survivor's
    handle; the parent handle must dedup on the ABSOLUTE index so
    consumers never see a token twice."""
    h = _handle()
    seen = []
    h.on_token = seen.append
    for i, t in enumerate([5, 6, 7]):
        h.apply_event({"ev": "token", "req": h.req_id, "i": i, "t": t})
    # survivor replays indices 0..3 (stash + one fresh token)
    for i, t in enumerate([5, 6, 7, 8]):
        h.apply_event({"ev": "token", "req": h.req_id, "i": i, "t": t})
    assert h.tokens == [5, 6, 7, 8]
    assert seen == [5, 6, 7, 8]


def test_apply_event_finish_backfills_missing_tokens():
    """The finish event is authoritative: tokens that never arrived as
    token events (worker died between emits) backfill at finish."""
    h = _handle(logprobs=True)
    h.apply_event({"ev": "token", "req": h.req_id, "i": 0, "t": 3,
                   "lp": -1.0})
    h.apply_event({"ev": "finish", "req": h.req_id, "tokens": [3, 4, 5],
                   "logprobs": [-1.0, -2.0, -3.0],
                   "finish_reason": "stop", "queue_wait_s": 0.0})
    out = h.result(timeout=0.0)
    assert out.tokens.tolist() == [3, 4, 5]
    assert out.logprobs.tolist() == [-1.0, -2.0, -3.0]
    # events after finish are late duplicates from a dead worker: ignored
    h.apply_event({"ev": "token", "req": h.req_id, "i": 3, "t": 9})
    assert h.tokens == [3, 4, 5]


def test_apply_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown result-plane event"):
        _handle().apply_event({"ev": "gibberish"})


# ------------------------------------------------- process round-trip

@pytest.mark.slow
def test_proc_cluster_greedy_matches_local_reference(cfg):
    requests = _greedy_requests(cfg, 6)
    want = _reference_outputs(cfg, requests, max_slots=2, max_seq=64)
    with ProcClusterFrontEnd(cfg, n_workers=2, policy="xartrek",
                             seed=SEED, max_slots=2, max_seq=64) as fe:
        fe.warmup(timeout=300.0)
        for r in requests:
            fe.submit(r)
        outs = fe.drain(timeout=120.0)
    got = [outs[r.req_id].tokens.tolist() for r in requests]
    assert got == want
    assert all(outs[r.req_id].finish_reason == "length" for r in requests)
    # both workers actually served (least-loaded routing spreads 6 reqs)
    assert len(set(fe.last_owners.values())) == 2


@pytest.mark.slow
def test_proc_cluster_sigkill_reroutes_byte_identical(cfg):
    requests = _greedy_requests(cfg, 6, max_new=24, seed=3)
    kw = dict(max_slots=2, max_seq=96, paged=True, block_size=16,
              num_blocks=64)
    want = _reference_outputs(cfg, requests, **kw)
    with ProcClusterFrontEnd(cfg, n_workers=2, policy="xartrek",
                             seed=SEED, **kw) as fe:
        fe.warmup(timeout=300.0)
        handles = [fe.submit(r) for r in requests]
        victim = fe.workers[0]
        victim_handles = [h for h in handles
                          if fe._owner[h.req_id] is victim]
        assert victim_handles, "routing should give worker 0 requests"
        # wait until the victim is genuinely mid-stream: it has emitted
        # tokens but no victim-owned request is anywhere near done
        deadline = time.monotonic() + 120.0
        while (not any(h.tokens for h in victim_handles)
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert any(h.tokens for h in victim_handles)
        assert not all(h.finished for h in victim_handles)
        os.kill(victim.process.pid, signal.SIGKILL)
        outs = fe.drain(timeout=240.0)
        s = fe.summary()
    got = [outs[r.req_id].tokens.tolist() for r in requests]
    assert got == want                      # byte-identical across kill
    assert s["failures"] == 1
    assert s["rerouted"] >= 1
    assert s["workers"]["pw0"]["failed"] is True
    assert s["workers"]["pw1"]["alive"] is True
    # every re-routed request's final owner is the survivor
    dead_owned = [rid for rid, wid in fe.last_owners.items()
                  if wid == "pw0"]
    assert not dead_owned or all(outs[rid].finish_reason == "length"
                                 for rid in dead_owned)
    # no orphaned KV blocks on the survivor once drained
    pool = s["pools"]["pw1"]
    assert pool["free_blocks"] == pool["num_blocks"]


@pytest.mark.slow
def test_proc_cluster_disaggregated_roles_across_processes(cfg):
    """Prefill/decode split over real processes: long prompts prefill
    on the prefill worker, the span rides the central handoff op into
    the decode owner's process, and outputs stay byte-identical."""
    rng = np.random.default_rng(11)
    requests = [GenerationRequest(
        rng.integers(1, cfg.vocab_size, size=s).astype(np.int32),
        max_new_tokens=6, sampling=SamplingParams(temperature=0.0))
        for s in (4, 24, 40)]           # short stays local, long spans
    kw = dict(max_slots=2, max_seq=96, paged=True, block_size=16,
              num_blocks=64)
    want = _reference_outputs(cfg, requests, **kw)
    with ProcClusterFrontEnd(cfg, n_workers=2, policy="xartrek",
                             seed=SEED, roles=("prefill", "mixed"),
                             **kw) as fe:
        fe.warmup(timeout=300.0)
        for r in requests:
            fe.submit(r)
        outs = fe.drain(timeout=120.0)
        s = fe.summary()
    assert [outs[r.req_id].tokens.tolist() for r in requests] == want
    assert s["handoffs"] >= 2           # both long prompts spanned
    assert s["roles"] == {"pw0": "prefill", "pw1": "mixed"}


@pytest.mark.slow
def test_proc_cluster_abort_round_trip(cfg):
    """abort() crosses the process boundary: the worker engine finishes
    the request as aborted and the finish event closes the handle."""
    requests = _greedy_requests(cfg, 2, max_new=48, seed=7)
    with ProcClusterFrontEnd(cfg, n_workers=1, policy="xartrek",
                             seed=SEED, max_slots=2, max_seq=96) as fe:
        fe.warmup(timeout=300.0)
        h0 = fe.submit(requests[0])
        h1 = fe.submit(requests[1])
        deadline = time.monotonic() + 60.0
        while not h0.tokens and time.monotonic() < deadline:
            time.sleep(0.005)
        assert h0.abort()
        outs = fe.drain(timeout=120.0)
    assert outs[h0.req_id].finish_reason == "aborted"
    assert outs[h1.req_id].finish_reason == "length"
    assert len(outs[h1.req_id].tokens) == 48
