"""Chunked prefill + prefill/decode disaggregation.

Four layers under test, mirroring the implementation stack:

* the Pallas prefill-at-offset kernel (``ops.paged_gqa_prefill`` and
  its int8 variant) against the XLA gather-then-attend path across GQA
  ratios, offsets (including 0 and block-unaligned) and pool dtypes;
* the engine's budgeted chunk loop — greedy tokens must be
  byte-identical chunked-on vs chunked-off on HOST, on ACCEL, under a
  forced HOST -> ACCEL -> HOST migration, and across a preemption that
  lands MID-prefill (the resume must restart from the completed-chunk
  offset, not token 0);
* the shared ``prompt_bucket`` compile signature: with the chunk budget
  at or below ``min_bucket`` every prefill_ctx call matches the default
  example shape, so a mixed prompt stream records ZERO bucket misses;
* the disaggregated cluster: prefill-role workers chunk prompts into
  ``KVSpan`` payloads handed to decode-role workers over the control
  plane, and the output stream stays byte-identical to a mixed fleet.

Byte-identity tests pin ``kv_cache_dtype`` to the compute dtype — the
same lossless-pool argument as the prefix-cache suite.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.runtime import XarTrekRuntime
from repro.kernels import ops
from repro.models.attention import paged_prefill_attention
from repro.serve import (ContinuousBatchingEngine, GenerationRequest,
                         ServeEngine)
from repro.serve.batch import KVSpan
from repro.serve.cluster import ClusterFrontEnd


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _serve(engine, reqs=()):
    return {rid: out.tokens for rid, out in engine.run(reqs).items()}


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32", kv_cache_dtype="float32")


@pytest.fixture(scope="module")
def sync_engine(cfg):
    return ServeEngine(cfg, seed=0)


def _prompt_set(cfg, seed=0):
    """Mixed stream: short (monolithic), block-unaligned medium, long
    multi-chunk — plus a shared-prefix pair to keep the cache busy."""
    rng = np.random.RandomState(seed)
    prefix = rng.randint(1, cfg.vocab_size, size=9)
    return [
        rng.randint(1, cfg.vocab_size, size=5),
        rng.randint(1, cfg.vocab_size, size=21),
        np.concatenate([prefix, rng.randint(1, cfg.vocab_size, size=4)]),
        np.concatenate([prefix, rng.randint(1, cfg.vocab_size, size=24)]),
    ]


def _reqs(prompts, n=6):
    return [GenerationRequest(np.asarray(p, np.int32), max_new_tokens=n)
            for p in prompts]


def _engine(cfg, params, *, prefix=True, **kw):
    base = dict(max_slots=5, max_seq=64, params=params,
                paged=True, block_size=8, num_blocks=24)
    base.update(kw)
    return ContinuousBatchingEngine(cfg, prefix_cache=prefix, **base)


# ------------------------------------------------- kernel vs XLA oracle

def _prefill_problem(seed, B, KV, G, hd, NP, BS, NBT, W):
    """Random pool + distinct-block tables + a W-token chunk's q/k/v."""
    rng = np.random.RandomState(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    kp = _rand(ks[0], (NP, BS, KV, hd))
    vp = _rand(ks[1], (NP, BS, KV, hd))
    tables = jnp.asarray(
        np.stack([rng.permutation(NP)[:NBT] for _ in range(B)]), jnp.int32)
    q = _rand(ks[2], (B, W, KV * G, hd))
    kn = _rand(ks[3], (B, W, KV, hd))
    vn = _rand(ks[4], (B, W, KV, hd))
    return q, kp, vp, kn, vn, tables


def _kv_index(KV, G):
    return np.repeat(np.arange(KV), G)


@pytest.mark.parametrize("KV,G", [(1, 1), (2, 2), (2, 4)])
@pytest.mark.parametrize("offset", [0, 8, 11])
def test_prefill_kernel_matches_xla_gather(KV, G, offset):
    """Streamed pool read masked to [0, offset) + fused chunk causal
    self-attention == gather-then-attend, across GQA ratios and both
    block-aligned and mid-block offsets (BS=8 -> 11 is unaligned)."""
    B, hd, NP, BS, NBT, W = 2, 16, 12, 8, 4, 8
    q, kp, vp, kn, vn, tables = _prefill_problem(3, B, KV, G, hd,
                                                 NP, BS, NBT, W)
    n_real = 5                       # chunk padding columns past length
    off = jnp.full((B,), offset, jnp.int32)
    length = off + n_real
    kvi = _kv_index(KV, G)
    want = paged_prefill_attention(q, kp, vp, tables, off, length, kn, vn,
                                   kv_index=kvi, backend="xla")
    got = paged_prefill_attention(q, kp, vp, tables, off, length, kn, vn,
                                  kv_index=kvi, backend="pallas")
    np.testing.assert_allclose(np.asarray(got)[:, :n_real],
                               np.asarray(want)[:, :n_real],
                               atol=2e-5, rtol=2e-5)


def test_prefill_kernel_pool_junk_isolation():
    """Pool columns at or past ``offset`` are junk (uninitialised or
    other requests' blocks): poison them with huge values and the
    kernel's output must not move."""
    B, KV, G, hd, NP, BS, NBT, W = 1, 2, 2, 16, 10, 8, 3, 8
    q, kp, vp, kn, vn, tables = _prefill_problem(7, B, KV, G, hd,
                                                 NP, BS, NBT, W)
    off = jnp.asarray([9], jnp.int32)
    length = jnp.asarray([9 + W], jnp.int32)
    kvi = _kv_index(KV, G)
    clean = paged_prefill_attention(q, kp, vp, tables, off, length, kn, vn,
                                    kv_index=kvi, backend="pallas")
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    # poison every pool position past the context in logical order
    order = np.asarray(tables)[0]
    flat_k = kp2[order].reshape(NBT * BS, KV, hd)
    flat_v = vp2[order].reshape(NBT * BS, KV, hd)
    flat_k[9:] = 1e4
    flat_v[9:] = 1e4
    kp2[order] = flat_k.reshape(NBT, BS, KV, hd)
    vp2[order] = flat_v.reshape(NBT, BS, KV, hd)
    dirty = paged_prefill_attention(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                    tables, off, length, kn, vn,
                                    kv_index=kvi, backend="pallas")
    np.testing.assert_allclose(np.asarray(dirty), np.asarray(clean),
                               atol=2e-5, rtol=2e-5)


def _quantise(pages):
    """Symmetric per-(token, kv-head) int8 quantisation of a pool."""
    p = np.asarray(pages)
    scale = np.abs(p).max(axis=-1, keepdims=True) / 127.0
    scale = np.maximum(scale, 1e-8).astype(np.float32)
    q = np.clip(np.round(p / scale), -127, 127).astype(np.int8)
    return jnp.asarray(q), jnp.asarray(scale)


@pytest.mark.parametrize("offset", [0, 13])
def test_prefill_kernel_int8_matches_xla(offset):
    """Int8 pool + scale planes: in-kernel dequantisation must equal the
    XLA dequantise-gather-attend path on the SAME quantised inputs."""
    B, KV, G, hd, NP, BS, NBT, W = 2, 2, 2, 16, 12, 8, 4, 8
    q, kp, vp, kn, vn, tables = _prefill_problem(11, B, KV, G, hd,
                                                 NP, BS, NBT, W)
    kq, ksc = _quantise(kp)
    vq, vsc = _quantise(vp)
    off = jnp.full((B,), offset, jnp.int32)
    length = off + W
    kvi = _kv_index(KV, G)
    want = paged_prefill_attention(q, kq, vq, tables, off, length, kn, vn,
                                   kv_index=kvi, backend="xla",
                                   k_scale=ksc, v_scale=vsc)
    got = paged_prefill_attention(q, kq, vq, tables, off, length, kn, vn,
                                  kv_index=kvi, backend="pallas",
                                  k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_prefill_wrapper_accepts_scalar_offset():
    """ops wrappers broadcast scalar offset/length to (B,)."""
    B, KV, G, hd, NP, BS, NBT, W = 2, 2, 1, 16, 8, 8, 2, 8
    q, kp, vp, kn, vn, tables = _prefill_problem(5, B, KV, G, hd,
                                                 NP, BS, NBT, W)
    kvi = tuple(_kv_index(KV, G).tolist())
    a = ops.paged_gqa_prefill(q, kp, vp, kn, vn, tables,
                              jnp.int32(8), jnp.int32(16), kv_index=kvi)
    b = ops.paged_gqa_prefill(q, kp, vp, kn, vn, tables,
                              jnp.full((B,), 8, jnp.int32),
                              jnp.full((B,), 16, jnp.int32), kv_index=kvi)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------- KVSpan wire form

def test_kv_span_round_trip():
    """to_bytes/from_bytes preserve every leaf bit-for-bit, including a
    bfloat16 pool (dtype name resolved through ml_dtypes)."""
    import ml_dtypes
    rng = np.random.RandomState(0)
    kv = {
        "k": rng.randn(2, 3, 8, 2, 4).astype(np.float32),
        "v": rng.randn(2, 3, 8, 2, 4).astype(ml_dtypes.bfloat16),
    }
    span = KVSpan(prompt=np.arange(17, dtype=np.int32), first_token=42,
                  first_logprob=-1.25, block_size=8, kv=kv)
    back = KVSpan.from_bytes(span.to_bytes())
    assert back.first_token == 42
    assert back.first_logprob == pytest.approx(-1.25)
    assert back.block_size == 8
    np.testing.assert_array_equal(back.prompt, span.prompt)
    for name, leaf in kv.items():
        assert back.kv[name].dtype == leaf.dtype
        np.testing.assert_array_equal(
            back.kv[name].view(np.uint8), leaf.view(np.uint8))


# ------------------------------------------- engine-level byte identity

def test_host_chunked_on_off_byte_identical(cfg, sync_engine):
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False)
    r_off = _reqs(prompts)
    want = _serve(off, r_off)
    on = _engine(cfg, sync_engine.params, prefix=False,
                 prefill_tokens_per_step=8)
    r_on = _reqs(prompts)
    got = _serve(on, r_on)
    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    assert on.stats["prefill_chunks"] > 0
    assert sum(on.stats["chunk_hist"].values()) == on.stats["prefill_chunks"]


def test_chunked_with_prefix_cache_byte_identical(cfg, sync_engine):
    """Chunking composes with prefix caching: a second run on the same
    engine revives the first run's registered chunk blocks, so the
    shared-prefix prompt starts its chunk loop at the matched-block
    offset instead of token 0."""
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False)
    r_off = _reqs(prompts)
    want = _serve(off, r_off)
    on = _engine(cfg, sync_engine.params, prefill_tokens_per_step=8)
    r_first = _reqs(prompts[:3])
    got = _serve(on, r_first)
    r_second = _reqs(prompts[3:])
    got.update(_serve(on, r_second))
    for a, b in zip(r_off, r_first + r_second):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    assert on.prefix_stats()["prefix_hit_tokens"] > 0
    assert on.stats["prefill_chunks"] > 0


def test_accel_chunked_on_off_byte_identical(cfg, sync_engine):
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False, backend="accel")
    r_off = _reqs(prompts)
    want = _serve(off, r_off)
    on = _engine(cfg, sync_engine.params, prefix=False, backend="accel",
                 prefill_tokens_per_step=8)
    r_on = _reqs(prompts)
    got = _serve(on, r_on)
    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    assert on.stats["prefill_chunks"] > 0


def test_migration_chunked_on_off_byte_identical(cfg, sync_engine):
    """Forced HOST -> ACCEL -> HOST mid-stream with chunking on: chunk
    prefills land on whichever target the policy picks per call and the
    stream stays bit-for-bit."""
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False)
    r_off = _reqs(prompts)
    want = _serve(off, r_off)

    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")

    def flip(engine):
        s = engine.stats["decode_steps"]
        if s == 1:
            rt.server.policy = "always_accel"
        elif s == 3:
            rt.server.policy = "always_host"

    on = _engine(cfg, sync_engine.params, prefix=False, runtime=rt,
                 on_step=flip, prefill_tokens_per_step=8)
    r_on = _reqs(prompts)
    got = _serve(on, r_on)
    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    decode = rt.summary()["per_function"]["cb_decode"]
    assert decode["calls"].get("host", 0) >= 1
    assert decode["calls"].get("accel", 0) >= 1
    assert on.stats["prefill_chunks"] > 0


def test_policy_budget_hook_drives_chunking(cfg, sync_engine):
    """No static knob: the engine pulls the per-step budget from the
    policy's ``prefill_budget`` hook (LatencyAwarePolicy's knob).  The
    hook returns None while no decode slot is active — nothing to
    stall — so the first admissions prefill monolithically and only
    later prompts, arriving against live decoders, get chunked
    (max_slots=2 plus a long-decoding first request force that)."""
    from repro.core.policy import LatencyAwarePolicy
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab_size, size=n) for n in (5, 21, 33)]
    lens = [20, 4, 4]

    def reqs():
        return [GenerationRequest(np.asarray(p, np.int32),
                                  max_new_tokens=n)
                for p, n in zip(prompts, lens)]

    off = _engine(cfg, sync_engine.params, prefix=False)
    r_off = reqs()
    want = _serve(off, r_off)
    pol = LatencyAwarePolicy(prefill_tokens_per_step=8)
    rt = XarTrekRuntime(registry=FunctionRegistry(), policy=pol)
    on = _engine(cfg, sync_engine.params, prefix=False, runtime=rt,
                 max_slots=2)
    r_on = reqs()
    got = _serve(on, r_on)
    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    assert on.stats["prefill_chunks"] > 0


# ----------------------------------------- preempt mid-prefill (bugfix)

def test_preempt_mid_prefill_resumes_from_offset(cfg, sync_engine):
    """A short decoder outgrows a tight pool while a long prompt is
    still chunk-prefilling: the prefilling slot is preempted, frees its
    private blocks but keeps the registered full ones in the cached
    set, and the resume restarts from the completed-chunk offset — not
    token 0 — with tokens equal to an unconstrained engine's."""
    rng = np.random.RandomState(3)
    p1 = rng.randint(1, cfg.vocab_size, size=6)
    p2 = rng.randint(1, cfg.vocab_size, size=20)
    big = _engine(cfg, sync_engine.params, prefix=False,
                  max_slots=2, max_seq=32)
    d1, d2 = _reqs([p1, p2], n=12)
    want = _serve(big, [d1, d2])
    small = _engine(cfg, sync_engine.params, max_slots=2, max_seq=32,
                    block_size=4, num_blocks=8, prefill_tokens_per_step=2)
    s1, s2 = _reqs([p1, p2], n=12)
    got = _serve(small, [s1, s2])
    assert small.slots.stats["preempted"] >= 1
    # the resume matched the chunks registered before preemption
    assert small.prefix_stats()["prefix_hit_tokens"] > 0
    np.testing.assert_array_equal(want[d1.req_id], got[s1.req_id])
    np.testing.assert_array_equal(want[d2.req_id], got[s2.req_id])
    assert small.slots.pool.blocks_in_use() == 0


# ----------------------------------------------- shared prompt buckets

def test_mixed_stream_zero_bucket_misses(cfg, sync_engine):
    """Satellite (a): chunked prefill, prefix-cache re-feed and
    resume-by-re-prefill all route through ``_ctx_chunk`` with widths
    bucketed by ``prompt_bucket`` — with the budget at ``min_bucket``
    every prefill_ctx call matches the registered example signature, so
    a mixed stream records zero shape-bucket misses."""
    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")
    eng = _engine(cfg, sync_engine.params, runtime=rt,
                  prefill_tokens_per_step=8)
    _serve(eng, _reqs(_prompt_set(cfg)))
    assert eng.stats["prefill_chunks"] > 0
    buckets = rt.summary()["shape_buckets"].get("cb_prefill_ctx",
                                                {"misses": 0})
    assert buckets["misses"] == 0


# ----------------------------------------------- disaggregated cluster

def test_disagg_cluster_byte_identical_and_observable(cfg, sync_engine):
    """1 prefill + 1 decode worker vs a mixed fleet: same greedy bytes,
    and the summary exposes roles, handoff count and per-worker
    chunked-prefill stats (satellite b)."""
    rng = np.random.RandomState(4)
    prompts = [rng.randint(1, cfg.vocab_size, size=n) for n in (19, 7, 30)]

    def run(**kw):
        fe = ClusterFrontEnd(cfg, n_engines=2, params=sync_engine.params,
                             max_slots=4, max_seq=64, paged=True,
                             block_size=8, num_blocks=24, **kw)
        with fe:
            fe.warmup()
            hs = [fe.submit(GenerationRequest(np.asarray(p, np.int32),
                                              max_new_tokens=5))
                  for p in prompts]
            outs = fe.drain(timeout=180)
            summ = fe.summary()
        return [outs[h.req_id].tokens for h in hs], summ

    want, _ = run()
    got, summ = run(roles=["prefill", "decode"], prefill_tokens_per_step=8)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    assert summ["roles"] == {"w0": "prefill", "w1": "decode"}
    # the 7-token prompt sits under the span threshold and prefills in
    # place on the decode owner; the two long ones ride the span tier
    assert summ["handoffs"] >= 2
    assert summ["chunked_prefill"]["w0"]["prefill_chunks"] > 0
    assert summ["chunked_prefill"]["w1"]["spans_admitted"] == 2


def test_disagg_cluster_tcp_transport(cfg, sync_engine):
    """Handoffs ride the line-JSON TCP control plane (base64 payloads),
    not just the in-process fast path."""
    rng = np.random.RandomState(5)
    prompts = [rng.randint(1, cfg.vocab_size, size=n) for n in (19, 30)]
    fe = ClusterFrontEnd(cfg, n_engines=2, params=sync_engine.params,
                         transport="tcp", max_slots=4, max_seq=64,
                         paged=True, block_size=8, num_blocks=24,
                         roles=["prefill", "decode"],
                         prefill_tokens_per_step=8)
    with fe:
        fe.warmup()
        hs = [fe.submit(GenerationRequest(np.asarray(p, np.int32),
                                          max_new_tokens=5))
              for p in prompts]
        outs = fe.drain(timeout=180)
        summ = fe.summary()
    assert summ["handoffs"] >= len(prompts)
    for h in hs:
        assert outs[h.req_id].finish_reason == "length"
        assert len(outs[h.req_id].tokens) == 5


def test_prefill_role_requires_paged_and_decode_capable(cfg, sync_engine):
    with pytest.raises(ValueError):
        ClusterFrontEnd(cfg, n_engines=2, params=sync_engine.params,
                        roles=["prefill", "prefill"], paged=True,
                        max_slots=2, max_seq=32, block_size=8,
                        num_blocks=16)
    with pytest.raises(ValueError):
        ClusterFrontEnd(cfg, n_engines=2, params=sync_engine.params,
                        roles=["prefill", "decode"], max_slots=2,
                        max_seq=32)


def test_engine_span_round_trip(cfg, sync_engine):
    """prefill_to_span on one engine, submit_span on another: the decode
    engine never sees the prompt's forward pass yet produces the same
    stream as a local end-to-end run."""
    rng = np.random.RandomState(6)
    prompt = rng.randint(1, cfg.vocab_size, size=19)
    [req] = _reqs([prompt], n=6)
    local = _engine(cfg, sync_engine.params, prefix=False)
    want = _serve(local, [req])[req.req_id]

    pre = _engine(cfg, sync_engine.params, prefix=False)
    span = KVSpan.from_bytes(
        pre.prefill_to_span(GenerationRequest(
            np.asarray(prompt, np.int32), max_new_tokens=6,
            req_id=req.req_id), budget=8).to_bytes())
    assert pre.slots.pool.blocks_in_use() == 0     # scratch blocks freed

    dec = _engine(cfg, sync_engine.params, prefix=False)
    dec.submit_span(GenerationRequest(np.asarray(prompt, np.int32),
                                      max_new_tokens=6,
                                      req_id=req.req_id), span)
    out = dec.run()[req.req_id]
    np.testing.assert_array_equal(want, out.tokens)
    assert dec.stats["spans_admitted"] == 1
    assert dec.stats["prefills"] == 0          # never ran the prompt
