"""Property tests on the platform simulator's invariants."""
import random

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis test dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.sim import AppProfile, PAPER_APPS, PlatformSim
from repro.core.targets import TargetKind

APPS = list(PAPER_APPS.values())


@given(n_apps=st.integers(1, 12), seed=st.integers(0, 100),
       policy=st.sampled_from(["always_host", "always_aux", "xartrek"]))
@settings(max_examples=40, deadline=None)
def test_all_jobs_complete(n_apps, seed, policy):
    """Every submitted job finishes; completion time >= isolated time on
    its chosen target (queueing/contention can only slow things down)."""
    sim = PlatformSim(policy=policy,
                      preconfigure=tuple(a.hw_kernel for a in APPS))
    rng = random.Random(seed)
    jobs = [sim.submit(rng.choice(APPS), at=rng.uniform(0, 1000))
            for _ in range(n_apps)]
    sim.run()
    assert len(sim.done) == n_apps
    best_case = {TargetKind.HOST: "x86_ms", TargetKind.AUX: "arm_ms",
                 TargetKind.ACCEL: "fpga_ms"}
    for j in jobs:
        assert j.finish >= j.start - 1e-6
        iso = getattr(j.app, best_case[j.target])
        assert j.finish - j.start >= iso - 1e-3, (
            j.app.name, j.target, j.finish - j.start, iso)


@given(seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_host_contention_monotone(seed):
    """Adding background load never speeds up an always-host app."""
    rng = random.Random(seed)
    app = rng.choice(APPS)
    times = []
    for n_bg in (0, 8, 20):
        sim = PlatformSim(policy="always_host")
        bg = AppProfile("bg", 30000, 30000, 30000, "K")
        for _ in range(n_bg):
            sim.submit(bg, at=0.0, background=True)
        job = sim.submit(app, at=1.0)
        sim.run()
        times.append(job.finish - job.start)
    assert times[0] <= times[1] + 1e-6 <= times[2] + 2e-6


@given(n_apps=st.integers(1, 8), seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_xartrek_never_uses_cold_accel(n_apps, seed):
    """With an empty bank and no reconfiguration time elapsed, the policy
    must not send anything to ACCEL before the bank turns hot."""
    sim = PlatformSim(policy="xartrek", reconfig_ms=1e12)  # never completes
    rng = random.Random(seed)
    for _ in range(n_apps):
        sim.submit(rng.choice(APPS), at=0.0)
    sim.run()
    assert sim.decisions[TargetKind.ACCEL] == 0


@given(seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_accel_serialises(seed):
    """Two simultaneous ACCEL jobs cannot both finish in isolated time."""
    rng = random.Random(seed)
    app = rng.choice(APPS)
    sim = PlatformSim(policy="always_accel",
                      preconfigure=(app.hw_kernel,))
    j1 = sim.submit(app, at=0.0)
    j2 = sim.submit(app, at=0.0)
    sim.run()
    d1, d2 = j1.finish - j1.start, j2.finish - j2.start
    assert max(d1, d2) >= 2 * app.fpga_ms - 1e-3
