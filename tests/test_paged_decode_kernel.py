"""Paged (block-table-aware) Pallas decode kernel vs the jnp oracles.

The kernel streams a row's physical KV blocks in logical order via
scalar-prefetched block tables — these tests pin its math to
``ref.decode_attention_ref`` (assembling the equivalent dense cache by
hand) and to the HOST gather-then-attend path across GQA ratios, head
dims, ragged live-lengths and block sizes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gqa_decode import paged_gqa_decode as paged_raw
from repro.models.attention import (
    decode_attention, kv_head_index, paged_decode_attention,
)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _pool_problem(seed, B, KV, hd, NP, BS, NBT, lengths):
    """Random pool + per-row tables of DISTINCT physical blocks (as the
    serve engine allocates), plus the current token's K/V."""
    rng = np.random.RandomState(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    kp = _rand(ks[0], (NP, BS, KV, hd))
    vp = _rand(ks[1], (NP, BS, KV, hd))
    tables = np.stack([rng.permutation(NP)[:NBT] for _ in range(B)])
    kn = _rand(ks[2], (B, 1, KV, hd))
    vn = _rand(ks[3], (B, 1, KV, hd))
    idx = jnp.asarray(lengths, jnp.int32)
    return kp, vp, jnp.asarray(tables, jnp.int32), kn, vn, idx


def _dense_equivalent(q, kp, vp, tables, idx, kn, vn, kv_index):
    """Assemble the dense per-row cache the paged kernel implies (gather
    blocks in logical order, write the new token at ``idx``) and run
    ``ref.decode_attention_ref`` per row over [0, idx] inclusive."""
    B, _, Hp, hd = q.shape
    NP, BS, KV, _ = kp.shape
    NBT = tables.shape[1]
    S = NBT * BS
    kc = np.asarray(kp)[np.asarray(tables)].reshape(B, S, KV, hd).copy()
    vc = np.asarray(vp)[np.asarray(tables)].reshape(B, S, KV, hd).copy()
    for b in range(B):
        kc[b, int(idx[b])] = np.asarray(kn)[b, 0]
        vc[b, int(idx[b])] = np.asarray(vn)[b, 0]
    kvmap = (np.arange(Hp) if kv_index is None else np.asarray(kv_index))
    out = np.zeros((B, 1, Hp, hd), np.float32)
    for b in range(B):
        qf = np.asarray(q)[b].transpose(1, 0, 2)          # (Hp, 1, hd)
        kf = kc[b].transpose(1, 0, 2)[kvmap]              # (Hp, S, hd)
        vf = vc[b].transpose(1, 0, 2)[kvmap]
        row = ref.decode_attention_ref(jnp.asarray(qf), jnp.asarray(kf),
                                       jnp.asarray(vf), jnp.int32(idx[b]))
        out[b] = np.asarray(row).transpose(1, 0, 2)
    return out


@pytest.mark.parametrize("Hp,KV,hd,BS,NBT,lengths", [
    (4, 2, 32, 8, 3, (0, 7, 23)),       # GQA 2:1, zero-length row
    (4, 4, 32, 8, 2, (3, 15, 10)),      # MHA (identity map)
    (3, 1, 64, 16, 2, (0, 31, 17)),     # odd heads onto one kv head
    (8, 2, 16, 4, 4, (15, 1, 8)),       # GQA 4:1, tiny blocks
    (5, 2, 32, 8, 3, (23, 11, 2)),      # non-uniform groups (3 + 2)
])
def test_paged_kernel_matches_decode_attention_ref(Hp, KV, hd, BS, NBT,
                                                   lengths):
    B, NP = len(lengths), NBT * len(lengths) + 1
    kv_idx = None if Hp == KV else kv_head_index(Hp, KV, Hp)
    q = _rand(jax.random.PRNGKey(42), (B, 1, Hp, hd))
    kp, vp, tables, kn, vn, idx = _pool_problem(7, B, KV, hd, NP, BS, NBT,
                                                lengths)
    got = ops.paged_gqa_decode(
        q, kp, vp, kn, vn, tables, idx,
        kv_index=None if kv_idx is None else tuple(int(i) for i in kv_idx))
    want = _dense_equivalent(q, kp, vp, tables, idx, kn, vn, kv_idx)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=2e-5)


def test_paged_raw_kernel_matches_oracle():
    """Raw (already-grouped) kernel vs the pure-jnp paged oracle."""
    B, KV, G, hd, NP, BS, NBT = 3, 2, 3, 32, 7, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = _rand(ks[0], (B, KV, G, hd))
    kp = _rand(ks[1], (NP, BS, KV, hd))
    vp = _rand(ks[2], (NP, BS, KV, hd))
    kn = _rand(ks[3], (B, KV, 1, hd))
    vn = _rand(ks[4], (B, KV, 1, hd))
    rng = np.random.RandomState(0)
    tables = jnp.asarray(rng.randint(0, NP, size=(B, NBT)), jnp.int32)
    idx = jnp.asarray([0, 13, 30], jnp.int32)
    got = paged_raw(q, kp, vp, kn, vn, tables, idx, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, kn, vn, tables, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_k", [8, 16, 64])
def test_gqa_decode_ragged_matches_xla_decode_attention(block_k):
    """Dense-cache ragged decode through the paged kernel (identity block
    table view) vs the XLA reference with the explicit-new-token path."""
    B, Smax, Hp, KV, hd = 3, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    q = _rand(ks[0], (B, 1, Hp, hd))
    kc = _rand(ks[1], (B, Smax, KV, hd))
    vc = _rand(ks[2], (B, Smax, KV, hd))
    kn = _rand(ks[3], (B, 1, KV, hd))
    vn = _rand(ks[4], (B, 1, KV, hd))
    idx = jnp.asarray([0, 29, 63], jnp.int32)
    kv_idx = kv_head_index(Hp, KV, Hp)
    got = ops.gqa_decode_ragged(q, kc, vc, idx, kn, vn,
                                kv_index=tuple(int(i) for i in kv_idx),
                                block_k=block_k)
    want = decode_attention(q, kc, vc, idx[:, None, None, None],
                            kv_index=kv_idx, k_new=kn, v_new=vn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_attention_backends_agree():
    """models.attention.paged_decode_attention: pallas backend (in-kernel
    block streaming) vs xla backend (materialised gather)."""
    B, Hp, KV, hd, NP, BS, NBT = 3, 4, 2, 32, 9, 8, 3
    kv_idx = kv_head_index(Hp, KV, Hp)
    q = _rand(jax.random.PRNGKey(5), (B, 1, Hp, hd))
    kp, vp, tables, kn, vn, idx = _pool_problem(9, B, KV, hd, NP, BS, NBT,
                                                (0, 7, 23))
    outs = {be: paged_decode_attention(q, kp, vp, tables, idx, kn, vn,
                                       kv_index=kv_idx, backend=be)
            for be in ("xla", "pallas")}
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["xla"]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("index", [jnp.int32(41),
                                   jnp.asarray([3, 57], jnp.int32)])
def test_no_knew_dense_decode_backends_agree(index):
    """decode_attention backend="pallas" without k_new (the synchronous
    engine's attend-over-[0,index] shape), scalar and ragged index."""
    B, Smax, Hp, hd = 2, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = _rand(ks[0], (B, 1, Hp, hd))
    kc = _rand(ks[1], (B, Smax, Hp, hd))
    vc = _rand(ks[2], (B, Smax, Hp, hd))
    xla_index = index[:, None, None, None] if index.ndim else index
    got = decode_attention(q, kc, vc, index, backend="pallas")
    want = decode_attention(q, kc, vc, xla_index, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_interpret_env_override_reaches_dispatch(monkeypatch):
    """REPRO_PALLAS_INTERPRET must be resolved per call, outside the jit
    cache: flipping it after a cached trace still takes effect (here:
    forcing native lowering on CPU fails loudly instead of silently
    reusing the interpret-mode executable)."""
    x = jnp.ones((8, 64))
    w = jnp.ones((64,))
    monkeypatch.setenv(ops.INTERPRET_ENV, "1")
    ops.rmsnorm(x, w)                       # traced + cached (interpret)
    monkeypatch.setenv(ops.INTERPRET_ENV, "0")
    with pytest.raises(Exception):          # no TPU: native lowering dies
        jax.block_until_ready(ops.rmsnorm(x, w))
    monkeypatch.delenv(ops.INTERPRET_ENV)
    ops.rmsnorm(x, w)                       # auto default restored


def test_junk_block_and_zero_length_rows_are_well_defined():
    """Length-0 rows (inactive serve slots: all-zero table into the junk
    block) must reduce to softmax over the new token alone — no NaNs, no
    reads of junk content."""
    B, KV, G, hd, NP, BS, NBT = 2, 1, 2, 16, 4, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = _rand(ks[0], (B, KV, G, hd))
    # poison the junk block with huge values: masked positions must not
    # leak them into the output
    kp = jnp.full((NP, BS, KV, hd), 1e4, jnp.float32)
    vp = jnp.full((NP, BS, KV, hd), -1e4, jnp.float32)
    kn = _rand(ks[1], (B, KV, 1, hd))
    vn = _rand(ks[2], (B, KV, 1, hd))
    tables = jnp.zeros((B, NBT), jnp.int32)
    idx = jnp.zeros((B,), jnp.int32)
    out = paged_raw(q, kp, vp, kn, vn, tables, idx, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.asarray(vn), out.shape),
                               atol=1e-6)


# ----------------------------------------------------- property sweep

@pytest.mark.slow
def test_paged_kernel_property_sweep():
    """hypothesis-optional randomized shape/length sweep (slow tier)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need the hypothesis test dep")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 4), st.sampled_from([16, 32]),
           st.sampled_from([4, 8]), st.integers(1, 4), st.integers(0, 1000),
           st.randoms(use_true_random=False))
    def run(B, KV, hd, BS, NBT, seed, rnd):
        group = rnd.randint(1, 3)
        Hp = KV * group + rnd.randint(0, 1)     # sometimes non-uniform
        kv_idx = None if Hp == KV else np.minimum(
            np.arange(Hp) // group, KV - 1)
        NP = B * NBT + 1
        lengths = [rnd.randint(0, NBT * BS - 1) for _ in range(B)]
        q = _rand(jax.random.PRNGKey(seed), (B, 1, Hp, hd))
        kp, vp, tables, kn, vn, idx = _pool_problem(
            seed, B, KV, hd, NP, BS, NBT, lengths)
        got = ops.paged_gqa_decode(
            q, kp, vp, kn, vn, tables, idx,
            kv_index=None if kv_idx is None
            else tuple(int(i) for i in kv_idx))
        want = _dense_equivalent(q, kp, vp, tables, idx, kn, vn, kv_idx)
        np.testing.assert_allclose(np.asarray(got), want,
                                   atol=3e-5, rtol=3e-5)

    run()
