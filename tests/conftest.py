"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real (single) device; multi-device tests spawn subprocesses
with their own --xla_force_host_platform_device_count."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
