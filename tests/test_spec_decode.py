"""Speculative decoding: draft-on-HOST / verify-on-ACCEL.

Four layers under test, mirroring the implementation stack:

* the verify kernel wrappers (``ops.paged_gqa_verify`` / ``_int8``) —
  bitwise the chunk-prefill kernel body under a distinct registered
  name, checked against the gather-then-attend ref oracle across GQA
  ratios x offsets x draft widths x f32/int8 pools, plus pool-junk
  isolation (junk beyond the masked window must not leak);
* the model step functions: ``decode_verify`` (multi-token
  prefill-at-offset + per-position sampling + masked pool scatter)
  against k sequential decode steps, and the fused ``decode_draft``
  chain against manually chained decode+sample;
* ``serve/spec.py``: the longest-accepted-prefix rule, the
  layer-truncated draft share, and ``zero_top_layers``' exact residual
  identity (the bench's ~1.0-acceptance configuration);
* the engine: GREEDY byte-identity spec-on vs spec-off on HOST, on
  ACCEL, through a runtime holding draft-on-HOST / verify-on-ACCEL
  (with per-target call accounting), under forced mid-stream verify
  migration, across preempt/resume on a starved pool, and with prefix
  caching; seeded-sampled determinism for a fixed spec config; the
  policy ``draft_len`` hook.

Satellite regressions ride along: the ``decode_stall_ms`` EWMA ->
``LatencyAwarePolicy.prefill_budget`` contraction loop, and the
static-signature ``_scatter_span`` (one compile for every span size,
byte-identical rehydrated tokens).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.policy import (
    Decision, LatencyAwarePolicy, LoadSignals, PinAccel,
)
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.kernels import ops
from repro.kernels.ref import (
    paged_prefill_attention_ref, paged_prefill_attention_int8_ref,
)
from repro.models.attention import paged_verify_attention
from repro.models.common import quantize_int8
from repro.models.model import build_model
from repro.models.sampling import sampling_leaves
from repro.serve import spec as spec_lib
from repro.serve.api import GenerationRequest, SamplingParams
from repro.serve.engine import ContinuousBatchingEngine


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32", kv_cache_dtype="float32")


ENGINE_KW = dict(max_slots=4, max_seq=96, paged=True, block_size=16,
                 num_blocks=24)


@pytest.fixture(scope="module")
def served(cfg):
    """(params, prompts, baseline greedy tokens per prompt index)."""
    eng = ContinuousBatchingEngine(cfg, **ENGINE_KW)
    rng = np.random.default_rng(1)
    prompts = [np.asarray(rng.integers(1, cfg.vocab_size,
                                       size=int(rng.integers(4, 20))),
                          np.int32) for _ in range(6)]
    reqs = _reqs(prompts)
    out = eng.run(reqs)
    base = [out[r.req_id].tokens for r in reqs]
    return eng.params, prompts, base


def _reqs(prompts, sampling=None, max_new=24):
    return [GenerationRequest(p, max_new_tokens=max_new,
                              sampling=sampling or SamplingParams())
            for p in prompts]


def _assert_identical(outs, reqs, base):
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(outs[r.req_id].tokens, base[i],
                                      err_msg=f"request {i}")


# --------------------------------------------- verify kernel wrappers

def _verify_problem(seed, B, KV, G, hd, NP, BS, NBT, W, int8=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    rng = np.random.RandomState(seed)
    kp = jax.random.normal(ks[0], (NP, BS, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[1], (NP, BS, KV, hd), jnp.float32)
    tables = jnp.asarray(
        np.stack([rng.permutation(NP)[:NBT] for _ in range(B)]), jnp.int32)
    q = jax.random.normal(ks[2], (B, W, KV * G, hd), jnp.float32)
    kn = jax.random.normal(ks[3], (B, W, KV, hd), jnp.float32)
    vn = jax.random.normal(ks[4], (B, W, KV, hd), jnp.float32)
    if not int8:
        return q, kp, vp, None, None, kn, vn, tables
    kq, ksc = quantize_int8(kp, axis=-1)
    vq, vsc = quantize_int8(vp, axis=-1)
    return q, kq, vq, ksc, vsc, kn, vn, tables


def _to_ref_layout(x, KV, G):
    """(B,W,KV*G,hd) model-facing -> (B,KV,W*G,hd) oracle-facing."""
    B, W, _, hd = x.shape
    return jnp.reshape(
        jnp.transpose(jnp.reshape(x, (B, W, KV, G, hd)), (0, 2, 1, 3, 4)),
        (B, KV, W * G, hd))


def _from_ref_layout(x, KV, G):
    B, _, WG, hd = x.shape
    W = WG // G
    return jnp.reshape(
        jnp.transpose(jnp.reshape(x, (B, KV, W, G, hd)), (0, 2, 1, 3, 4)),
        (B, W, KV * G, hd))


@pytest.mark.parametrize("KV,G", [(1, 1), (2, 2), (2, 4)])
@pytest.mark.parametrize("offset", [8, 11, 16])
@pytest.mark.parametrize("W", [1, 2, 4])
@pytest.mark.parametrize("int8", [False, True], ids=["f32", "int8"])
def test_verify_matches_ref_oracle(KV, G, offset, W, int8):
    """The verify wrapper == the gather-then-attend oracle across GQA
    ratios, block-aligned and mid-block offsets, every supported draft
    width, and both pool dtypes."""
    B, hd, NP, BS, NBT = 2, 16, 12, 8, 4
    q, kp, vp, ksc, vsc, kn, vn, tables = _verify_problem(
        7, B, KV, G, hd, NP, BS, NBT, W, int8=int8)
    off = jnp.full((B,), offset, jnp.int32)
    length = off + W
    kvi = tuple(np.repeat(np.arange(KV), G))
    qr = _to_ref_layout(q, KV, G)
    if int8:
        got = ops.paged_gqa_verify_int8(q, kp, ksc, vp, vsc, kn, vn,
                                        tables, off, length, kv_index=kvi)
        want = paged_prefill_attention_int8_ref(qr, kp, ksc, vp, vsc,
                                                kn, vn, tables, off,
                                                length, group=G)
    else:
        got = ops.paged_gqa_verify(q, kp, vp, kn, vn, tables, off,
                                   length, kv_index=kvi)
        want = paged_prefill_attention_ref(qr, kp, vp, kn, vn, tables,
                                           off, length, group=G)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_from_ref_layout(want, KV, G)),
                               rtol=2e-5, atol=2e-5)


def test_verify_wrapper_is_prefill_body():
    """The distinct verify name runs the SAME kernel body as chunk
    prefill — bitwise, both dtypes (the registration split is about
    runtime accounting, not math)."""
    B, KV, G, hd, NP, BS, NBT, W = 2, 2, 2, 16, 12, 8, 4, 4
    q, kp, vp, _, _, kn, vn, tables = _verify_problem(
        3, B, KV, G, hd, NP, BS, NBT, W)
    off = jnp.full((B,), 11, jnp.int32)
    kvi = tuple(np.repeat(np.arange(KV), G))
    a = ops.paged_gqa_verify(q, kp, vp, kn, vn, tables, off, off + W,
                             kv_index=kvi)
    b = ops.paged_gqa_prefill(q, kp, vp, kn, vn, tables, off, off + W,
                              kv_index=kvi)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_pool_junk_isolation():
    """Pool content at masked positions — unreferenced blocks AND the
    referenced blocks' columns at or past ``offset`` — must not change
    the verify output: rejected-draft junk beyond the write frontier is
    exactly such content."""
    B, KV, G, hd, NP, BS, NBT, W = 2, 2, 2, 16, 12, 8, 4, 4
    q, kp, vp, _, _, kn, vn, tables = _verify_problem(
        5, B, KV, G, hd, NP, BS, NBT, W)
    offset = 11                       # mid-block: block 1 is half junk
    off = jnp.full((B,), offset, jnp.int32)
    kvi = tuple(np.repeat(np.arange(KV), G))
    base = np.asarray(ops.paged_gqa_verify(q, kp, vp, kn, vn, tables,
                                           off, off + W, kv_index=kvi))
    kp2, vp2 = np.array(kp), np.array(vp)
    used = set()
    for b in range(B):
        for j in range(-(-offset // BS)):
            used.add(int(tables[b, j]))
    for p in range(NP):
        if p not in used:
            kp2[p] = 1e4              # junk an unreferenced block
            vp2[p] = -1e4
    for b in range(B):
        blk = int(tables[b, offset // BS])
        kp2[blk, offset % BS:] = 7e3  # junk past the frontier, in-block
        vp2[blk, offset % BS:] = -7e3
    got = np.asarray(ops.paged_gqa_verify(q, jnp.asarray(kp2),
                                          jnp.asarray(vp2), kn, vn,
                                          tables, off, off + W,
                                          kv_index=kvi))
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_verify_attention_backends_agree(backend, cfg):
    """Both model-facing verify builds agree with the XLA reference —
    the migration-safety precondition for the engine matrix below."""
    B, KV, G, hd, NP, BS, NBT, W = 2, 2, 2, 16, 12, 8, 4, 4
    q, kp, vp, _, _, kn, vn, tables = _verify_problem(
        9, B, KV, G, hd, NP, BS, NBT, W)
    off = jnp.full((B,), 9, jnp.int32)
    kvi = np.repeat(np.arange(KV), G)
    want = paged_verify_attention(q, kp, vp, tables, off, off + W, kn,
                                  vn, kv_index=kvi, backend="xla")
    got = paged_verify_attention(q, kp, vp, tables, off, off + W, kn,
                                 vn, kv_index=kvi, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------ model step functions

def _paged_state(cfg, model, params, S=21, bs=16, seed=0):
    """Prefill a prompt into pool blocks; returns (cache, prompt, table,
    first greedy token)."""
    cache = model.init_paged_cache(25, bs)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab_size, size=S).astype(np.int32)
    nb = -(-S // bs) + 1
    table = np.zeros((1, 8), np.int32)
    table[0, :nb] = np.arange(1, nb + 1)
    Sb = 32
    toks = np.zeros((1, Sb), np.int32)
    toks[0, :S] = prompt
    batch = {"tokens": jnp.asarray(toks),
             "offset": jnp.zeros((1,), jnp.int32),
             "length": jnp.full((1,), S, jnp.int32),
             "block_table": jnp.asarray(table),
             **sampling_leaves(SamplingParams(), 1)}
    tok0, _, pc = model.prefill_ctx_sampled(params, cache, batch,
                                            backend="xla")
    intra = np.arange(Sb)
    valid = intra < S
    blk = np.where(valid, table[0][np.clip(intra // bs, 0, 7)], 0)
    off = np.where(valid, intra % bs, 0)
    for k in pc:
        c = np.array(np.asarray(cache[k]))
        c[:, blk, off] = np.asarray(pc[k][:, 0]).astype(c.dtype)
        cache[k] = jnp.asarray(c)
    return cache, prompt, table, int(np.asarray(tok0)[0])


def test_decode_verify_matches_sequential_decode(cfg):
    """Feed verify the TRUE next tokens as 'drafts': its per-position
    samples must reproduce k sequential greedy decode steps, and its
    masked scatter must leave the pool able to continue decoding."""
    model = build_model(cfg, None)
    params = model.init(jax.random.PRNGKey(0))
    cache, prompt, table, t0 = _paged_state(cfg, model, params)
    S, k = len(prompt), 4
    # sequential oracle: 4 decode steps
    seq_cache = {n: v for n, v in cache.items()}
    toks, cur = [], t0
    for i in range(k):
        b = {"tokens": jnp.full((1, 1), cur, jnp.int32),
             "index": jnp.full((1,), S + i, jnp.int32),
             "block_table": jnp.asarray(table),
             **sampling_leaves(SamplingParams(), 1)}
        t, _, seq_cache = model.decode_sampled(params, seq_cache, b,
                                               backend="xla")
        cur = int(np.asarray(t)[0])
        toks.append(cur)
    # one verify: tokens [t0, toks[0], toks[1], toks[2]] at offset S
    vt = np.asarray([[t0] + toks[:k - 1]], np.int32)
    vb = {"tokens": jnp.asarray(vt),
          "offset": jnp.full((1,), S, jnp.int32),
          "length": jnp.full((1,), S + k, jnp.int32),
          "n_valid": jnp.full((1,), k, jnp.int32),
          "block_table": jnp.asarray(table),
          **sampling_leaves(SamplingParams(), 1)}
    vtoks, _, vcache = model.decode_verify(params, cache, vb,
                                           backend="xla")
    np.testing.assert_array_equal(np.asarray(vtoks)[0], toks)
    # the scatter wrote the fed tokens' KV: decoding ON from the verify
    # cache must agree with decoding on from the sequential cache
    nxt = {"tokens": jnp.full((1, 1), toks[-1], jnp.int32),
           "index": jnp.full((1,), S + k, jnp.int32),
           "block_table": jnp.asarray(table),
           **sampling_leaves(SamplingParams(), 1)}
    a, _, _ = model.decode_sampled(params, vcache, nxt, backend="xla")
    b, _, _ = model.decode_sampled(params, seq_cache, nxt, backend="xla")
    assert int(np.asarray(a)[0]) == int(np.asarray(b)[0])


def test_decode_draft_chain_matches_manual_chain(cfg):
    """The fused fori_loop chain == n_steps manual decode+sample calls,
    including the traced (non-recompiling) n_steps bound."""
    dcfg = spec_lib.draft_model_config(cfg)
    model = build_model(dcfg, None)
    target = build_model(cfg, None)
    params = spec_lib.share_draft_params(
        target.init(jax.random.PRNGKey(0)), dcfg.num_layers)
    B, S, k = 2, 9, 4
    cache = model.init_cache(B, 32)
    rng = np.random.default_rng(3)
    t0 = rng.integers(1, cfg.vocab_size, size=(B, 1)).astype(np.int32)
    # manual chain
    mcache = {n: v for n, v in cache.items()}
    cur, manual = jnp.asarray(t0), []
    from repro.models import sampling as sampling_lib
    sl = sampling_leaves(SamplingParams(), B)
    for i in range(k):
        logits, mcache = model.decode(params, mcache,
                                      {"tokens": cur,
                                       "index": jnp.full((B,), S + i,
                                                         jnp.int32)},
                                      backend="xla")
        t, _ = sampling_lib.sample_tokens(
            logits[:, -1, :], sl["temperature"], sl["top_k"], sl["top_p"],
            sl["seed"], jnp.full((B,), S + i + 1, jnp.int32))
        manual.append(np.asarray(t))
        cur = t[:, None].astype(jnp.int32)
    fused = jax.jit(lambda p, c, b: model.decode_draft(p, c, b,
                                                       backend="xla",
                                                       max_steps=k))
    for n in (k, 2):                  # full chain AND a shrunk k
        drafts, _, _ = fused(params, {m: v for m, v in cache.items()},
                             {"tokens": jnp.asarray(t0),
                              "index": jnp.full((B,), S, jnp.int32),
                              "n_steps": jnp.int32(n), **sl})
        drafts = np.asarray(drafts)
        for i in range(n):
            np.testing.assert_array_equal(drafts[:, i], manual[i])
        assert np.all(drafts[:, n:] == 0)      # untouched past n_steps


# --------------------------------------------------------- spec helpers

def test_acceptance_lengths_rule():
    drafts = np.array([[5, 6, 7],      # all match -> emit 4
                       [5, 0, 7],      # first mismatch at col 1 -> 2
                       [9, 6, 7],      # mismatch at col 0 -> 1
                       [5, 6, 7],      # n_valid=2: only col 0 counts
                       [1, 2, 3]])     # inactive row
    verify = np.array([[5, 6, 7, 8],
                       [5, 6, 7, 8],
                       [5, 6, 7, 8],
                       [5, 6, 7, 8],
                       [5, 6, 7, 8]])
    n_valid = np.array([4, 4, 4, 2, 0])
    assert spec_lib.acceptance_lengths(drafts, verify, n_valid) == \
        [4, 2, 1, 2, 0]


def test_zero_top_layers_exact_identity(cfg):
    """A zeroed layer is an exact residual identity: the zeroed-target
    logits equal the layer-truncated draft's BITWISE — the bench's
    near-1-acceptance configuration is exact, not approximate."""
    target = build_model(cfg, None)
    params = target.init(jax.random.PRNGKey(0))
    keep = 1
    zp = spec_lib.zero_top_layers(params, keep)
    dcfg = spec_lib.draft_model_config(cfg, num_layers=keep)
    draft = build_model(dcfg, None)
    dp = spec_lib.share_draft_params(zp, keep)
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    lt, _ = target.prefill(zp, {"tokens": toks})
    ld, _ = draft.prefill(dp, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(lt), np.asarray(ld))


def test_draft_model_config_validates(cfg):
    d = spec_lib.draft_model_config(cfg)
    assert d.num_layers == max(1, cfg.num_layers // 2)
    assert d.kv_cache_dtype == cfg.dtype      # dense scratch is lossless
    with pytest.raises(ValueError):
        spec_lib.draft_model_config(cfg, num_layers=cfg.num_layers + 1)
    with pytest.raises(ValueError):
        spec_lib.draft_model_config(cfg, num_layers=0)


def test_latency_policy_draft_len_hook():
    pol = LatencyAwarePolicy(queue_depth_hi=8)
    idle = LoadSignals(queue_depth=0, active_slots=2, free_kv_frac=0.9)
    mid = LoadSignals(queue_depth=4, active_slots=2, free_kv_frac=0.9)
    hot = LoadSignals(queue_depth=9, active_slots=2, free_kv_frac=0.9)
    assert pol.draft_len(idle, 4) == 4
    assert pol.draft_len(mid, 4) == 2         # half under queue build-up
    assert pol.draft_len(hot, 4) == 0         # pressured: spec off


# ------------------------------------------------------- engine matrix

def test_spec_engine_requires_paged(cfg):
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(cfg, spec_decode=True, max_slots=2,
                                 max_seq=64)


def test_spec_byte_identity_host(cfg, served):
    params, prompts, base = served
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, **ENGINE_KW)
    reqs = _reqs(prompts)
    _assert_identical(eng.run(reqs), reqs, base)
    st = eng.spec_stats()
    assert st["spec_rounds"] > 0
    # spec rounds emit most tokens; the rest ride the plain-decode
    # fallback (pool-short fan-out shrink)
    total = sum(len(b) for b in base)
    assert 0 < st["spec_emitted_tokens"] <= total
    assert 0.0 <= st["spec_acceptance_rate"] <= 1.0


def test_spec_byte_identity_accel(cfg, served):
    params, prompts, base = served
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, policy=PinAccel(),
                                   **ENGINE_KW)
    reqs = _reqs(prompts)
    _assert_identical(eng.run(reqs), reqs, base)
    assert eng.spec_stats()["spec_rounds"] > 0


class _SplitPolicy:
    """Draft on HOST, verify on ACCEL, everything else HOST — the
    headline heterogeneous split, scripted."""
    name = "split_draft_verify"

    def decide(self, signals, row, residency):
        if row.app.endswith("_verify") and residency.resident:
            return Decision(TargetKind.ACCEL)
        return Decision(TargetKind.HOST)


def test_spec_draft_host_verify_accel(cfg, served):
    """Byte-identity with the draft chain and verify dispatched to
    DIFFERENT targets, and the runtime's per-function accounting sees
    both as distinct binaries."""
    params, prompts, base = served
    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, runtime=rt,
                                   fn_prefix="sp", **ENGINE_KW)
    rt.server.policy = _SplitPolicy()
    reqs = _reqs(prompts)
    _assert_identical(eng.run(reqs), reqs, base)
    pf = rt.summary()["per_function"]
    assert pf["sp_draft"]["calls"].get("host", 0) > 0
    assert pf["sp_draft"]["calls"].get("accel", 0) == 0
    assert pf["sp_verify"]["calls"].get("accel", 0) > 0
    assert pf["sp_verify"]["calls"].get("host", 0) == 0
    # one spec round = one draft dispatch + one verify dispatch
    assert (pf["sp_draft"]["calls"]["host"]
            == pf["sp_verify"]["calls"]["accel"]
            == eng.stats["spec_rounds"])


class _FlipVerify:
    """Verify HOST -> ACCEL -> HOST mid-stream; draft stays HOST."""
    name = "flip_verify"

    def __init__(self, at=(3, 8)):
        self.at, self.n = at, 0

    def decide(self, signals, row, residency):
        if row.app.endswith("_verify"):
            self.n += 1
            if self.at[0] < self.n <= self.at[1] and residency.resident:
                return Decision(TargetKind.ACCEL)
        return Decision(TargetKind.HOST)


def test_spec_forced_midstream_migration(cfg, served):
    params, prompts, base = served
    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, runtime=rt,
                                   fn_prefix="fv", **ENGINE_KW)
    rt.server.policy = _FlipVerify()
    reqs = _reqs(prompts)
    _assert_identical(eng.run(reqs), reqs, base)
    vf = rt.summary()["per_function"]["fv_verify"]
    assert vf["calls"].get("accel", 0) == 5
    assert vf["migrations"] >= 2      # HOST->ACCEL and ACCEL->HOST


def test_spec_preempt_resume_starved_pool(cfg, served):
    """A pool too small for all slots forces preempt/resume mid-stream
    and exercises the fan-out-shrink + plain-decode fallback; output is
    still byte-identical."""
    params, prompts, base = served
    kw = dict(ENGINE_KW, num_blocks=9)
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, **kw)
    reqs = _reqs(prompts)
    _assert_identical(eng.run(reqs), reqs, base)
    assert eng.spec_stats()["spec_rounds"] > 0


def test_spec_with_prefix_cache(cfg, served):
    """Spec rounds write RANGES of blocks — the COW defense and the
    accepted-only block registration must keep two cache-sharing waves
    byte-identical to the uncached baseline."""
    params, prompts, base = served
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, prefix_cache=True,
                                   **ENGINE_KW)
    for wave in range(2):             # second wave hits the prefix cache
        reqs = _reqs(prompts)
        _assert_identical(eng.run(reqs), reqs, base)
    assert eng.prefix_stats()["prefix_hit_tokens"] > 0


def test_spec_int8_pool_greedy_identity(cfg, served):
    """int8 target pool: verify routes through the dequantising kernel
    wrapper; spec-on must match spec-off on the SAME lossy pool."""
    params, prompts, _ = served
    c8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    r0 = _reqs(prompts)
    out0 = ContinuousBatchingEngine(c8, params=params,
                                    **ENGINE_KW).run(r0)
    eng = ContinuousBatchingEngine(c8, params=params, spec_decode=True,
                                   spec_draft_len=4, **ENGINE_KW)
    r1 = _reqs(prompts)
    _assert_identical(eng.run(r1), r1,
                      [out0[r.req_id].tokens for r in r0])
    assert eng.spec is not None
    assert eng.spec.cfg.kv_cache_dtype == "float32"   # draft scratch


def test_spec_sampled_deterministic(cfg, served):
    """Seeded sampling: spec-on output is bitwise reproducible across
    fresh engines for a fixed spec configuration (every comparand
    commits verify's draws under the same positional keys)."""
    params, prompts, _ = served
    sp = SamplingParams(temperature=0.8, top_k=40, seed=7)
    outs = []
    for _ in range(2):
        eng = ContinuousBatchingEngine(cfg, params=params,
                                       spec_decode=True,
                                       spec_draft_len=4, **ENGINE_KW)
        reqs = _reqs(prompts, sampling=sp)
        out = eng.run(reqs)
        outs.append([out[r.req_id].tokens for r in reqs])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_spec_zeroed_target_full_acceptance(cfg, served):
    """zero_top_layers makes draft == target exactly, so acceptance hits
    1.0 and every round emits its full width — the mechanism behind the
    benchmark's speedup floor."""
    params, prompts, _ = served
    zp = spec_lib.zero_top_layers(params, 1)
    r0 = _reqs(prompts)
    out0 = ContinuousBatchingEngine(cfg, params=zp, **ENGINE_KW).run(r0)
    eng = ContinuousBatchingEngine(cfg, params=zp, spec_decode=True,
                                   spec_draft_len=4, spec_draft_layers=1,
                                   **ENGINE_KW)
    r1 = _reqs(prompts)
    _assert_identical(eng.run(r1), r1,
                      [out0[r.req_id].tokens for r in r0])
    st = eng.spec_stats()
    assert st["spec_acceptance_rate"] == 1.0
    # k tokens per 2 dispatches: far fewer rounds than tokens
    assert st["spec_rounds"] * 2 < st["spec_emitted_tokens"]


class _FixedDraftLen:
    """Policy scripting the draft_len hook through the runtime."""
    name = "fixed_k"

    def __init__(self, k):
        self.k = k

    def decide(self, signals, row, residency):
        return Decision(TargetKind.HOST)

    def draft_len(self, signals, default=4):
        return self.k


def test_spec_policy_draft_len_zero_disables(cfg, served):
    params, prompts, base = served
    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, runtime=rt,
                                   fn_prefix="k0", **ENGINE_KW)
    rt.server.policy = _FixedDraftLen(0)
    reqs = _reqs(prompts)
    _assert_identical(eng.run(reqs), reqs, base)
    assert eng.stats["spec_rounds"] == 0      # every step fell back
    assert eng.stats["decode_steps"] > 0


def test_spec_policy_draft_len_shrinks_width(cfg, served):
    """k=2 from the policy, verify width compiled at 4: the shrink is
    per-row data (n_valid), so at most 1 drafted token per row rides
    each round."""
    params, prompts, _ = served
    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")
    eng = ContinuousBatchingEngine(cfg, params=params, spec_decode=True,
                                   spec_draft_len=4, runtime=rt,
                                   fn_prefix="k2", **ENGINE_KW)
    rt.server.policy = _FixedDraftLen(2)
    reqs = _reqs(prompts)
    eng.run(reqs)
    st = eng.spec_stats()
    assert st["spec_rounds"] > 0
    # <= 1 proposed draft per row per round under k=2
    assert st["spec_proposed_tokens"] <= st["spec_rounds"] * len(prompts)


# -------------------------------------- satellite: stall-feedback loop

def test_prefill_budget_contracts_on_stall():
    pol = LatencyAwarePolicy(prefill_tokens_per_step=64,
                             stall_target_ms=50.0)
    calm = LoadSignals(queue_depth=0, active_slots=2, free_kv_frac=0.9,
                       decode_stall_ms=10.0)
    hot = LoadSignals(queue_depth=0, active_slots=2, free_kv_frac=0.9,
                      decode_stall_ms=200.0)
    assert pol.prefill_budget(calm, None) == 64
    assert pol.prefill_budget(hot, None) == 16       # 64 * 50/200
    worse = dataclasses.replace(hot, decode_stall_ms=100000.0)
    assert pol.prefill_budget(worse, None) == 1      # floored, never 0


def test_engine_stall_ewma_feeds_budget(cfg, served):
    """Regression for the feedback loop end to end: the engine's stall
    EWMA reaches the policy through LoadSignals.decode_stall_ms, the
    budget contracts while stalled, and idle steps decay the signal so
    the budget recovers."""
    params, _, _ = served
    rt = XarTrekRuntime(registry=FunctionRegistry(),
                        policy=LatencyAwarePolicy(
                            prefill_tokens_per_step=64,
                            stall_target_ms=50.0))
    eng = ContinuousBatchingEngine(cfg, params=params, runtime=rt,
                                   fn_prefix="st", **ENGINE_KW)
    assert eng.signals().decode_stall_ms is None     # no stall yet
    real = eng.signals       # budget only applies with decodes in flight
    eng.signals = lambda: dataclasses.replace(real(), active_slots=2)
    eng._stall_ewma = 200.0                          # induced stall
    assert eng.signals().decode_stall_ms == 200.0
    assert eng._prefill_budget() == 16               # contracted
    for _ in range(60):      # idle iterations: no pending chunk work
        eng._advance_prefills(None)
    assert eng.signals().decode_stall_ms < 50.0      # decayed
    assert eng._prefill_budget() == 64               # recovered


# ------------------------------------ satellite: span-rehydrate scatter

def test_scatter_span_one_compile_and_identical(cfg, served):
    """Span rehydration compiles ONCE for every span size (the old
    per-block-count _scatter specialized per size) and the rehydrated
    engine's tokens stay byte-identical to local serving."""
    params, prompts, base = served
    pre = ContinuousBatchingEngine(cfg, params=params, **ENGINE_KW)
    dec = ContinuousBatchingEngine(cfg, params=params, **ENGINE_KW)
    # prompts span 1 and 2 block spans (block_size 16, len 4..19)
    reqs = _reqs(prompts)
    for r in reqs:
        dec.submit_span(r, pre.prefill_to_span(r))
    out = dec.run()
    _assert_identical(out, reqs, base)
    assert dec.stats["spans_admitted"] == len(reqs)
    sizes = {len(np.asarray(p)) // dec.block_size for p in prompts}
    assert len(sizes) > 1             # the sweep really varied span size
    assert dec._scatter_span._cache_size() == 1
