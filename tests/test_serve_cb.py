"""Continuous-batching serve engine: token equivalence with the
synchronous engine, slot reuse, ragged arrivals through the Xar-Trek
runtime, and the shape-bucketed binary cache."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.serve import (ContinuousBatchingEngine, GenerationRequest,
                         RequestQueue, ServeEngine, poisson_arrivals,
                         prompt_bucket)

def _serve(engine, reqs=()):
    """v2 run() flattened to the old {req_id: token-array} shape."""
    return {rid: out.tokens for rid, out in engine.run(reqs).items()}



@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32")


@pytest.fixture(scope="module")
def sync_engine(cfg):
    return ServeEngine(cfg, seed=0)


def _prompts(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)


# ------------------------------------------------------------- equivalence

def test_cb_tokens_match_sync_engine(cfg, sync_engine):
    """Byte-identical greedy tokens on the same prompts and weights."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(prompts, max_new_tokens=6).tokens
    cb = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                  params=sync_engine.params)
    got = cb.generate(np.asarray(prompts), max_new_tokens=6)
    np.testing.assert_array_equal(want, got)


def test_cb_tokens_match_sync_when_slots_fewer_than_requests(cfg, sync_engine):
    """Two waves through 2 slots still reproduce the 4-row sync batch
    (slot state fully resets between occupants)."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(prompts, max_new_tokens=5).tokens
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    got = cb.generate(np.asarray(prompts), max_new_tokens=5)
    np.testing.assert_array_equal(want, got)


# ---------------------------------------------------------------- slotting

def test_slot_reuse_after_eviction(cfg, sync_engine):
    rng = np.random.RandomState(1)
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    reqs = [GenerationRequest(rng.randint(0, cfg.vocab_size, size=8),
                    max_new_tokens=n) for n in (3, 1, 4, 2, 3)]
    out = _serve(cb, reqs)
    assert sorted(out) == sorted(r.req_id for r in reqs)
    for r in reqs:
        assert out[r.req_id].shape == (r.max_new_tokens,)
    st = cb.slots.stats
    assert st["admitted"] == 5 and st["released"] == 5
    assert st["peak_active"] <= 2
    assert sum(cb.slots.slot_uses) == 5
    assert max(cb.slots.slot_uses) >= 3          # some row was reused
    assert not cb.slots.active                   # everything evicted


def test_overlong_request_rejected_at_submission(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=16,
                                  params=sync_engine.params)
    with pytest.raises(ValueError, match="positions"):
        cb.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=20)
    with pytest.raises(ValueError, match="positions"):
        _serve(cb, [GenerationRequest(np.arange(1, 9, dtype=np.int32),
                          max_new_tokens=20)])
    # the engine stays usable after a rejection
    out = cb.generate(np.arange(1, 9, dtype=np.int32)[None, :],
                      max_new_tokens=2)
    assert out.shape == (1, 2)


def test_bucket_overhanging_cache_row_is_clamped(cfg, sync_engine):
    """max_seq=12 (not a power of two): a 9-token prompt prefills in a
    16-wide bucket that overhangs the cache row; the write is clamped
    and tokens still match the sync engine."""
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=12,
                                  params=sync_engine.params)
    prompt = np.arange(1, 10, dtype=np.int32)[None, :]
    got = cb.generate(prompt, max_new_tokens=3)
    want = sync_engine.generate(jnp.asarray(prompt), max_new_tokens=3).tokens
    np.testing.assert_array_equal(want, got)


def test_serve_drains_results_per_call(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                  params=sync_engine.params)
    first = _serve(cb, [GenerationRequest(np.arange(1, 6, dtype=np.int32),
                              max_new_tokens=2)])
    second = _serve(cb, [GenerationRequest(np.arange(1, 6, dtype=np.int32),
                               max_new_tokens=2)])
    assert len(first) == 1 and len(second) == 1
    assert set(first) != set(second)       # no all-time accumulation
    assert not cb.results


def test_cb_rejects_position_synchronised_families():
    ssm_cfg = reduced(ARCHS["mamba2-2.7b"])
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(ssm_cfg, max_slots=2, max_seq=32)


# ----------------------------------------------------------- runtime path

def test_ragged_arrivals_through_runtime(cfg):
    rt = XarTrekRuntime(registry=FunctionRegistry(),
                        min_reconfig_seconds=0.0)
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  runtime=rt, seed=0)
    rng = np.random.RandomState(0)
    reqs = [GenerationRequest(rng.randint(0, cfg.vocab_size,
                                size=int(rng.randint(4, 20))),
                    max_new_tokens=int(rng.randint(1, 6)),
                    arrival_s=0.005 * i)
            for i in range(6)]
    out = _serve(cb, reqs)
    assert len(out) == len(reqs)
    assert rt.call_log, "no step went through the runtime"
    # every executed target is a declared variant of the called function
    for rec in rt.call_log:
        fn = rt.registry.get(rec["fn"])
        assert TargetKind(rec["target"]) in fn.variants, rec
    per_fn = {rec["fn"] for rec in rt.call_log}
    assert per_fn == {"cb_prefill", "cb_decode"}


def test_runtime_tokens_match_no_runtime(cfg, sync_engine):
    """Dispatching through XarTrekRuntime must not change the math."""
    prompts = _prompts(cfg, B=3, S=10)
    want = sync_engine.generate(prompts, max_new_tokens=4).tokens
    rt = XarTrekRuntime(registry=FunctionRegistry(),
                        min_reconfig_seconds=0.0)
    cb = ContinuousBatchingEngine(cfg, max_slots=3, max_seq=64,
                                  runtime=rt, params=sync_engine.params)
    got = cb.generate(np.asarray(prompts), max_new_tokens=4)
    np.testing.assert_array_equal(want, got)


def test_prefill_shape_buckets_cached(cfg, sync_engine):
    """Different prompt lengths hit different prefill buckets; repeats
    reuse the LRU'd compile instead of recompiling."""
    rt = XarTrekRuntime(registry=FunctionRegistry(),
                        min_reconfig_seconds=0.0)
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  runtime=rt, params=sync_engine.params,
                                  min_bucket=8)
    rng = np.random.RandomState(2)
    for S in (4, 12, 20, 12, 4):         # buckets 8, 16, 32, 16, 8
        cb.submit(rng.randint(0, cfg.vocab_size, size=S), max_new_tokens=1)
    _serve(cb)
    stats = rt.binaries["cb_prefill"].shape_stats
    # bucket 8 matches the prepare()-time default; 16 and 32 are bucket
    # compiles, re-used on repeat
    assert stats["misses"] == 2
    assert stats["hits"] >= 1
    assert stats["evictions"] == 0


# ----------------------------------------------------- ACCEL / migration

def test_accel_backend_tokens_match_host(cfg, sync_engine):
    """Direct (no-runtime) engines: every step on the Pallas kernels must
    reproduce the XLA engine's greedy tokens byte-for-byte — dense ragged
    decode and paged (in-kernel block streaming) alike."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(prompts, max_new_tokens=5).tokens
    for kw in ({}, {"paged": True, "block_size": 16}):
        accel = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                         params=sync_engine.params,
                                         backend="accel", **kw)
        got = accel.generate(np.asarray(prompts), max_new_tokens=5)
        np.testing.assert_array_equal(want, got, err_msg=str(kw))


def test_forced_midstream_migration_is_byte_identical(cfg, sync_engine):
    """HOST -> ACCEL -> HOST forced mid-stream (policy flips while slots
    are live): a real kernel swap under generation must keep greedy
    tokens byte-identical to the no-migration run, and the summary must
    prove both backends actually served decode steps."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(prompts, max_new_tokens=6).tokens

    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")

    def flip(engine):
        s = engine.stats["decode_steps"]
        if s == 1:
            rt.server.policy = "always_accel"
        elif s == 3:
            rt.server.policy = "always_host"

    mig = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                   params=sync_engine.params, runtime=rt,
                                   paged=True, block_size=16, on_step=flip)
    got = mig.generate(np.asarray(prompts), max_new_tokens=6)
    np.testing.assert_array_equal(want, got)

    summary = rt.summary()
    decode = summary["per_function"]["cb_decode"]
    assert decode["calls"].get("host", 0) >= 1
    assert decode["calls"].get("accel", 0) >= 1
    assert decode["migrations"] >= 2            # there AND back
    # distinct builds: both targets were compiled (eagerly, at prepare)
    assert decode["compiles"]["host"]["compiles"] >= 1
    assert decode["compiles"]["accel"]["compiles"] >= 1


def test_eager_accel_compiles_before_first_call(cfg, sync_engine):
    """prepare() must leave the ACCEL build bank-resident so the first
    migration never pays compile time inside the timed region."""
    rt = XarTrekRuntime(registry=FunctionRegistry())
    ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                             params=sync_engine.params, runtime=rt,
                             fn_prefix="eag")
    assert rt.bank.is_resident("eag_decode")
    assert rt.bank.is_resident("eag_prefill")
    from repro.core.targets import TargetKind as TK
    assert rt.binaries["eag_decode"].is_compiled(TK.ACCEL)
    assert not rt.call_log                      # compiles, not calls


# ------------------------------------------------------- queue + buckets

def test_request_queue_orders_by_arrival_then_fifo():
    q = RequestQueue()
    a = GenerationRequest(np.array([1]), arrival_s=0.5)
    b = GenerationRequest(np.array([2]), arrival_s=0.0)
    c = GenerationRequest(np.array([3]), arrival_s=0.0)
    for r in (a, b, c):
        q.submit(r)
    assert q.pop_arrived(now=0.1) is b         # earliest arrival wins
    assert q.pop_arrived(now=0.1) is c         # FIFO among equal arrivals
    assert q.pop_arrived(now=0.1) is None      # a not arrived yet
    assert q.next_arrival() == 0.5
    assert q.pop_arrived(now=1.0) is a
    assert len(q) == 0


def test_poisson_arrivals_monotone_and_rate():
    times = poisson_arrivals(2000, rate_per_s=10.0, rng=0)
    assert all(b > a for a, b in zip(times, times[1:]))
    mean_gap = times[-1] / len(times)
    assert 0.08 < mean_gap < 0.12              # ~1/rate


def test_prompt_bucket_powers_of_two():
    assert prompt_bucket(1) == 8
    assert prompt_bucket(8) == 8
    assert prompt_bucket(9) == 16
    assert prompt_bucket(33) == 64
