"""XarTrekRuntime end-to-end on real jitted functions + migration ABI."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.function import FunctionRegistry, MigratableFunction
from repro.core.migration import AbiMismatch, check_abi, migrate, migration_bytes
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import TargetKind
from repro.kernels import ops, ref


def _host_knn(test, train, labels):
    d = ref.hamming_ref(test, train)
    _, idx = jax.lax.top_k(-d, 3)
    votes = labels[idx]
    counts = jax.vmap(lambda v: jnp.bincount(v, length=10))(votes)
    return jnp.argmax(counts, -1).astype(jnp.int32)


def _accel_knn(test, train, labels):
    return ops.knn_digits(test, train, labels)


def _data(key):
    test = jax.random.randint(key, (16, 7), 0, 2**31 - 1,
                              jnp.int32).astype(jnp.uint32)
    train = jax.random.randint(key, (128, 7), 0, 2**31 - 1,
                               jnp.int32).astype(jnp.uint32)
    labels = jax.random.randint(key, (128,), 0, 10, jnp.int32)
    return test, train, labels


def _registry():
    reg = FunctionRegistry()
    reg.register(MigratableFunction(
        "knn_digits", "digitrec",
        {TargetKind.HOST: _host_knn, TargetKind.ACCEL: _accel_knn}))
    return reg


def test_runtime_latency_hiding_then_accel(key):
    rt = XarTrekRuntime(registry=_registry(), min_reconfig_seconds=0.4)
    args = _data(key)
    rt.prepare("knn_digits", *args,
               table_row={"fpga_thr": -0.5, "arm_thr": 10.0})
    out1 = rt.call("knn_digits", *args)
    assert rt.call_log[-1]["target"] == "host"    # bank cold: stay on host
    deadline = time.time() + 5.0
    while not rt.bank.is_resident("knn_digits") and time.time() < deadline:
        time.sleep(0.05)
    out2 = rt.call("knn_digits", *args)
    assert rt.call_log[-1]["target"] == "accel"   # bank hot: migrate
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_runtime_threshold_adaptation_drains_slow_target(key):
    """Algorithm 1 at work on real timings: if ACCEL turns out slower than
    HOST, its threshold rises and calls drain back to HOST."""
    reg = FunctionRegistry()

    def slow_accel(test, train, labels):
        out = _host_knn(test, train, labels)
        # artificial extra work (the 'FPGA-hostile' case, CG-A-style);
        # the accumulator feeds the output through a runtime-zero term so
        # XLA cannot dead-code-eliminate it
        acc = jnp.int32(0)
        for i in range(25):
            acc = acc + jnp.sum(ref.hamming_ref(test, train ^ jnp.uint32(i + 1)))
        return out + jnp.minimum(acc, 0).astype(jnp.int32)

    reg.register(MigratableFunction(
        "knn2", "digitrec2",
        {TargetKind.HOST: _host_knn, TargetKind.ACCEL: slow_accel}))
    rt = XarTrekRuntime(registry=reg, min_reconfig_seconds=0.0)
    args = _data(key)
    # seed x86_exec as the estimation step would (Table-1 measurement);
    # without it Algorithm 1 has nothing to compare ACCEL against
    host_jit = jax.jit(_host_knn)
    jax.block_until_ready(host_jit(*args))
    t0 = time.perf_counter()
    jax.block_until_ready(host_jit(*args))
    host_ms = (time.perf_counter() - t0) * 1e3
    rt.prepare("knn2", *args, table_row={"fpga_thr": -0.5, "arm_thr": 1e9,
                                         "x86_exec": host_ms})
    rt.bank.load_sync("knn2")
    targets = []
    for _ in range(8):
        rt.call("knn2", *args)
        targets.append(rt.call_log[-1]["target"])
    assert targets[0] == "accel"
    assert targets[-1] == "host", f"threshold never adapted: {targets}"


def test_runtime_abi_check_rejects_mismatch(key):
    reg = FunctionRegistry()

    def bad_accel(test, train, labels):
        return _host_knn(test, train, labels).astype(jnp.float32)  # dtype drift

    fn = MigratableFunction(
        "knn3", "digitrec3",
        {TargetKind.HOST: _host_knn, TargetKind.ACCEL: bad_accel})
    reg.register(fn)
    rt = XarTrekRuntime(registry=reg)
    with pytest.raises(ValueError, match="ABI mismatch"):
        rt.prepare("knn3", *_data(key))


def test_migrate_resharding_roundtrip(key):
    state = {"w": jax.random.normal(key, (8, 4)),
             "opt": {"m": jnp.zeros((8, 4))}}
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), state)
    out, seconds = migrate(state, shardings, measure=True)
    assert seconds >= 0
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(state["w"]))
    assert migration_bytes(state) == (8 * 4 * 4) * 2


def test_migrate_abi_mismatch_raises(key):
    state = {"w": jnp.zeros((4,))}
    bad = {"w2": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
    with pytest.raises(AbiMismatch):
        check_abi(state, bad)
