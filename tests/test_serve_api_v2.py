"""Serve API v2: SamplingParams / GenerationRequest / RequestOutput,
streaming handles, abort, in-graph per-request sampling determinism
(HOST vs ACCEL, forced mid-stream migration, preempt/resume), the
single static decode compile signature, the lane-aligned paged pool,
and the removed v1 surface."""
import dataclasses
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.runtime import XarTrekRuntime
from repro.serve import (
    ContinuousBatchingEngine, GenerationRequest, RequestOutput,
    SamplingParams, ServeEngine,
)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32")


@pytest.fixture(scope="module")
def sync_engine(cfg):
    return ServeEngine(cfg, seed=0)


def _prompts(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    return np.asarray(jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                         jnp.int32))


SAMPLED = SamplingParams(temperature=0.9, top_k=50, top_p=0.95, seed=7)


# ------------------------------------------------------------------ types

def test_sampling_params_validation():
    SamplingParams()                                 # greedy default
    SamplingParams(temperature=1.5, top_k=40, top_p=0.9, seed=3)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_request_output_validates_finish_reason():
    out = RequestOutput(req_id=1, tokens=[1, 2], finish_reason="stop")
    assert out.n_tokens == 2 and out.tokens.dtype == np.int32
    with pytest.raises(ValueError, match="finish_reason"):
        RequestOutput(req_id=1, tokens=[1], finish_reason="eof")


# ------------------------------------------------- submit() field routing

def test_submit_routes_all_request_fields(cfg, sync_engine):
    """Regression: the v1 submit() dropped stop_tokens on the floor.
    Every field — stop budget, arrival, sampling — must route through."""
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    prompt = np.arange(1, 9, dtype=np.int32)
    # find the greedy second token, then stop on it
    ref = cb.run([GenerationRequest(prompt, max_new_tokens=5)])
    stop_tok = int(next(iter(ref.values())).tokens[1])

    sp = SamplingParams(temperature=0.0, seed=9)
    h = cb.submit(prompt, max_new_tokens=5, arrival_s=0.01,
                  stop_tokens=(stop_tok,), sampling=sp)
    req = h.request
    assert req.stop_tokens == (stop_tok,)
    assert req.max_new_tokens == 5
    assert req.arrival_s == 0.01
    assert req.sampling is sp
    out = cb.run()[h.req_id]
    assert out.finish_reason == "stop"
    assert out.n_tokens == 2 and int(out.tokens[-1]) == stop_tok


def test_submit_accepts_generation_request(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    req = GenerationRequest(np.arange(1, 6, dtype=np.int32),
                            max_new_tokens=2)
    h = cb.submit(req)
    assert h.request is req
    out = cb.run()
    assert out[req.req_id].finish_reason == "length"


# --------------------------------------------------- greedy back-compat

def test_temperature_zero_matches_greedy_sync_engine(cfg, sync_engine):
    """temperature=0.0 must be byte-identical to the pre-v2 greedy
    engines (argmax over raw logits, sampled path bypassed)."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(jnp.asarray(prompts),
                                max_new_tokens=6).tokens
    cb = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                  params=sync_engine.params)
    got = cb.generate(prompts, max_new_tokens=6,
                      sampling=SamplingParams(temperature=0.0, seed=42))
    np.testing.assert_array_equal(want, got)


# --------------------------------------------------- seeded determinism

def test_sampled_deterministic_and_distinct_from_greedy(cfg, sync_engine):
    prompts = _prompts(cfg, B=4, S=12)
    cb = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                  params=sync_engine.params)
    a = cb.generate(prompts, max_new_tokens=6, sampling=SAMPLED)
    b = cb.generate(prompts, max_new_tokens=6, sampling=SAMPLED)
    np.testing.assert_array_equal(a, b)
    greedy = cb.generate(prompts, max_new_tokens=6)
    assert not np.array_equal(a, greedy)


def test_sampled_independent_of_batch_composition(cfg, sync_engine):
    """The PRNG key is fold_in(seed, absolute position) — slot index and
    neighbours must not change a request's tokens."""
    prompt = np.arange(1, 13, dtype=np.int32)
    sp = SamplingParams(temperature=0.8, top_k=30, seed=11)
    solo = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                    params=sync_engine.params)
    alone = solo.run([GenerationRequest(prompt, 6, sampling=sp)])
    want = next(iter(alone.values())).tokens
    crowd = [GenerationRequest(_prompts(cfg, 1, 9, seed=i)[0], 6,
                               sampling=SamplingParams(temperature=1.2,
                                                       seed=50 + i))
             for i in range(3)]
    target = GenerationRequest(prompt, 6, sampling=sp)
    out = solo.run(crowd + [target])
    np.testing.assert_array_equal(want, out[target.req_id].tokens)


def test_sampled_host_vs_accel_byte_identical(cfg, sync_engine):
    """Same seed => identical tokens on the XLA and Pallas builds, dense
    ragged and paged (in-kernel streaming) alike."""
    prompts = _prompts(cfg, B=4, S=12)
    host = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                    params=sync_engine.params,
                                    backend="host")
    want = host.generate(prompts, max_new_tokens=6, sampling=SAMPLED)
    for kw in ({}, {"paged": True, "block_size": 16}):
        accel = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                         params=sync_engine.params,
                                         backend="accel", **kw)
        got = accel.generate(prompts, max_new_tokens=6, sampling=SAMPLED)
        np.testing.assert_array_equal(want, got, err_msg=str(kw))


def test_sampled_midstream_migration_byte_identical(cfg, sync_engine):
    """Forced HOST -> ACCEL -> HOST while sampled requests are live:
    tokens must match the no-migration run, both backends must really
    serve decode steps, and decode must keep ONE static compile
    signature (no shape-bucket recompiles, one compile per target)."""
    prompts = _prompts(cfg, B=4, S=12)
    host = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                    params=sync_engine.params,
                                    backend="host", paged=True,
                                    block_size=16)
    want = host.generate(prompts, max_new_tokens=6, sampling=SAMPLED)

    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")

    def flip(engine):
        s = engine.stats["decode_steps"]
        if s == 1:
            rt.server.policy = "always_accel"
        elif s == 3:
            rt.server.policy = "always_host"

    mig = ContinuousBatchingEngine(cfg, max_slots=4, max_seq=64,
                                   params=sync_engine.params, runtime=rt,
                                   paged=True, block_size=16, on_step=flip,
                                   fn_prefix="smig")
    got = mig.generate(prompts, max_new_tokens=6, sampling=SAMPLED)
    np.testing.assert_array_equal(want, got)

    decode = rt.summary()["per_function"]["smig_decode"]
    assert decode["calls"].get("host", 0) >= 1
    assert decode["calls"].get("accel", 0) >= 1
    assert decode["migrations"] >= 2
    # one static signature: the eagerly-compiled default served every
    # step on both targets — no per-request recompiles
    binary = rt.binaries["smig_decode"]
    assert binary.shape_stats["misses"] == 0
    assert binary.compile_stats[list(binary.compile_stats)[0]]["compiles"] == 1
    for stats in binary.compile_stats.values():
        assert stats["compiles"] == 1


def test_sampled_preempt_resume_byte_identical(cfg, sync_engine):
    """A pool too small for two long sampled generations forces preempt +
    resume-by-recompute; the stashed-token replay plus position-keyed
    sampling keeps tokens byte-identical to the unpressured run."""
    rng = np.random.RandomState(3)
    p1 = rng.randint(0, cfg.vocab_size, size=4)
    p2 = rng.randint(0, cfg.vocab_size, size=4)
    specs = [SamplingParams(temperature=0.8, top_k=30, seed=21),
             SamplingParams(temperature=1.1, top_p=0.9, seed=22)]
    mk = lambda: [GenerationRequest(p, 12, sampling=s)
                  for p, s in zip((p1, p2), specs)]
    roomy = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params,
                                     paged=True, block_size=4,
                                     fn_prefix="roomy")
    ra = mk()
    want = roomy.run(ra)
    assert roomy.slots.stats["preempted"] == 0
    small = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params,
                                     paged=True, block_size=4, num_blocks=6,
                                     fn_prefix="small")
    rb = mk()
    got = small.run(rb)
    assert small.slots.stats["preempted"] >= 1
    for a, b in zip(ra, rb):
        np.testing.assert_array_equal(want[a.req_id].tokens,
                                      got[b.req_id].tokens)
    assert small.slots.pool.blocks_in_use() == 0


# ------------------------------------------------------------- streaming

def test_streaming_iterator_and_callback(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    h1 = cb.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=5)
    seen = []
    h2 = cb.submit(np.arange(2, 9, dtype=np.int32), max_new_tokens=4,
                   on_token=seen.append)
    t = threading.Thread(target=cb.run)
    t.start()
    streamed = list(h1)              # blocks until end-of-stream
    t.join()
    out1 = h1.result(timeout=1.0)
    assert streamed == list(out1.tokens)
    assert out1.finish_reason == "length" and out1.n_tokens == 5
    assert seen == list(h2.result(timeout=1.0).tokens)
    assert h1.finished and h2.finished


def test_request_output_metrics(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    h = cb.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    out = cb.run()[h.req_id]
    assert out.queue_wait_s >= 0.0
    assert out.ttft_s >= out.queue_wait_s      # TTFT includes the prefill
    assert out.tpot_s > 0.0                    # 4 tokens -> 3 decode gaps


# ----------------------------------------------------------------- abort

def test_abort_midstream_frees_slot_and_blocks(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params,
                                  paged=True, block_size=16)
    box = {}

    def stopper(tok):
        if len(box["h"].tokens) >= 2:
            box["h"].abort()

    box["h"] = cb.submit(np.arange(1, 9, dtype=np.int32),
                         max_new_tokens=12, on_token=stopper)
    out = cb.run()[box["h"].req_id]
    assert out.finish_reason == "aborted"
    assert 2 <= out.n_tokens < 12              # cut well short of budget
    assert not cb.slots.active
    assert cb.slots.pool.blocks_in_use() == 0  # blocks freed mid-stream


def test_abort_preempted_request_finishes_aborted(cfg, sync_engine):
    """An abort landing while the target is preempted (requeued with a
    token stash, no active slot) must still finish it as aborted — and
    must not disturb the surviving request's tokens."""
    rng = np.random.RandomState(3)
    p1 = rng.randint(0, cfg.vocab_size, size=4)
    p2 = rng.randint(0, cfg.vocab_size, size=4)
    roomy = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params,
                                     paged=True, block_size=4,
                                     fn_prefix="ar")
    ra, rb = (GenerationRequest(p, 12) for p in (p1, p2))
    want = roomy.run([ra, rb])

    small = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params,
                                     paged=True, block_size=4, num_blocks=6,
                                     fn_prefix="as")
    sa, sb = (GenerationRequest(p, 12) for p in (p1, p2))
    state = {}

    def on_step(eng):
        # the instant a preemption stashes a request, abort THAT request
        if eng._resume and "aborted" not in state:
            rid = next(iter(eng._resume))
            state["aborted"] = rid
            state["stash_len"] = len(eng._resume[rid][0])
            assert eng.abort(rid)

    small.on_step = on_step
    got = small.run([sa, sb])
    assert "aborted" in state, "pool never forced a preemption"
    rid = state["aborted"]
    survivor, wsurv = ((sb, rb) if rid == sa.req_id else (sa, ra))
    assert got[rid].finish_reason == "aborted"
    assert got[rid].n_tokens == state["stash_len"]  # kept its stash
    # the survivor is unaffected and byte-identical to the roomy run
    np.testing.assert_array_equal(want[wsurv.req_id].tokens,
                                  got[survivor.req_id].tokens)
    assert small.slots.pool.blocks_in_use() == 0


def test_run_exception_unblocks_streaming_handles(cfg, sync_engine):
    """If run() raises (here: a request failing validation), unfinished
    handles finish as aborted instead of hanging their consumers."""
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=16,
                                  params=sync_engine.params)
    h = cb.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=2)
    bad = GenerationRequest(np.arange(1, 9, dtype=np.int32),
                            max_new_tokens=20)        # overlong for rows
    with pytest.raises(ValueError, match="positions"):
        cb.run([bad])
    assert h.finished
    assert h.result(timeout=1.0).finish_reason == "aborted"
    assert list(h) == []                              # iterator terminates


def test_abort_queued_request_and_unknown_id(cfg, sync_engine):
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    hq = cb.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=3,
                   arrival_s=30.0)              # never arrives in-test
    ha = cb.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=3)
    assert cb.abort(hq.req_id)
    assert not cb.abort(999999)                 # unknown
    out = cb.run()
    assert out[hq.req_id].finish_reason == "aborted"
    assert out[hq.req_id].n_tokens == 0
    assert out[ha.req_id].finish_reason == "length"
    assert not cb.abort(ha.req_id)              # already finished


# -------------------------------------------------- lane-aligned pool

def test_lane_aligned_pool_byte_identical(cfg, sync_engine):
    """Pool allocated with head_dim padded to the TPU lane width: greedy
    tokens stay byte-identical on both backends (writers zero-pad the
    per-token KV; readers slice the real head_dim back out)."""
    prompts = _prompts(cfg, B=4, S=12)
    want = sync_engine.generate(jnp.asarray(prompts),
                                max_new_tokens=5).tokens
    for backend in ("host", "accel"):
        eng = ContinuousBatchingEngine(
            cfg, max_slots=4, max_seq=64, params=sync_engine.params,
            paged=True, block_size=16, lane_align=True, backend=backend,
            fn_prefix=f"la_{backend}")
        assert eng.cache["k"].shape[-1] == 128   # hd 32 -> one lane tile
        got = eng.generate(prompts, max_new_tokens=5)
        np.testing.assert_array_equal(want, got, err_msg=backend)


def test_lane_align_default_off_in_interpret_mode(cfg, sync_engine):
    """CI (interpret mode) keeps the historical unpadded pool layout —
    no memory blow-up, no behaviour change."""
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                   params=sync_engine.params,
                                   paged=True, block_size=16,
                                   fn_prefix="noal")
    assert eng.cache["k"].shape[-1] == cfg.resolved_head_dim


# ------------------------------------------------------ deprecation shims

def test_v1_request_and_serve_are_removed(cfg, sync_engine):
    """The v1 shims are gone: both fail fast with a pointer at the v2
    replacement (not an ImportError far from the fix)."""
    from repro.serve.scheduler import Request

    with pytest.raises(TypeError, match="GenerationRequest"):
        Request(np.arange(1, 6, dtype=np.int32), 2)

    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    with pytest.raises(RuntimeError, match="run\\(\\)"):
        cb.serve([GenerationRequest(np.arange(1, 6, dtype=np.int32), 2)])
    # the engine stays usable after the failed call
    out = cb.run([GenerationRequest(np.arange(1, 6, dtype=np.int32), 2)])
    assert all(o.tokens.shape == (2,) for o in out.values())


# --------------------------------------------------------- logprobs opt-in

def test_logprobs_opt_in_surfaced_and_aligned(cfg, sync_engine):
    """SamplingParams(logprobs=True) returns per-token chosen-token
    logprobs aligned with tokens (greedy and sampled); without the
    opt-in the field is None — and enabling it changes neither the
    tokens nor the compile signature (same engine, same run)."""
    cb = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                  params=sync_engine.params)
    prompt = np.arange(1, 9, dtype=np.int32)
    plain = GenerationRequest(prompt, 5)
    withlp = GenerationRequest(prompt, 5,
                               sampling=SamplingParams(logprobs=True))
    sampled = GenerationRequest(prompt, 5,
                                sampling=SamplingParams(
                                    temperature=0.8, top_k=40, seed=7,
                                    logprobs=True))
    out = cb.run([plain, withlp, sampled])
    assert out[plain.req_id].logprobs is None
    for r in (withlp, sampled):
        o = out[r.req_id]
        assert o.logprobs is not None
        assert o.logprobs.shape == o.tokens.shape
        assert o.logprobs.dtype == np.float32
        assert (o.logprobs <= 0).all() and np.isfinite(o.logprobs).all()
    # logprobs opt-in never moves tokens (greedy == greedy)
    np.testing.assert_array_equal(out[plain.req_id].tokens,
                                  out[withlp.req_id].tokens)
    # greedy logprob is the argmax token's raw log-softmax mass: the
    # most likely token, so each step's logprob is the row maximum —
    # spot-check the first one against a direct forward pass
    logits, _ = jax.jit(sync_engine.model.prefill)(
        sync_engine.params, {"tokens": jnp.asarray(prompt)[None, :]})
    ref = jax.nn.log_softmax(logits[0, -1].astype(jnp.float32))
    tok0 = out[withlp.req_id].tokens[0]
    np.testing.assert_allclose(out[withlp.req_id].logprobs[0],
                               np.asarray(ref)[tok0], rtol=2e-5,
                               atol=2e-5)


def test_logprobs_identical_across_backends_and_preemption(cfg,
                                                           sync_engine):
    """Chosen-token logprobs are part of the determinism contract:
    byte-comparable HOST vs ACCEL, and preserved across a forced
    preempt/resume (the stash replays logprobs with the tokens)."""
    rng = np.random.RandomState(3)
    p1 = rng.randint(0, cfg.vocab_size, size=4)
    p2 = rng.randint(0, cfg.vocab_size, size=4)
    sp = SamplingParams(temperature=0.9, top_k=0, seed=11, logprobs=True)

    def serve(policy_kw, paged_kw):
        eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                       params=sync_engine.params,
                                       **policy_kw, **paged_kw)
        reqs = [GenerationRequest(p1, 12, sampling=sp),
                GenerationRequest(p2, 12, sampling=sp)]
        out = eng.run(reqs)
        return [out[r.req_id] for r in reqs], eng

    from repro.core.policy import PinAccel, PinHost
    host, _ = serve({"policy": PinHost()}, {})
    accel, _ = serve({"policy": PinAccel()},
                     {"paged": True, "block_size": 4})
    tight, eng = serve({}, {"paged": True, "block_size": 4,
                            "num_blocks": 6})
    assert eng.slots.stats["preempted"] >= 1, "pool never preempted"
    for a, b in zip(host, accel):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logprobs, b.logprobs,
                                   rtol=2e-5, atol=2e-5)
    for a, b in zip(host, tight):
        # tokens are exactly preemption-invariant; logprob VALUES are
        # only near-equal — resume rebuilds the KV via a batched
        # prefill whose float accumulation order differs from the
        # incremental decode path (argmax/Gumbel comparisons absorb
        # those last-bit differences, log-masses show them)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logprobs, b.logprobs,
                                   rtol=5e-3, atol=5e-3)
