"""Training loop, checkpointing (atomicity, GC, async), failure/restart,
optimizer behaviour, data-pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager, latest_step_dir,
                                      restore_checkpoint, save_checkpoint)
from repro.configs import ARCHS, reduced
from repro.configs.model_config import ShapeConfig, TrainConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models.model import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.compression import compress_int8_ef, decompress_int8
from repro.train.trainer import FailureInjector, SimulatedFailure, Trainer

CFG = reduced(ARCHS["smollm-135m"])
SHAPE = ShapeConfig("t", 64, 4, "train")


def test_loss_decreases():
    tr = Trainer(CFG, SHAPE, TrainConfig(learning_rate=3e-3), total_steps=40)
    log = tr.run(steps=40, log_every=0)
    assert log[-1]["loss"] < log[0]["loss"] - 0.05


def test_checkpoint_roundtrip(tmp_path, key):
    model = build_model(CFG, mesh=None)
    params = model.init(key)
    state = {"params": params, "x": jnp.arange(7)}
    save_checkpoint(str(tmp_path), 5, state, meta={"arch": CFG.name})
    restored, step, meta = restore_checkpoint(str(tmp_path), state)
    assert step == 5 and meta["arch"] == CFG.name
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(3) * s})
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step"))
    assert dirs == ["step_00000003", "step_00000004"]
    restored, step, _ = mgr.restore({"x": jnp.zeros(3)})
    assert step == 4 and float(restored["x"][0]) == 4.0


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_async=True)
    mgr.save(1, {"x": jnp.ones(4)})
    mgr.wait()
    assert mgr.has_checkpoint()


def test_checkpoint_crash_mid_save_is_atomic(tmp_path):
    """A stale tmp dir must not corrupt restore."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.ones(3)})
    os.makedirs(tmp_path / ".tmp_step_2_deadbeef")  # simulated crash litter
    restored, step, _ = mgr.restore({"x": jnp.zeros(3)})
    assert step == 1


def test_latest_file_lost_falls_back_to_scan(tmp_path):
    save_checkpoint(str(tmp_path), 7, {"x": jnp.ones(2)})
    os.remove(tmp_path / "LATEST")
    assert latest_step_dir(str(tmp_path)).endswith("step_00000007")


def test_failure_injection_and_restart(tmp_path):
    tr = Trainer(CFG, SHAPE, TrainConfig(learning_rate=1e-3),
                 ckpt_dir=str(tmp_path), ckpt_every=5, total_steps=12)
    log = tr.run(steps=12, injector=FailureInjector(fail_at_steps=(8,)),
                 log_every=0)
    steps = [m["step"] for m in log]
    assert steps[-1] == 12
    assert 8 in steps and steps.count(6) == 2   # re-ran 6,7 after restart


def test_failure_without_checkpointing_raises():
    tr = Trainer(CFG, SHAPE, TrainConfig(), total_steps=5)
    with pytest.raises(SimulatedFailure):
        tr.run(steps=5, injector=FailureInjector(fail_at_steps=(2,)),
               log_every=0)


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Fault tolerance invariant: a killed-and-restarted run converges to
    the same final loss as an uninterrupted one (same data stream)."""
    t1 = Trainer(CFG, SHAPE, TrainConfig(learning_rate=1e-3),
                 total_steps=10, seed=3)
    clean = t1.run(steps=10, log_every=0)
    t2 = Trainer(CFG, SHAPE, TrainConfig(learning_rate=1e-3),
                 ckpt_dir=str(tmp_path), ckpt_every=5, total_steps=10, seed=3)
    faulty = t2.run(steps=10, injector=FailureInjector(fail_at_steps=(7,)),
                    log_every=0)
    assert abs(clean[-1]["loss"] - faulty[-1]["loss"]) < 5e-2


# ---------------------------------------------------------------- optim

def test_adamw_moves_toward_minimum():
    opt = AdamW(TrainConfig(learning_rate=0.1, weight_decay=0.0))
    params = {"w": jnp.array([[5.0, -3.0]])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}      # d/dw of w^2
        params, state = opt.update(grads, state, params, jnp.float32(0.1))
        state.pop("gnorm", None)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_grad_clip_bounds_update():
    opt = AdamW(TrainConfig(learning_rate=1.0, grad_clip=1.0,
                            weight_decay=0.0))
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 1e6)}
    new_params, state = opt.update(g, state, params, jnp.float32(1.0))
    assert float(state["gnorm"]) > 1e5
    assert float(jnp.max(jnp.abs(new_params["w"]))) <= 1.1


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-4


def test_zero1_specs_shard_data_axis():
    model = build_model(ARCHS["yi-6b"], mesh=None)
    opt = AdamW(TrainConfig(zero1=True))
    specs = opt.state_specs(model.specs(), model.shapes(), dp_size=16)
    from jax.sharding import PartitionSpec
    leaves = jax.tree.leaves(specs["m"],
                             is_leaf=lambda x: isinstance(x, PartitionSpec))
    assert any("data" in str(s) for s in leaves)


def test_int8_error_feedback_converges():
    """Compression error with feedback is bounded; without feedback the
    bias accumulates."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)) * 1e-3)
    err = {"g": jnp.zeros((64,))}
    total = jnp.zeros((64,))
    for _ in range(50):
        q, scales, err_new = compress_int8_ef({"g": g_true}, err)
        err = err_new
        total = total + decompress_int8(q, scales)["g"]
    # mean of decompressed ≈ true gradient (error feedback recycles residue)
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true),
                               atol=2e-5)


# ----------------------------------------------------------------- data

def test_pipeline_deterministic():
    p1 = SyntheticPipeline(CFG, SHAPE, seed=1)
    p2 = SyntheticPipeline(CFG, SHAPE, seed=1)
    b1, b2 = p1.batch(3), p2.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_labels_are_shifted_stream():
    p = SyntheticPipeline(CFG, SHAPE, seed=1)
    b = p.batch(0)
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    # structured positions: label[t] == token[t+1] for most t
    match = (labs[:, :-1] == toks[:, 1:]).mean()
    assert match > 0.99


def test_pipeline_learnable_structure():
    p = SyntheticPipeline(CFG, SHAPE, seed=1)
    toks = np.asarray(p.batch(0)["tokens"])
    pred = (toks[:, :-1] * 31 + 7) % CFG.vocab_size
    frac = (toks[:, 1:] == pred).mean()
    assert 0.6 < frac < 0.9          # ~75% Markov structure
