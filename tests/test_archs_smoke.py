"""Per-architecture smoke tests: reduced config, one train/prefill/decode
step on CPU, asserting output shapes and finiteness (assignment f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SMOKE_SHAPES, reduced
from repro.configs.model_config import ShapeConfig
from repro.models.model import build_model

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch, key):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, mesh=None)
    params = model.init(key)
    batch = model.dummy_batch(key, SMOKE_SHAPES["smoke_train"])
    batch["labels"] = batch["tokens"]
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch, key):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, mesh=None)
    params = model.init(key)
    batch = model.dummy_batch(key, SMOKE_SHAPES["smoke_prefill"])
    batch.pop("labels", None)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    full = model.init_cache(2, 64 + 8)
    for k in full:
        if k in ("attn_k", "attn_v", "k", "v", "k_scale", "v_scale"):
            full[k] = jax.lax.dynamic_update_slice(
                full[k], cache[k].astype(full[k].dtype),
                (0,) * full[k].ndim)
        else:
            full[k] = cache[k].astype(full[k].dtype)
    dec = model.dummy_batch(key, SMOKE_SHAPES["smoke_decode"])
    dec["index"] = jnp.int32(64)
    logits2, cache2 = jax.jit(model.decode)(params, full, dec)
    assert logits2.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_gradients_finite_and_nonzero(arch, key):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, mesh=None)
    params = model.init(key)
    batch = model.dummy_batch(key, ShapeConfig("t", 32, 2, "train"))
    batch["labels"] = batch["tokens"]

    def loss_fn(p):
        return model.loss(p, batch)[0]

    grads = jax.jit(jax.grad(loss_fn))(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in leaves), f"{arch}: non-finite grads"
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in leaves)
    assert total > 0, f"{arch}: all-zero grads"


def test_param_counts_full_configs():
    """Full (non-reduced) configs match their advertised sizes."""
    expect = {
        "smollm-135m": (0.10e9, 0.18e9),
        "smollm-360m": (0.30e9, 0.45e9),
        "yi-6b": (5.5e9, 6.5e9),
        "qwen1.5-32b": (30e9, 36e9),
        "olmoe-1b-7b": (6.0e9, 7.5e9),
        "granite-moe-1b-a400m": (1.0e9, 1.7e9),
        "pixtral-12b": (11e9, 13.5e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "musicgen-medium": (1.3e9, 2.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params not in " \
                              f"[{lo/1e9:.1f}, {hi/1e9:.1f}]"


def test_moe_active_params_below_total():
    cfg = ARCHS["olmoe-1b-7b"]
    assert cfg.active_param_count() < cfg.param_count() / 3
