"""Prefix caching with copy-on-write paged KV: hash-chain keying,
block-pool refcount/validation semantics, LRU eviction of cached
blocks, deduped fragmentation accounting, COW forks, and byte-identical
greedy tokens cache-on vs cache-off on HOST, ACCEL, under forced
mid-stream migration, and across preempt/resume of shared blocks.

The byte-identity tests pin ``kv_cache_dtype`` to the compute dtype:
a lossy pool dtype (f32 compute over a bf16 pool) would make cache-on
reads of the matched prefix differ from cache-off's in-flight KV by a
rounding step — the cache must be lossless for bitwise equivalence
(bf16/bf16 and f32/f32 both are).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.runtime import XarTrekRuntime
from repro.serve import (BlockPool, ContinuousBatchingEngine,
                         GenerationRequest, PagedSlotManager, ServeEngine)
from repro.serve.batch import chain_hashes


def _serve(engine, reqs=()):
    return {rid: out.tokens for rid, out in engine.run(reqs).items()}


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32", kv_cache_dtype="float32")


@pytest.fixture(scope="module")
def sync_engine(cfg):
    return ServeEngine(cfg, seed=0)


def _prompt_set(cfg):
    """Five prompts exercising every match class against one shared
    16-token (2-block at bs=8) base: two live-sharing suffix variants,
    one exact block-aligned repeat (the fully-cached COW case), one
    partial-block divergence (matches exactly 1 block), one full miss."""
    rng = np.random.RandomState(42)
    base = rng.randint(0, cfg.vocab_size, size=16)
    other = rng.randint(0, cfg.vocab_size, size=16)
    return [
        np.concatenate([base, rng.randint(0, cfg.vocab_size, size=3)]),
        np.concatenate([base, rng.randint(0, cfg.vocab_size, size=3)]),
        base.copy(),
        np.concatenate([base[:12], rng.randint(0, cfg.vocab_size, size=4)]),
        other,
    ]


def _reqs(prompts, n=6):
    return [GenerationRequest(np.asarray(p, np.int32), max_new_tokens=n)
            for p in prompts]


def _engine(cfg, params, *, prefix=True, **kw):
    base = dict(max_slots=5, max_seq=64, params=params,
                paged=True, block_size=8, num_blocks=24)
    base.update(kw)
    return ContinuousBatchingEngine(cfg, prefix_cache=prefix, **base)


# ------------------------------------------------------------ hash chain

def test_chain_hashes_full_blocks_only():
    t = list(range(20))
    assert chain_hashes(t[:7], 8) == []            # partial block: no key
    assert len(chain_hashes(t[:8], 8)) == 1
    assert len(chain_hashes(t, 8)) == 2            # 20 tokens -> 2 full


def test_chain_hashes_prefix_property_and_divergence():
    a = list(range(32))
    h = chain_hashes(a, 8)
    assert chain_hashes(a + [99, 100], 8) == h     # extension keeps prefix
    b = list(a)
    b[10] = 999                                    # diverge inside block 1
    hb = chain_hashes(b, 8)
    assert hb[0] == h[0]                           # block 0 untouched
    assert hb[1] != h[1]
    assert hb[2] != h[2]                           # chain: all later differ
    c = list(a)
    c[0] = 999                                     # diverge in block 0
    assert all(x != y for x, y in zip(chain_hashes(c, 8), h))


# ------------------------------------------------- pool refcount + free()

def test_block_pool_free_validates_ids():
    pool = BlockPool(num_blocks=4, block_size=8)
    blocks = pool.alloc(2)
    with pytest.raises(ValueError, match="junk block 0"):
        pool.free([0])
    with pytest.raises(ValueError, match="out of range"):
        pool.free([5])
    with pytest.raises(ValueError, match="out of range"):
        pool.free([-1])
    # a never-allocated (but in-range) id is a double free
    spare = next(b for b in range(1, 5) if b not in blocks)
    with pytest.raises(ValueError, match="double free"):
        pool.free([spare])
    pool.free([blocks[0]])
    with pytest.raises(ValueError, match="double free"):
        pool.free([blocks[0]])
    # duplicate ids inside ONE call: second occurrence must raise too
    with pytest.raises(ValueError, match="double free"):
        pool.free([blocks[1], blocks[1]])


def test_block_pool_refcounted_sharing():
    pool = BlockPool(num_blocks=4, block_size=8)
    [b] = pool.alloc(1)
    pool.ref(b)                                    # second holder
    assert pool.blocks_in_use() == 1               # physical, not logical
    pool.free([b])
    assert pool.blocks_in_use() == 1               # one holder remains
    pool.free([b])
    assert pool.blocks_in_use() == 0
    with pytest.raises(ValueError, match="not allocated"):
        pool.ref(b)


def test_block_pool_cached_revive_and_lru_eviction():
    pool = BlockPool(num_blocks=4, block_size=8)
    b1, b2 = pool.alloc(2)
    assert pool.register(b1, 101) and pool.register(b2, 202)
    assert not pool.register(b1, 303)              # block already keyed
    pool.free([b1, b2])
    # refcount 0 but registered: parked cached, still allocatable
    assert pool.cached_blocks() == 2
    assert pool.free_blocks() == 4
    assert pool.blocks_in_use() == 0
    # miss leaves the cache alone; hit revives (consumes capacity)
    assert pool.match(999) is None
    assert pool.match(202) == b2
    assert pool.refcount[b2] == 1
    assert pool.free_blocks() == 3
    assert pool.stats["cache_hits"] == 1
    # a fresh alloc exhausts the free list then evicts the LRU cached
    got = pool.alloc(3)
    assert b1 in got                               # evicted + reused
    assert pool.stats["evicted"] == 1
    assert pool.lookup(101) is None                # key dropped on evict
    assert pool.lookup(202) == b2                  # live block keeps its key
    assert not pool.register(got[0], 202)          # first writer wins


def test_block_pool_unregister_cached_returns_to_free():
    pool = BlockPool(num_blocks=2, block_size=4)
    [b] = pool.alloc(1)
    pool.register(b, 7)
    pool.free([b])
    assert pool.is_cached(b)
    pool.unregister(b)                             # no longer reachable
    assert pool.cached_blocks() == 0
    assert pool.free_blocks() == 2
    assert pool.lookup(7) is None


# ------------------------------------- manager: match / COW / fragmentation

def test_manager_prefix_match_and_shared_fragmentation():
    mgr = PagedSlotManager(max_slots=2, block_size=4, num_blocks=16,
                           max_seq=32, prefix_cache=True)
    prompt = list(range(8))                        # 2 full blocks
    ra, rb = (GenerationRequest(np.asarray(prompt, np.int32),
                                max_new_tokens=4) for _ in range(2))
    blocks = mgr.pool.alloc(2)
    sa = mgr.admit(ra, first_token=1, blocks=blocks)
    mgr.register_full_blocks(sa, prompt)
    assert sa.block_hashes == chain_hashes(prompt, 4)
    # partial tail block is never matchable
    assert mgr.matchable_blocks(prompt[:6]) == 1
    assert mgr.matchable_blocks(prompt) == 2
    got, hashes = mgr.match_prefix(prompt)
    assert got == blocks and hashes == sa.block_hashes
    assert mgr.pool.refcount[blocks[0]] == 2       # shared, refcounted
    sb = mgr.admit(rb, first_token=1, blocks=got)
    frag = mgr.fragmentation()
    assert frag["reserved_positions"] == 2 * 4     # physical: deduped
    assert frag["shared_positions"] == 2 * 4       # the 2 extra logical
    assert frag["used_positions"] == 16
    assert frag["frag_positions"] == 0
    assert mgr.pool.blocks_in_use() == 2
    # COW: a write into the shared tail forks it for the writer only
    new_blocks, copy = mgr.ensure_writable(sb.blocks, 1)
    assert copy == (blocks[1], new_blocks[1])
    assert new_blocks[1] != blocks[1]
    assert mgr.pool.refcount[blocks[1]] == 1       # sa keeps the original
    assert mgr._stats["cow_forks"] == 1
    sb.blocks = new_blocks
    # sole-owner registered block: rewritten in place, key dropped
    in_place, copy2 = mgr.ensure_writable(sa.blocks, 1)
    assert copy2 is None and in_place[1] == blocks[1]
    assert not mgr.pool.is_registered(blocks[1])
    mgr.release(sa)
    mgr.release(sb)
    assert mgr.pool.blocks_in_use() == 0


# --------------------------------------------- engine: byte identity (HOST)

def test_host_cache_on_off_byte_identical_with_hits(cfg, sync_engine):
    """The headline invariant: greedy tokens are byte-identical with the
    prefix cache on vs off, while the cache-on engine computes strictly
    fewer prefill tokens, shares blocks live (shared_positions > 0
    mid-run), and forks COW at least once (the exact-repeat prompt)."""
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False)
    r_off = _reqs(prompts)
    want = _serve(off, r_off)

    shared_seen = []

    def watch(engine):
        shared_seen.append(
            engine.slots.fragmentation()["shared_positions"])

    on = _engine(cfg, sync_engine.params, on_step=watch)
    r_on = _reqs(prompts)
    got = _serve(on, r_on)

    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    ps = on.prefix_stats()
    assert ps["prefill_tokens"] < off.prefix_stats()["prefill_tokens"]
    assert ps["prefix_hit_tokens"] >= 30           # 16 + 15 + 8 exact
    assert ps["prefix_hit_rate"] > 0.3
    assert ps["cow_forks"] >= 1                    # the exact-repeat prompt
    assert ps["prefix_block_hits"] >= 5
    assert max(shared_seen) > 0                    # blocks WERE shared live
    assert on.slots.pool.blocks_in_use() == 0      # fully drained
    assert on.slots.pool.cached_blocks() > 0       # prefixes stay warm


def test_host_cached_revival_across_runs(cfg, sync_engine):
    """Blocks released at completion park in the cached set; a later run
    with the same prefix revives them instead of re-prefilling."""
    rng = np.random.RandomState(5)
    base = rng.randint(0, cfg.vocab_size, size=16)
    eng = _engine(cfg, sync_engine.params)
    _serve(eng, _reqs([base]))
    assert eng.slots.pool.blocks_in_use() == 0
    assert eng.slots.pool.cached_blocks() >= 2
    eng.reset_stats()
    suffix = np.concatenate([base, rng.randint(0, cfg.vocab_size, size=3)])
    _serve(eng, _reqs([suffix]))
    ps = eng.prefix_stats()
    assert ps["prefix_hit_tokens"] == 16           # both base blocks revived
    assert ps["prefill_tokens"] == 3


def test_eviction_under_pressure_keeps_pool_sound(cfg, sync_engine):
    """A pool too small to keep every finished prefix warm evicts LRU
    cached blocks to serve new allocations — and the free/cached
    accounting still drains to a full pool."""
    rng = np.random.RandomState(9)
    eng = _engine(cfg, sync_engine.params, max_slots=1, max_seq=32,
                  num_blocks=6)
    for i in range(3):
        prompt = rng.randint(0, cfg.vocab_size, size=16)
        out = _serve(eng, _reqs([prompt], n=4))
        assert all(len(t) == 4 for t in out.values())
    pool = eng.slots.pool
    assert pool.stats["evicted"] >= 1
    assert pool.blocks_in_use() == 0
    assert pool.free_blocks() == pool.num_blocks


# ------------------------------------------- ACCEL / migration / preemption

def test_accel_cache_on_off_byte_identical(cfg, sync_engine):
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False, backend="accel")
    r_off = _reqs(prompts)
    want = _serve(off, r_off)
    on = _engine(cfg, sync_engine.params, backend="accel")
    r_on = _reqs(prompts)
    got = _serve(on, r_on)
    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    assert on.prefix_stats()["prefix_hit_tokens"] > 0


def test_migration_cache_on_off_byte_identical(cfg, sync_engine):
    """Forced HOST -> ACCEL -> HOST mid-stream with the prefix cache on:
    shared paged blocks survive a real kernel swap bit-for-bit."""
    prompts = _prompt_set(cfg)
    off = _engine(cfg, sync_engine.params, prefix=False)
    r_off = _reqs(prompts)
    want = _serve(off, r_off)

    rt = XarTrekRuntime(registry=FunctionRegistry(), policy="always_host")

    def flip(engine):
        s = engine.stats["decode_steps"]
        if s == 1:
            rt.server.policy = "always_accel"
        elif s == 3:
            rt.server.policy = "always_host"

    on = _engine(cfg, sync_engine.params, runtime=rt, on_step=flip)
    r_on = _reqs(prompts)
    got = _serve(on, r_on)
    for a, b in zip(r_off, r_on):
        np.testing.assert_array_equal(want[a.req_id], got[b.req_id])
    decode = rt.summary()["per_function"]["cb_decode"]
    assert decode["calls"].get("host", 0) >= 1
    assert decode["calls"].get("accel", 0) >= 1    # both targets served
    assert on.prefix_stats()["prefix_hit_tokens"] > 0


def test_preempt_resume_with_shared_blocks_byte_identical(cfg, sync_engine):
    """Two long generations sharing a one-block prefix on a pool too
    small for both: the youngest is preempted WHILE holding shared
    blocks, resumes by re-prefill (matching its own cached blocks), and
    greedy tokens still equal the dense engine's."""
    rng = np.random.RandomState(3)
    prefix = rng.randint(0, cfg.vocab_size, size=4)
    p1 = np.concatenate([prefix, rng.randint(0, cfg.vocab_size, size=2)])
    p2 = np.concatenate([prefix, rng.randint(0, cfg.vocab_size, size=2)])
    dense = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=24,
                                     params=sync_engine.params)
    d1, d2 = _reqs([p1, p2], n=12)
    want = _serve(dense, [d1, d2])
    small = _engine(cfg, sync_engine.params, max_slots=2, max_seq=24,
                    block_size=4, num_blocks=8)
    s1, s2 = _reqs([p1, p2], n=12)
    got = _serve(small, [s1, s2])
    assert small.slots.stats["preempted"] >= 1
    np.testing.assert_array_equal(want[d1.req_id], got[s1.req_id])
    np.testing.assert_array_equal(want[d2.req_id], got[s2.req_id])
    assert small.slots.pool.blocks_in_use() == 0


# ---------------------------------------------------- load-signal bugfix

def test_queue_depth_counts_only_arrived_requests(cfg, sync_engine):
    """Regression: pre-submitted future arrivals (Poisson streams) are
    not load yet — signals().queue_depth must not count them."""
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                   params=sync_engine.params)
    eng.submit(GenerationRequest(np.arange(1, 5, dtype=np.int32),
                                 max_new_tokens=2, arrival_s=1e9))
    assert len(eng.queue) == 1
    assert eng.signals().queue_depth == 0          # not arrived yet
    assert eng.queue.arrived_len(0.0) == 0
    assert eng.queue.arrived_len(2e9) == 1
