"""Decode-path correctness: prefill + token-by-token decode must match the
teacher-forced forward pass (fp32, lossless caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.model_config import ShapeConfig
from repro.models import transformer as tf_lib
from repro.models.common import rmsnorm
from repro.models.model import build_model

S = 32


def _teacher_logits(model, cfg, params, batch):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        logits, _, _ = tf_lib.forward(params, batch, cfg, model.geom, None,
                                      mode="train")
        return logits
    x = tf_lib.embed_inputs(params, batch, cfg)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], x.shape[:2])
    x, _ = model._core(params, x, mode="train", positions=pos, cache=None)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return tf_lib.output_logits(params, x, cfg)


def _grow(model, cache, B, max_seq):
    full = model.init_cache(B, max_seq)
    for k in full:
        if k in ("attn_k", "attn_v", "k", "v", "k_scale", "v_scale"):
            full[k] = jax.lax.dynamic_update_slice(
                full[k], cache[k].astype(full[k].dtype), (0,) * full[k].ndim)
        else:
            full[k] = cache[k].astype(full[k].dtype)
    return full


@pytest.mark.parametrize("arch,tol", [
    ("smollm-135m", 1e-3), ("qwen1.5-32b", 1e-3), ("yi-6b", 5e-3),
    ("musicgen-medium", 1e-3), ("mamba2-2.7b", 1e-3), ("zamba2-1.2b", 5e-3),
    ("olmoe-1b-7b", 1e-3), ("pixtral-12b", 5e-3),
])
def test_prefill_decode_matches_teacher_forcing(arch, tol, key):
    cfg = dataclasses.replace(
        reduced(ARCHS[arch]), dtype="float32", kv_cache_dtype="float32",
        capacity_factor=16.0)   # high capacity: no MoE token drops
    model = build_model(cfg, mesh=None)
    params = model.init(key)
    batch = model.dummy_batch(key, ShapeConfig("t", S, 2, "train"))
    batch.pop("labels", None)
    logits_full = _teacher_logits(model, cfg, params, batch)

    half = S // 2
    audio = cfg.family == "audio"
    pre = {"tokens": (batch["tokens"][:, :, :half] if audio
                      else batch["tokens"][:, :half])}
    if "patch_embeds" in batch:
        pre["patch_embeds"] = batch["patch_embeds"][:, :min(cfg.num_patches,
                                                            half)]
    lg, cache = jax.jit(model.prefill)(params, pre)
    err0 = float(jnp.max(jnp.abs(
        lg.astype(jnp.float32) - logits_full[:, half - 1:half].astype(jnp.float32))))
    assert err0 < tol, f"prefill logits diverge: {err0}"

    cache = _grow(model, cache, 2, S)
    dstep = jax.jit(model.decode)
    worst = 0.0
    for t in range(half, S):
        dec = {"tokens": (batch["tokens"][:, :, t:t + 1] if audio
                          else batch["tokens"][:, t:t + 1]),
               "index": jnp.int32(t)}
        lg2, cache = dstep(params, cache, dec)
        err = float(jnp.max(jnp.abs(
            lg2.astype(jnp.float32) - logits_full[:, t:t + 1].astype(jnp.float32))))
        worst = max(worst, err)
    assert worst < tol, f"decode diverges from teacher forcing: {worst}"


def test_int8_cache_close_not_exact(key):
    """int8 KV is lossy but bounded; fp32 run is the reference."""
    base = dataclasses.replace(reduced(ARCHS["qwen1.5-32b"]), dtype="float32",
                               capacity_factor=16.0)
    outs = {}
    for cdt in ("float32", "int8"):
        cfg = dataclasses.replace(base, kv_cache_dtype=cdt)
        model = build_model(cfg, mesh=None)
        params = model.init(key)
        batch = model.dummy_batch(key, ShapeConfig("t", S, 2, "train"))
        cache = model.init_cache(2, S)
        dec = {"tokens": batch["tokens"][:, :1], "index": jnp.int32(0)}
        lg, _ = jax.jit(model.decode)(params, cache, dec)
        outs[cdt] = lg.astype(jnp.float32)
    err = float(jnp.max(jnp.abs(outs["int8"] - outs["float32"])))
    scale = float(jnp.max(jnp.abs(outs["float32"]))) + 1e-9
    assert err / scale < 0.15, f"int8 cache relative error too large: {err/scale}"


def test_vlm_loss_masks_patches(key):
    cfg = dataclasses.replace(reduced(ARCHS["pixtral-12b"]), dtype="float32")
    model = build_model(cfg, mesh=None)
    params = model.init(key)
    batch = model.dummy_batch(key, ShapeConfig("t", S, 2, "train"))
    batch["labels"] = batch["tokens"]
    _, m1 = model.loss(params, batch)
    # perturbing patch-position labels must not change the loss
    labels2 = batch["labels"].at[:, :cfg.num_patches].set(0)
    _, m2 = model.loss(params, dict(batch, labels=labels2))
    assert abs(float(m1["lm_loss"]) - float(m2["lm_loss"])) < 1e-6
