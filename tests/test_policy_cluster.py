"""SchedulingPolicy protocol + multi-engine cluster front-end.

* ``XarTrekHeuristic.decide`` reproduces the legacy ``schedule()``
  decision on every Algorithm-2 branch (table-driven + dense sweep).
* Policies move placement, never outputs: greedy and seeded-sampled
  tokens are byte-identical under PinHost / PinAccel /
  LatencyAwarePolicy.
* 2-engine ``ClusterFrontEnd`` round-trip over the TCP scheduler
  transport with an induced-load migration proven via
  ``runtime.summary()`` migration counts.
* ``LoadMonitor`` banding rides ``LoadSignals`` and the
  job_started/finished accounting is exercised by the engine path.
"""
import dataclasses
import math
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.function import FunctionRegistry
from repro.core.monitor import LoadMonitor
from repro.core.policy import (
    Decision, LatencyAwarePolicy, LoadSignals, PinAccel, PinAux, PinHost,
    Residency, XarTrekHeuristic, resolve_policy, schedule,
)
from repro.core.runtime import XarTrekRuntime
from repro.core.targets import DEFAULT_PLATFORM, TargetKind
from repro.core.thresholds import ThresholdRow
from repro.serve import (
    ClusterFrontEnd, ContinuousBatchingEngine, GenerationRequest,
    SamplingParams, ServeEngine,
)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                               dtype="float32")


@pytest.fixture(scope="module")
def sync_engine(cfg):
    return ServeEngine(cfg, seed=0)


def _prompts(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)


# ----------------------------------------------- Algorithm-2 parity


# one row per Algorithm-2 branch: (load, arm_thr, fpga_thr, resident)
ALG2_BRANCHES = [
    # l.9-13: load <= arm, load > fpga, cold -> HOST + reconfigure
    (15.0, 20.0, 10.0, False),
    # l.14-18: load > arm, load > fpga, cold -> AUX + reconfigure
    (25.0, 20.0, 10.0, False),
    # l.19-21: low load -> HOST
    (5.0, 20.0, 10.0, True),
    (5.0, 20.0, 10.0, False),
    # l.22-24: only ARM profitable -> AUX
    (15.0, 10.0, 20.0, True),
    (15.0, 10.0, 20.0, False),
    # l.25-27: hot kernel, fpga_thr < arm_thr -> ACCEL
    (25.0, 20.0, 10.0, True),
    # l.29-30: hot kernel, fpga_thr >= arm_thr -> AUX
    (25.0, 10.0, 10.0, True),
    (25.0, 5.0, 10.0, True),
    # boundary loads (== thresholds)
    (10.0, 20.0, 10.0, True),
    (20.0, 20.0, 10.0, False),
    # infinite thresholds (the cold-table default)
    (3.0, math.inf, math.inf, False),
]


@pytest.mark.parametrize("load,arm,fpga,resident", ALG2_BRANCHES)
def test_xartrek_heuristic_matches_legacy_schedule(load, arm, fpga,
                                                   resident):
    row = ThresholdRow("app", "KNL", fpga_thr=fpga, arm_thr=arm)
    want = schedule(load, row, resident)
    got = XarTrekHeuristic().decide(
        LoadSignals(x86_load=load), row, Residency(resident=resident))
    assert got == want, (load, arm, fpga, resident)


def test_xartrek_heuristic_dense_sweep_parity():
    """Exhaustive grid over load x thresholds x residency: the protocol
    wrapper and the legacy free function never disagree."""
    grid = [0.0, 1.0, 9.9, 10.0, 10.1, 20.0, 30.0, math.inf]
    for load in grid[:-1]:
        for arm in grid:
            for fpga in grid:
                row = ThresholdRow("a", "k", fpga_thr=fpga, arm_thr=arm)
                for resident in (False, True):
                    assert (XarTrekHeuristic().decide(
                        LoadSignals(x86_load=load), row,
                        Residency(resident=resident))
                        == schedule(load, row, resident))


# ----------------------------------------------- built-in policy units


def test_pin_policies_targets_and_reconfigure():
    row = ThresholdRow("a", "k")
    s = LoadSignals()
    assert PinHost().decide(s, row, Residency()) == Decision(TargetKind.HOST)
    assert PinAux().decide(s, row, Residency()) == Decision(TargetKind.AUX)
    # cold ACCEL pin keeps requesting the async load; hot pin doesn't
    assert PinAccel().decide(s, row, Residency()) == Decision(
        TargetKind.ACCEL, reconfigure=True)
    assert PinAccel().decide(s, row, Residency(loading=True)) == Decision(
        TargetKind.ACCEL, reconfigure=False)
    assert PinAccel().decide(s, row, Residency(resident=True)) == Decision(
        TargetKind.ACCEL, reconfigure=False)


def test_latency_aware_policy_decisions():
    pol = LatencyAwarePolicy(queue_depth_hi=4, free_kv_lo=0.25,
                             ttft_slo_s=0.5)
    row = ThresholdRow("a", "k")
    hot, cold = Residency(resident=True), Residency()
    calm = LoadSignals(queue_depth=0, free_kv_frac=1.0)
    assert pol.decide(calm, row, hot).target == TargetKind.HOST
    for pressure in (LoadSignals(queue_depth=4),
                     LoadSignals(free_kv_frac=0.2),
                     LoadSignals(ttft_p50_s=0.9)):
        assert pol.decide(pressure, row, hot).target == TargetKind.ACCEL
        # cold kernel: stay HOST, kick the async load (latency hiding)
        d = pol.decide(pressure, row, cold)
        assert d.target == TargetKind.HOST and d.reconfigure
        d = pol.decide(pressure, row, Residency(loading=True))
        assert d.target == TargetKind.HOST and not d.reconfigure
    # a strictly faster resident ACCEL is used even without pressure
    fast = LoadSignals(host_decode_ms=8.0, accel_decode_ms=4.0)
    assert pol.decide(fast, row, hot).target == TargetKind.ACCEL


def test_resolve_policy_aliases_and_errors():
    assert isinstance(resolve_policy("xartrek"), XarTrekHeuristic)
    assert isinstance(resolve_policy("always_accel"), PinAccel)
    p = LatencyAwarePolicy()
    assert resolve_policy(p) is p
    with pytest.raises(ValueError, match="unknown policy"):
        resolve_policy("always_gpu")
    with pytest.raises(TypeError, match="SchedulingPolicy"):
        resolve_policy(42)


def test_signals_aggregate_is_cross_engine_pressure():
    a = LoadSignals(queue_depth=5, active_slots=2, free_kv_frac=0.5,
                    host_decode_ms=4.0, band="low")
    b = LoadSignals(queue_depth=0, active_slots=1, free_kv_frac=0.9,
                    host_decode_ms=8.0, accel_decode_ms=6.0,
                    band="medium")
    agg = LoadSignals.aggregate([a, b])
    assert agg.queue_depth == 5 and agg.active_slots == 3
    assert agg.free_kv_frac == 0.5           # worst engine
    assert agg.host_decode_ms == 6.0         # mean of observers
    assert agg.accel_decode_ms == 6.0        # None contributors skipped
    assert agg.band == "medium" and agg.engines == 2


def test_engine_rejects_non_pin_policy_without_runtime(cfg):
    with pytest.raises(ValueError, match="runtime"):
        ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                 policy=XarTrekHeuristic())
    with pytest.raises(ValueError, match="not both"):
        ContinuousBatchingEngine(cfg, max_slots=2, max_seq=32,
                                 policy=PinHost(), backend="accel")


# ------------------------------------------ placement never moves outputs


def test_outputs_byte_identical_across_policies(cfg, sync_engine):
    """Greedy AND seeded-sampled tokens are byte-identical under
    PinHost, PinAccel and LatencyAwarePolicy (tuned so pressure flips
    placement mid-run): policies move placement, never outputs."""
    def make_reqs():
        return [GenerationRequest(
            rng2.randint(0, cfg.vocab_size, size=int(rng2.randint(4, 14))),
            max_new_tokens=6,
            sampling=(SamplingParams(temperature=0.8, top_k=40,
                                     seed=100 + i)
                      if i % 2 else SamplingParams()))
            for i in range(6)]

    outs = {}
    for name, build in (
            ("pin_host", lambda: ContinuousBatchingEngine(
                cfg, max_slots=2, max_seq=64, params=sync_engine.params,
                policy=PinHost())),
            ("pin_accel", lambda: ContinuousBatchingEngine(
                cfg, max_slots=2, max_seq=64, params=sync_engine.params,
                policy=PinAccel())),
            ("latency_aware", lambda: ContinuousBatchingEngine(
                cfg, max_slots=2, max_seq=64, params=sync_engine.params,
                runtime=XarTrekRuntime(registry=FunctionRegistry()),
                fn_prefix="lat",
                policy=LatencyAwarePolicy(queue_depth_hi=2)))):
        rng2 = np.random.RandomState(23)
        reqs = make_reqs()
        outs[name] = [
            out.tokens for _, out in sorted(build().run(reqs).items())]
    for name in ("pin_accel", "latency_aware"):
        for a, b in zip(outs["pin_host"], outs[name]):
            np.testing.assert_array_equal(a, b, err_msg=name)


# --------------------------------------------------- engine signal feed


def test_engine_publishes_signals_and_monitor_accounting(cfg, sync_engine):
    """The engine publishes LoadSignals to the scheduler each loop
    iteration (band included — monitor banding is live on the serve
    path now) and the runtime's job_started/finished accounting drains
    back to zero after the run."""
    rt = XarTrekRuntime(registry=FunctionRegistry())
    started = []
    orig = rt.monitor.job_started
    rt.monitor.job_started = lambda kind: (started.append(kind),
                                           orig(kind))[1]
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64,
                                   params=sync_engine.params, runtime=rt,
                                   fn_prefix="sig")
    out = eng.run([GenerationRequest(np.arange(1, 9, dtype=np.int32),
                                     max_new_tokens=4)])
    assert len(out) == 1
    # the engine's snapshot reached the scheduler server
    assert "sig" in rt.server._published
    pub = rt.server._published["sig"]
    assert pub.band in ("low", "medium", "high")
    assert pub.host_decode_ms is not None and pub.host_decode_ms > 0
    # monitor accounting was exercised by every step and drained
    assert started and all(k in TargetKind for k in started)
    for kind in TargetKind:
        assert rt.monitor.active(kind) == 0
    # banding rides the monitor's own signals too
    assert rt.monitor.signals().band == "low"
    mon = LoadMonitor(DEFAULT_PLATFORM)
    for _ in range(7):
        mon.job_started(TargetKind.HOST)
    assert mon.signals().band == "medium"
    assert mon.signals().x86_load == 7.0


# ------------------------------------------------------- cluster serving


def test_cluster_round_trip_with_induced_migration(cfg, sync_engine):
    """2 engines, one TCP scheduler, shared XarTrekHeuristic: a burst on
    the cluster raises the AGGREGATE load past the decode threshold, so
    decode steps migrate HOST -> ACCEL (and the long request's outputs
    stay byte-identical to the single-engine reference)."""
    prompt = np.arange(1, 13, dtype=np.int32)
    want = sync_engine.generate(
        np.asarray(prompt)[None, :], max_new_tokens=24).tokens[0]

    fe = ClusterFrontEnd(cfg, n_engines=2, policy="xartrek",
                         transport="tcp", params=sync_engine.params,
                         max_slots=2, max_seq=64)
    fe.set_decode_thresholds(fpga_thr=2.0)
    with fe:
        fe.warmup()
        long = fe.submit(GenerationRequest(prompt, max_new_tokens=24))
        time.sleep(0.1)      # let it start decoding under low load
        burst = [fe.submit(GenerationRequest(
            np.arange(1, 9, dtype=np.int32), max_new_tokens=6))
            for _ in range(10)]
        outs = fe.drain(timeout=180)
        summary = fe.summary()

    assert len(outs) == 11
    np.testing.assert_array_equal(outs[long.req_id].tokens, want)
    for h in burst:
        assert outs[h.req_id].finish_reason == "length"
    # the burst's queue pressure crossed fpga_thr on the CENTRAL
    # scheduler: real migrations, recorded per worker
    assert summary["migrations"] >= 1
    assert summary["decisions"]["accel"] >= 1
    # both workers actually served steps (the front-end balanced)
    for wid, s in summary["per_engine"].items():
        assert s["calls"] > 0, wid
    accel_decodes = sum(
        s["per_function"].get(f"{wid}_decode", {})
        .get("calls", {}).get("accel", 0)
        for wid, s in summary["per_engine"].items())
    assert accel_decodes >= 1


def test_cluster_cross_engine_pressure_migrates_other_worker(cfg,
                                                             sync_engine):
    """The ROADMAP scenario verbatim: worker 1 serves ONE long request;
    worker 0 takes a burst submitted directly to it.  Worker 1's decode
    steps migrate to ACCEL because of worker 0's published pressure —
    co-tenant load balancing, not self-defence."""
    fe = ClusterFrontEnd(cfg, n_engines=2, policy="xartrek",
                         transport="inproc", params=sync_engine.params,
                         max_slots=2, max_seq=64, worker_prefix="x")
    fe.set_decode_thresholds(fpga_thr=2.0)
    w0, w1 = fe.workers
    with fe:
        fe.warmup()          # lazy jits compile outside the scenario
        # prompt fits the warmed 8-wide prefill bucket: no mid-scenario
        # shape-bucket compile can eat the pressure window
        h_long = w1.submit(GenerationRequest(
            np.arange(1, 9, dtype=np.int32), max_new_tokens=50))
        time.sleep(0.02)     # a couple of low-load HOST steps first
        burst = [w0.submit(GenerationRequest(
            np.arange(1, 7, dtype=np.int32), max_new_tokens=8))
            for _ in range(8)]
        deadline = time.monotonic() + 180
        for h in [h_long] + burst:
            h.result(timeout=max(deadline - time.monotonic(), 0.01))
        s1 = w1.runtime.summary()

    decode = s1["per_function"]["x1_decode"]
    assert decode["calls"].get("accel", 0) >= 1, s1
    assert s1["migrations"] >= 1
