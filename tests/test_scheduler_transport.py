"""Direct coverage of the pieces core/runtime.py leans on: the TCP
scheduler transport (round-trip, unknown-op and malformed-JSON error
paths) and the KernelBank (LRU eviction, async-load race semantics)."""
import json
import socket
import threading
import time

import pytest

from repro.core.kernel_bank import KernelBank
from repro.core.monitor import LoadMonitor
from repro.core.scheduler import (SchedulerServer, TcpSchedulerClient,
                                  TcpSchedulerServer)
from repro.core.targets import DEFAULT_PLATFORM, TargetKind
from repro.core.thresholds import ThresholdTable


def _server(policy: str = "always_aux") -> SchedulerServer:
    platform = DEFAULT_PLATFORM
    return SchedulerServer(platform, ThresholdTable(),
                           KernelBank(slots=2), LoadMonitor(platform),
                           policy=policy)


@pytest.fixture()
def tcp():
    srv = TcpSchedulerServer(_server())
    addr = srv.start()
    yield srv, addr
    srv.stop()


# ------------------------------------------------------------ TCP transport

def test_tcp_request_report_roundtrip(tcp):
    srv, addr = tcp
    client = TcpSchedulerClient("appA", addr)
    try:
        d = client.before_call()
        assert d.target == TargetKind.AUX         # always_aux policy
        assert not d.reconfigure
        client.after_call(TargetKind.AUX, 12.5)
        row = srv.inner.table.row("appA")
        assert row.arm_exec == 12.5               # Algorithm 1 recorded it
        assert srv.inner.decisions[TargetKind.AUX] == 1
    finally:
        client.close()


def test_tcp_many_clients_roundtrip(tcp):
    srv, addr = tcp
    clients = [TcpSchedulerClient(f"app{i}", addr) for i in range(4)]
    try:
        for c in clients:
            for _ in range(3):
                assert c.before_call().target == TargetKind.AUX
        assert srv.inner.decisions[TargetKind.AUX] == 12
    finally:
        for c in clients:
            c.close()


def _raw_rpc(addr, line: bytes) -> dict:
    with socket.create_connection(addr) as sock:
        f = sock.makefile("rwb")
        f.write(line)
        f.flush()
        return json.loads(f.readline())


def test_tcp_unknown_op_reports_error(tcp):
    _, addr = tcp
    resp = _raw_rpc(addr, b'{"op": "bogus"}\n')
    assert resp == {"error": "unknown op bogus"}


def test_tcp_malformed_json_reports_error_and_keeps_serving(tcp):
    _, addr = tcp
    resp = _raw_rpc(addr, b"this is not json\n")
    assert "error" in resp
    # a malformed line must not take the server down
    resp = _raw_rpc(addr, b'{"op": "request", "app": "x"}\n')
    assert resp["flag"] == TargetKind.AUX.flag


def test_tcp_missing_field_reports_error(tcp):
    _, addr = tcp
    resp = _raw_rpc(addr, b'{"op": "request"}\n')   # no "app"
    assert "error" in resp


def test_tcp_heartbeat_op_records_liveness(tcp):
    srv, addr = tcp
    client = TcpSchedulerClient("hb", addr)
    try:
        t0 = time.monotonic()
        client.heartbeat("w0", 0)
        client.heartbeat("w0", 1, info={"slots": 2})
        client.heartbeat("w1", 0)
        beats = srv.inner.heartbeats
        assert beats["w0"]["seq"] == 1
        assert beats["w0"]["info"] == {"slots": 2}
        assert beats["w1"]["seq"] == 0
        assert beats["w0"]["t"] >= t0      # parent-clock timestamps
    finally:
        client.close()


def test_tcp_kernel_op_registers_remote_residency(tcp):
    """A worker in another process reports its bank state: the central
    table's hw_kernel pins to the REMOTE name and residency() answers
    from the remote snapshot when the server has no local bank."""
    platform = DEFAULT_PLATFORM
    bankless = SchedulerServer(platform, ThresholdTable(), bank=None,
                               monitor=LoadMonitor(platform),
                               policy="xartrek")
    with TcpSchedulerServer(bankless) as srv:
        client = TcpSchedulerClient("w0_decode", srv.address)
        try:
            client.register_remote_kernel("w0_decode", "w0_decode",
                                          True, False)
            assert bankless.table.row("w0_decode").hw_kernel == "w0_decode"
            res = bankless.residency("w0_decode")
            assert res.resident and not res.loading
            # unreported kernels answer cold, not KeyError
            assert not bankless.residency("nope").resident
        finally:
            client.close()


def test_tcp_server_stop_is_idempotent_and_releases_port():
    srv = TcpSchedulerServer(_server())
    addr = srv.start()
    srv.stop()
    srv.stop()                          # second stop: no-op, no raise
    # the listener socket is gone: the port is rebindable immediately
    probe = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    probe.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    probe.bind(addr)
    probe.close()
    # an unstarted server's stop must still close its listener
    srv2 = TcpSchedulerServer(_server())
    srv2.stop()
    srv2.stop()


def test_tcp_client_raises_on_server_error_response(tcp):
    """Server-side failures surface as RuntimeError at the client (not
    a KeyError three frames up), and the connection keeps serving."""
    _, addr = tcp
    client = TcpSchedulerClient("errs", addr)
    try:
        with pytest.raises(RuntimeError, match="heartbeat.*failed"):
            client._rpc({"op": "heartbeat"})    # missing fields
        assert client.before_call().target == TargetKind.AUX
    finally:
        client.close()
    client.close()                      # close-after-close: no raise


def test_tcp_client_raises_connection_error_on_dead_server():
    """A peer that hangs up mid-rpc surfaces as ConnectionError, not an
    empty-line JSONDecodeError."""
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def accept_and_hang_up():
        conn, _ = lst.accept()
        conn.close()

    t = threading.Thread(target=accept_and_hang_up, daemon=True)
    t.start()
    client = TcpSchedulerClient("w", lst.getsockname())
    try:
        with pytest.raises(ConnectionError):
            client.heartbeat("w", 0)
    finally:
        client.close()
        t.join(5.0)
        lst.close()


# -------------------------------------------------------------- KernelBank

def _tick_clock():
    """Deterministic strictly-increasing clock."""
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


def test_bank_lru_eviction_prefers_least_recently_used():
    bank = KernelBank(slots=2, clock=_tick_clock())
    bank.load_sync("a")
    bank.load_sync("b")
    assert bank.is_resident("a")        # touch a -> b is now LRU
    bank.load_sync("c")
    assert bank.resident_kernels() == ["a", "c"]
    assert bank.stats["evictions"] == 1
    assert bank.stats["loads"] == 3


def test_bank_load_race_window_then_resident():
    """Algorithm 2's 'No HW kernel' branch: while the async load runs the
    kernel is NOT resident (callers keep executing on a CPU target — the
    latency-hiding fallback runtime.call performs), and is_loading
    reports the in-flight reconfiguration."""
    started = threading.Event()
    release = threading.Event()

    def slow_load(name):
        started.set()
        assert release.wait(5.0)
        return name

    bank = KernelBank(slots=2, load_fn=slow_load)
    bank.load_async("k")
    assert started.wait(5.0)
    assert not bank.is_resident("k")    # race window: load still running
    assert bank.is_loading("k")
    hits_before = bank.stats["hits"]
    misses_before = bank.stats["misses"]
    assert misses_before >= 1
    release.set()
    deadline = time.time() + 5.0
    while not bank.is_resident("k") and time.time() < deadline:
        time.sleep(0.01)
    assert bank.is_resident("k")
    assert bank.stats["hits"] > hits_before
    assert not bank.is_loading("k")
    assert bank.get("k") == "k"


def test_bank_duplicate_load_async_is_idempotent():
    release = threading.Event()
    calls = []

    def slow_load(name):
        calls.append(name)
        release.wait(5.0)
        return name

    bank = KernelBank(slots=2, load_fn=slow_load)
    bank.load_async("k")
    bank.load_async("k")                # second request: no second thread
    release.set()
    bank.load_sync("k")
    assert calls == ["k"]
    assert bank.stats["loads"] == 1
    bank.load_async("k")                # already resident: no-op
    assert bank.stats["loads"] == 1
