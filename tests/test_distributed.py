"""Multi-device tests: run in subprocesses with forced host device counts
so the main test process keeps its single real device."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow   # each case compiles in a fresh subprocess

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """2x2-mesh loss/grads == single-device on the SAME padded geometry
    (padding differs by TP degree, so the unsharded reference model is
    built with the sharded geometry explicitly)."""
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.configs.model_config import ShapeConfig
        from repro.models.model import Model, build_model
        from repro.models.transformer import Geometry
        from repro.parallel.compat import make_mesh, use_mesh
        from repro.parallel.sharding import named_tree

        cfg = dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                                  dtype="float32")
        key = jax.random.PRNGKey(0)
        shape = ShapeConfig("t", 64, 4, "train")
        mesh = make_mesh((2, 2), ("data", "model"))

        m_ref = Model(cfg=cfg, geom=Geometry.of(cfg, 2), mesh=None)
        params = m_ref.init(key)
        batch = m_ref.dummy_batch(key, shape)
        batch["labels"] = batch["tokens"]
        loss0, _ = jax.jit(m_ref.loss)(params, batch)
        g0 = jax.jit(jax.grad(lambda p: m_ref.loss(p, batch)[0]))(params)

        m_s = build_model(cfg, mesh)
        with use_mesh(mesh):
            params_s = jax.device_put(params, named_tree(mesh, m_s.specs()))
            batch_s = jax.device_put(
                batch, named_tree(mesh, m_s.batch_spec()))
            loss1, _ = jax.jit(m_s.loss)(params_s, batch_s)
            g1 = jax.jit(jax.grad(lambda p: m_s.loss(p, batch_s)[0]))(params_s)
        d_loss = abs(float(loss0) - float(loss1))
        # per-leaf relative bound: GSPMD partial-sum/scatter ordering gives
        # O(0.5%) fp32 drift on large-magnitude leaves (embed-scatter grads
        # are O(300)), and the tolerance must not depend on that magnitude
        d_grad = max(float(jnp.max(jnp.abs(a - b)))
                     / max(float(jnp.max(jnp.abs(a))), 1.0)
                     for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
        print("DELTA", d_loss, d_grad)
        assert d_loss < 1e-4, (float(loss0), float(loss1))
        assert d_grad < 0.01

        print("OK")
    """)
    assert "OK" in out


def test_moe_expert_parallel_matches_unsharded():
    """shard_map EP MoE == single-device MoE (same routing/capacity)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import ARCHS, reduced
        from repro.configs.model_config import ShapeConfig
        from repro.models.model import build_model
        from repro.parallel.compat import make_mesh, use_mesh
        from repro.parallel.sharding import named_tree

        cfg = dataclasses.replace(reduced(ARCHS["olmoe-1b-7b"]),
                                  dtype="float32")
        key = jax.random.PRNGKey(0)
        shape = ShapeConfig("t", 32, 4, "train")

        m0 = build_model(cfg, mesh=None)
        params = m0.init(key)
        batch = m0.dummy_batch(key, shape)
        batch["labels"] = batch["tokens"]
        loss0, _ = jax.jit(m0.loss)(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        m1 = build_model(cfg, mesh)
        with use_mesh(mesh):
            pspec = named_tree(mesh, m1.specs())
            params_s = jax.device_put(params, pspec)
            bspec = named_tree(mesh, m1.batch_spec())
            batch_s = jax.device_put(batch, bspec)
            loss1, _ = jax.jit(m1.loss)(params_s, batch_s)
        d = abs(float(loss0) - float(loss1))
        print("DELTA", d)
        # capacity is per-shard under EP so a little routing drift is
        # expected; fp32 keeps it tight
        assert d < 0.05, (float(loss0), float(loss1))
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Checkpoint saved from a 4x2 mesh restores onto 2x2 (elastic)."""
    out = _run(f"""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.configs.model_config import ShapeConfig, TrainConfig
        from repro.parallel.compat import make_mesh
        from repro.train.trainer import Trainer

        cfg = reduced(ARCHS["smollm-135m"])
        shape = ShapeConfig("t", 64, 4, "train")
        tcfg = TrainConfig(learning_rate=1e-3)

        mesh1 = make_mesh((4, 2), ("data", "model"))
        tr1 = Trainer(cfg, shape, tcfg, mesh=mesh1,
                      ckpt_dir=r"{tmp_path}", ckpt_every=4, total_steps=4)
        tr1.run(steps=4, log_every=0)

        mesh2 = make_mesh((2, 2), ("data", "model"))
        tr2 = Trainer(cfg, shape, tcfg, mesh=mesh2,
                      ckpt_dir=r"{tmp_path}", ckpt_every=4, total_steps=8)
        log = tr2.run(steps=8, log_every=0)
        assert log[0]["step"] == 5, log[0]
        print("OK resumed-on-smaller-mesh")
    """)
    assert "OK" in out


def test_multipod_mesh_and_grad_compression():
    """pod-axis mesh builds; int8+EF compressed psum over 'pod' works."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compression import pod_allreduce_compressed
        from repro.parallel.compat import make_mesh, shard_map, use_mesh
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        grads = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4) * 1e-3}
        err = {"w": jnp.zeros((2, 4))}

        def f(g, e):
            return pod_allreduce_compressed(g, e)

        sm = shard_map(f, mesh=mesh,
                       in_specs=(P(), P()), out_specs=(P(), P()),
                       check_vma=False)
        with use_mesh(mesh):
            mean, new_err = jax.jit(sm)(grads, err)
        np.testing.assert_allclose(np.asarray(mean["w"]),
                                   np.asarray(grads["w"]), rtol=0.02,
                                   atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_single_cell_mini():
    """The dry-run machinery itself (reduced device count, small arch)."""
    out = _run("""
        import os
        # simulate the dryrun entry with fewer fake devices for speed
        import jax
        from repro.configs import get_arch, get_shape
        from repro.launch.dryrun import build_step
        from repro.models.model import build_model
        from repro.parallel.compat import make_mesh, peak_memory_bytes, use_mesh
        from repro.launch.hlo_cost import analyze

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_arch("smollm-135m")
        shape = get_shape("train_4k")
        model = build_model(cfg, mesh)
        with use_mesh(mesh):
            jitted, specs = build_step(model, cfg, shape, mesh)
            compiled = jitted.lower(*specs).compile()
        peak = peak_memory_bytes(compiled.memory_analysis())
        assert peak > 0
        r = analyze(compiled.as_text())
        assert r["flops"] > 1e12
        print("OK", peak, r["flops"])
    """, devices=8)
    assert "OK" in out
