"""The trip-count-aware HLO cost analyzer vs known-flop programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze

D = 128


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_single_matmul_flops():
    txt = _compile(lambda a, b: a @ b,
                   jax.ShapeDtypeStruct((D, D), jnp.float32),
                   jax.ShapeDtypeStruct((D, D), jnp.float32))
    r = analyze(txt)
    assert abs(r["flops"] - 2 * D**3) / (2 * D**3) < 0.05


@pytest.mark.parametrize("L", [1, 5, 12])
def test_scan_flops_scale_with_trip_count(L):
    def body(x, w):
        return jnp.tanh(x @ w), None

    def fn(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    txt = _compile(fn, jax.ShapeDtypeStruct((D, D), jnp.float32),
                   jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    r = analyze(txt)
    expect = 2 * D**3 * L
    assert abs(r["flops"] - expect) / expect < 0.1, \
        f"L={L}: {r['flops']:.3e} vs {expect:.3e}"


def test_grad_of_scan_flops():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def fn(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    L = 7
    txt = _compile(jax.grad(fn, argnums=1),
                   jax.ShapeDtypeStruct((D, D), jnp.float32),
                   jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    r = analyze(txt)
    expect = (2 + 2) * D**3 * L + 2 * D**3 * (L - 1)  # fwd + wgrad + dgrad
    assert abs(r["flops"] - expect) / expect < 0.15


def test_nested_scan_multiplies():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def step(x, _):
            y, _ = jax.lax.scan(inner, x, ws)
            return y, None
        y, _ = jax.lax.scan(step, x, None, length=3)
        return jnp.sum(y)

    txt = _compile(outer, jax.ShapeDtypeStruct((D, D), jnp.float32),
                   jax.ShapeDtypeStruct((4, D, D), jnp.float32))
    r = analyze(txt)
    expect = 2 * D**3 * 3 * 4
    assert abs(r["flops"] - expect) / expect < 0.1


def test_parser_handles_wide_tuples():
    """Tuple types with /*index=N*/ comments must not break op parsing."""
    def body(carry, w):
        a, b, c, d, e, f = carry
        return (a @ w, b, c, d, e, f), None

    def fn(a, ws):
        init = (a,) * 6
        out, _ = jax.lax.scan(body, init, ws)
        return jnp.sum(out[0])

    txt = _compile(fn, jax.ShapeDtypeStruct((D, D), jnp.float32),
                   jax.ShapeDtypeStruct((5, D, D), jnp.float32))
    r = analyze(txt)
    expect = 2 * D**3 * 5
    assert r["flops"] > expect * 0.9
